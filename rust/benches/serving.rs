//! Serving perf, artifact-free (the serving layer decodes through the
//! KV-cached pure-Rust forward):
//!
//! - closed-loop throughput + batch occupancy of the continuous-batching
//!   engine, dense vs compressed-with-exact-factors (isolates the
//!   low-rank kernel cost);
//! - the decode rows CI gates: KV-cached incremental decode vs the
//!   full-prefix recompute oracle for a 256-token completion on the
//!   synthetic (builtin tiny) config. Before timing, the two modes'
//!   greedy outputs are asserted identical — speed means nothing if the
//!   cache diverges from the oracle;
//! - the batched rows CI gates: one stacked `decode_batch` per tick vs a
//!   per-session `decode_step` loop at B ∈ {1, 4, 8} on the builtin
//!   "small" config. Before timing, the two paths' logits are asserted
//!   bitwise equal per row — the decode_batch row-equality contract;
//! - the quantized rows CI gates: the B=8 t=4 stacked-decode workload
//!   through the f32 low-rank backend vs the fused int8 backend built
//!   from the same factors (see README "Quantized serving"). Before
//!   timing, each backend's rows are asserted bitwise against its own
//!   decode_step and the int8 model's PPL within 10% of f32 low-rank.

use aasvd::bench::Bench;
use aasvd::data::{Batcher, Corpus, Domain};
use aasvd::eval::{lowrank_ppl, quant_ppl};
use aasvd::model::init::init_params;
use aasvd::model::lowrank::exact_factors;
use aasvd::model::quant_lowrank::QuantBlockFactors;
use aasvd::model::Config;
use aasvd::serve::batcher::bench_prompts;
use aasvd::serve::http::parse::{find_head_end, parse_head, Limits};
use aasvd::serve::{
    CompressedBackend, DecodeMode, DenseBackend, GenParams, ModelBackend, PagedKvOptions,
    QuantizedBackend, ServeMetrics, ServedModel, Server, ServerOptions, Session,
};
use aasvd::util::pool::Pool;
use aasvd::util::rng::Rng;

const DECODE_TOKENS: usize = 256;
const BATCH_TOKENS: usize = 32;

/// Deterministic per-row token stream for the batched-decode rows.
fn batch_token(row: usize, step: usize) -> i32 {
    ((row * 31 + step * 7) % 256) as i32
}

/// Fresh one-token-prompt sessions, one per batch row.
fn batch_sessions<B: ModelBackend + ?Sized>(be: &mut B, rows: usize) -> Vec<Session> {
    (0..rows)
        .map(|r| be.prefill(&[r as i32 + 1]).unwrap().session)
        .collect()
}

/// The decode_batch row contract for one backend: every batched row
/// must match its sequential decode_step twin bitwise.
fn assert_batch_rows_match(
    be_batch: &mut dyn ModelBackend,
    be_seq: &mut dyn ModelBackend,
    label: &str,
) {
    let mut batched = batch_sessions(be_batch, 8);
    let mut solo = batch_sessions(be_seq, 8);
    for step in 0..8usize {
        let toks: Vec<i32> = (0..8).map(|r| batch_token(r, step)).collect();
        let rows = Pool::exact(4).install(|| {
            let mut refs: Vec<&mut Session> = batched.iter_mut().collect();
            be_batch.decode_batch(&mut refs, &toks)
        });
        for (r, row) in rows.into_iter().enumerate() {
            let row = row.expect("batched row succeeds");
            let want = be_seq.decode_step(&mut solo[r], toks[r]).unwrap();
            assert!(
                row.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{label}: decode_batch row {r} diverged from decode_step at step {step}"
            );
        }
    }
}

/// Eight prompts sharing an exactly-4-block (64-token) prefix with short
/// unique tails — the shared-prefix workload for the paged-KV rows.
fn prefix_prompts() -> Vec<String> {
    let mut prefix = String::from("shared system prompt for the prefix-reuse serving bench ");
    while prefix.len() < 64 {
        prefix.push('.');
    }
    (0..8).map(|i| format!("{prefix} tail {i:02}")).collect()
}

/// Run the 8 shared-prefix requests through one server (paged when
/// `paged` is Some); returns per-request texts + the final metrics.
fn prefix_round(
    cfg: &Config,
    model: ServedModel,
    paged: Option<PagedKvOptions>,
) -> (Vec<String>, ServeMetrics) {
    let server = Server::start_with(
        cfg.clone(),
        model,
        ServerOptions {
            paged_kv: paged,
            ..Default::default()
        },
    );
    let completions: Vec<_> = prefix_prompts()
        .iter()
        .map(|p| {
            server
                .submit(
                    p,
                    GenParams {
                        max_new_tokens: 8,
                        temperature: 0.0,
                        ..Default::default()
                    },
                )
                .expect("queue has room")
        })
        .collect();
    let texts: Vec<String> = completions
        .into_iter()
        .map(|c| c.wait().expect("request completes").text)
        .collect();
    (texts, server.shutdown())
}

/// One single-request completion through a fresh server; returns its text.
fn decode_one(cfg: &Config, model: ServedModel, mode: DecodeMode, max_new: usize) -> String {
    let server = Server::start_with(
        cfg.clone(),
        model,
        ServerOptions {
            decode: mode,
            ..Default::default()
        },
    );
    let resp = server
        .submit(
            "the cat",
            GenParams {
                max_new_tokens: max_new,
                temperature: 0.0,
                ..Default::default()
            },
        )
        .expect("queue has room")
        .wait()
        .expect("request completes");
    server.shutdown();
    resp.text
}

fn main() {
    let cfg = Config::builtin("tiny").unwrap();
    let params = init_params(&cfg, &mut Rng::new(1));
    let blocks: Vec<_> = (0..cfg.n_layers)
        .map(|i| exact_factors(&cfg, &params, i))
        .collect();
    let prompts = bench_prompts(16, 5);

    // cache-exactness smoke: cached and recompute greedy decodes must
    // agree exactly before their speeds are compared
    let cached = decode_one(&cfg, ServedModel::Dense(params.clone()), DecodeMode::Cached, 64);
    let recomputed = decode_one(
        &cfg,
        ServedModel::Dense(params.clone()),
        DecodeMode::Recompute,
        64,
    );
    assert_eq!(
        cached, recomputed,
        "cached decode diverged from the full-prefix recompute oracle"
    );

    let mut b = Bench::new();
    b.min_iters = 3;
    b.max_iters = 6;
    type ModelFactory = Box<dyn Fn() -> ServedModel>;
    let variants: Vec<(&str, ModelFactory)> = vec![
        (
            "dense",
            Box::new({
                let p = params.clone();
                move || ServedModel::Dense(p.clone())
            }),
        ),
        (
            "lowrank",
            Box::new({
                let p = params.clone();
                let bl = blocks.clone();
                move || ServedModel::Compressed(p.clone(), bl.clone())
            }),
        ),
    ];
    for (label, make_model) in variants {
        b.run(
            &format!("serve[{label}] 16 reqs x 8 toks (closed loop)"),
            Some(16.0 * 8.0),
            || {
                let server = Server::start(cfg.clone(), make_model());
                let completions: Vec<_> = prompts
                    .iter()
                    .map(|p| {
                        server
                            .submit(
                                p,
                                GenParams {
                                    max_new_tokens: 8,
                                    temperature: 0.0,
                                    ..Default::default()
                                },
                            )
                            .expect("closed loop stays under max_queue")
                    })
                    .collect();
                for c in completions {
                    c.wait().unwrap();
                }
                let m = server.shutdown();
                std::hint::black_box(m);
            },
        );
    }

    // decode-throughput rows (the CI gate): one request, 256 new tokens.
    // Recompute re-runs the whole prefix per token — the pre-KV-cache
    // path — so it pays O(len²) attention per step where cached pays
    // O(len); CI gates cached at >= 3x recompute throughput.
    b.min_iters = 2;
    b.max_iters = 3;
    b.warmup = 1;
    for (label, mode) in [
        ("cached", DecodeMode::Cached),
        ("recompute", DecodeMode::Recompute),
    ] {
        let p = params.clone();
        b.run(
            &format!("decode[dense {label}] 1 req x {DECODE_TOKENS} toks"),
            Some(DECODE_TOKENS as f64),
            || {
                let text = decode_one(&cfg, ServedModel::Dense(p.clone()), mode, DECODE_TOKENS);
                std::hint::black_box(text);
            },
        );
    }

    // paged-KV prefix-reuse rows (the third CI gate): 8 requests sharing
    // a 64-token (4-block) prefix through the paged dense backend, with
    // the radix prefix cache on vs off. work_per_iter is the *measured*
    // prefill token count per round — the cache-on row must show >= 3x
    // fewer prefill tokens (it skips the shared span's forward passes);
    // CI gates on the saved work_per_iter ratio, not wall time. Before
    // timing: all three variants (plain dense, paged+cache, paged
    // cache-off) must produce identical tokens — prefix reuse is only a
    // win if it is bitwise invisible.
    {
        let pk = |prefix_cache| PagedKvOptions {
            blocks: 128,
            block_tokens: 16,
            prefix_cache,
        };
        let (plain_texts, _) = prefix_round(&cfg, ServedModel::Dense(params.clone()), None);
        let (on_texts, on_m) =
            prefix_round(&cfg, ServedModel::Dense(params.clone()), Some(pk(true)));
        let (off_texts, off_m) =
            prefix_round(&cfg, ServedModel::Dense(params.clone()), Some(pk(false)));
        assert_eq!(
            plain_texts, on_texts,
            "paged decode with prefix sharing diverged from dense decode"
        );
        assert_eq!(
            plain_texts, off_texts,
            "paged decode (cache off) diverged from dense decode"
        );
        assert!(
            on_m.prefill_tokens * 3 <= off_m.prefill_tokens,
            "prefix cache saved too little prefill: {} on vs {} off",
            on_m.prefill_tokens,
            off_m.prefill_tokens
        );
        assert_eq!(on_m.kv_blocks_leaked, 0, "paged round leaked blocks");
        for (label, prefix_cache, prefill_tokens) in [
            ("prefix_on", true, on_m.prefill_tokens),
            ("prefix_off", false, off_m.prefill_tokens),
        ] {
            let p = params.clone();
            b.run(
                &format!("serve_paged[dense {label}] B=8 shared64"),
                Some(prefill_tokens as f64),
                || {
                    let (texts, m) =
                        prefix_round(&cfg, ServedModel::Dense(p.clone()), Some(pk(prefix_cache)));
                    assert_eq!(m.prefill_tokens, prefill_tokens, "prefill work drifted");
                    std::hint::black_box(texts);
                },
            );
        }
    }

    // batched-vs-sequential decode rows (the second CI gate): B sessions
    // on the builtin "small" config, advanced BATCH_TOKENS steps either
    // by a per-session decode_step loop or by one stacked decode_batch
    // per tick. The "small" config is large enough that the stacked pass
    // dominates pool dispatch; CI gates batched (t=4) >= 2x sequential
    // aggregate throughput at B = 8.
    let small = Config::builtin("small").unwrap();
    let small_params = init_params(&small, &mut Rng::new(7));

    // row-equality smoke: every batched row must match its sequential
    // decode_step twin bitwise before the two paths' speeds are compared
    {
        let mut be_batch = DenseBackend::new(small.clone(), small_params.clone());
        let mut be_seq = DenseBackend::new(small.clone(), small_params.clone());
        let mut batched = batch_sessions(&mut be_batch, 8);
        let mut solo = batch_sessions(&mut be_seq, 8);
        for step in 0..8usize {
            let toks: Vec<i32> = (0..8).map(|r| batch_token(r, step)).collect();
            let rows = Pool::exact(4).install(|| {
                let mut refs: Vec<&mut Session> = batched.iter_mut().collect();
                be_batch.decode_batch(&mut refs, &toks)
            });
            for (r, row) in rows.into_iter().enumerate() {
                let row = row.expect("batched row succeeds");
                let want = be_seq.decode_step(&mut solo[r], toks[r]).unwrap();
                assert!(
                    row.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "decode_batch row {r} diverged from decode_step at step {step}"
                );
            }
        }
    }

    for rows in [1usize, 4, 8] {
        let mut be = DenseBackend::new(small.clone(), small_params.clone());
        b.run(
            &format!("decode_seq[small] B={rows} x {BATCH_TOKENS} toks"),
            Some((rows * BATCH_TOKENS) as f64),
            || {
                let mut sessions = batch_sessions(&mut be, rows);
                for step in 0..BATCH_TOKENS {
                    for (r, session) in sessions.iter_mut().enumerate() {
                        let logits = be.decode_step(session, batch_token(r, step)).unwrap();
                        std::hint::black_box(&logits);
                    }
                }
            },
        );
    }
    for (rows, threads) in [(1usize, 4usize), (4, 4), (8, 1), (8, 4)] {
        let mut be = DenseBackend::new(small.clone(), small_params.clone());
        let pool = Pool::exact(threads);
        b.run(
            &format!("decode_batch[small] B={rows} t={threads} x {BATCH_TOKENS} toks"),
            Some((rows * BATCH_TOKENS) as f64),
            || {
                pool.install(|| {
                    let mut sessions = batch_sessions(&mut be, rows);
                    for step in 0..BATCH_TOKENS {
                        let toks: Vec<i32> = (0..rows).map(|r| batch_token(r, step)).collect();
                        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                        let out = be.decode_batch(&mut refs, &toks);
                        std::hint::black_box(&out);
                    }
                });
            },
        );
    }
    // quantized-vs-lowrank batched decode rows (the fourth CI gate): the
    // same B=8 t=4 stacked-decode workload through the f32 low-rank
    // backend and the fused int8 backend built from the same exact
    // factors. Before timing: (a) each backend's decode_batch rows must
    // match its own decode_step bitwise (the row contract, per backend);
    // (b) the int8 model's artifact-free perplexity on the tiny config
    // must land within 10% of the f32 low-rank model's — throughput
    // means nothing if the quantized model decodes garbage. CI gates
    // quant >= 1.0x lowrank throughput: the fused kernels touch 4x
    // fewer factor bytes, so they must not lose to the f32 path they
    // replace.
    {
        // perplexity-delta ceiling, artifact-free on the tiny config
        let qtiny: Vec<_> = blocks
            .iter()
            .map(|bf| QuantBlockFactors::from_block(&cfg, bf).expect("exact factors are finite"))
            .collect();
        let corpus = Corpus::generate(Domain::Wiki, 20_000, 9);
        let ppl_batches: Vec<_> = Batcher::new(cfg.batch, cfg.seq).sequential(&corpus.valid, 2);
        let lr_ppl = lowrank_ppl(&cfg, &params, &blocks, &ppl_batches);
        let q_ppl = quant_ppl(&cfg, &params, &qtiny, &ppl_batches);
        assert!(
            (q_ppl - lr_ppl).abs() <= 0.10 * lr_ppl,
            "quantized ppl {q_ppl} drifted beyond 10% of lowrank ppl {lr_ppl}"
        );

        let small_blocks: Vec<_> = (0..small.n_layers)
            .map(|i| exact_factors(&small, &small_params, i))
            .collect();
        let small_q: Vec<_> = small_blocks
            .iter()
            .map(|bf| QuantBlockFactors::from_block(&small, bf).expect("exact factors are finite"))
            .collect();
        type BackendFactory = Box<dyn Fn() -> Box<dyn ModelBackend>>;
        let backends: Vec<(&str, BackendFactory)> = vec![
            (
                "lowrank",
                Box::new({
                    let (c, p, bl) = (small.clone(), small_params.clone(), small_blocks.clone());
                    move || {
                        Box::new(
                            CompressedBackend::new(c.clone(), p.clone(), bl.clone())
                                .expect("block count matches"),
                        )
                    }
                }),
            ),
            (
                "quant",
                Box::new({
                    let (c, p, bl) = (small.clone(), small_params.clone(), small_q.clone());
                    move || {
                        Box::new(
                            QuantizedBackend::new(c.clone(), p.clone(), bl.clone())
                                .expect("block count matches"),
                        )
                    }
                }),
            ),
        ];
        for (label, make) in backends {
            let mut be_batch = make();
            let mut be_seq = make();
            assert_batch_rows_match(be_batch.as_mut(), be_seq.as_mut(), label);

            let mut be = make();
            let pool = Pool::exact(4);
            b.run(
                &format!("decode_batch[small {label}] B=8 t=4 x {BATCH_TOKENS} toks"),
                Some((8 * BATCH_TOKENS) as f64),
                || {
                    pool.install(|| {
                        let mut sessions = batch_sessions(be.as_mut(), 8);
                        for step in 0..BATCH_TOKENS {
                            let toks: Vec<i32> =
                                (0..8).map(|r| batch_token(r, step)).collect();
                            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                            let out = be.decode_batch(&mut refs, &toks);
                            std::hint::black_box(&out);
                        }
                    });
                },
            );
        }
    }

    // HTTP front-door parse row: request-head scan + parse cost per
    // request, measured off the wire path. This is the per-connection
    // fixed overhead the front door adds before a request reaches the
    // engine; it is reported for tracking, not gated.
    {
        const PARSES: usize = 10_000;
        let head = b"POST /v1/completions HTTP/1.1\r\nhost: localhost\r\ncontent-type: application/json\r\ncontent-length: 64\r\naccept: text/event-stream\r\n\r\n";
        let limits = Limits::default();
        b.min_iters = 3;
        b.max_iters = 6;
        b.run(
            &format!("http[parse_head] {PARSES} heads"),
            Some(PARSES as f64),
            || {
                for _ in 0..PARSES {
                    let end = find_head_end(head).expect("terminator present");
                    let parsed = parse_head(&head[..end], &limits).expect("well-formed head");
                    std::hint::black_box(&parsed);
                }
            },
        );
    }
    b.save("serving");
}
