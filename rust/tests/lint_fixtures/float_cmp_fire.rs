// aasvd-lint: path=src/eval/fixture.rs

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}
