//! Test utilities: approximate assertions + randomized property checks.

pub mod approx;
pub mod prop;
