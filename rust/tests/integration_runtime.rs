//! Integration: the pure-Rust reference transformer must match the AOT HLO
//! artifacts executed through PJRT — this pins down every numeric
//! convention (RoPE interleave, norm eps, mask value, layout order) across
//! the Rust/JAX boundary.
//!
//! Requires `make artifacts` (tiny config). Artifact-dependent tests no-op
//! if artifacts are missing so `cargo test` stays green on a fresh
//! checkout; the serving test runs everywhere (serving decodes through
//! the KV-cached pure-Rust forward).

use aasvd::model::forward::{block_forward, model_forward, model_nll};
use aasvd::model::init::init_params;
use aasvd::model::lowrank::{block_lr_forward, concat_factors, exact_factors};
use aasvd::model::Config;
use aasvd::runtime::{Engine, Value};
use aasvd::serve::{Event, GenParams, ServedModel, Server};
use aasvd::testkit::approx::rel_err;
use aasvd::util::rng::Rng;

fn engine() -> Option<Engine> {
    Engine::new("artifacts").ok().filter(|e| e.entry("tiny").is_ok())
}

fn tiny() -> Config {
    Config::builtin("tiny").unwrap()
}

#[test]
fn model_fwd_artifact_matches_reference() {
    let Some(eng) = engine() else { return };
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(42));
    let (b, t) = (cfg.batch, cfg.seq);
    let mut rng = Rng::new(7);
    let tokens: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
    let tokens_i32: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();

    let out = eng
        .run(
            "tiny",
            "model_fwd",
            &[Value::F32(&params.data), Value::I32(&tokens_i32)],
        )
        .unwrap();
    let reference = model_forward(&cfg, &params, &tokens, t);
    let err = rel_err(&out[0].f32, &reference);
    assert!(err < 2e-2, "model_fwd rel err {err}");
}

#[test]
fn model_nll_artifact_matches_reference() {
    let Some(eng) = engine() else { return };
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(43));
    let (b, t) = (cfg.batch, cfg.seq);
    let mut rng = Rng::new(8);
    let tokens: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
    let targets: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
    let ti: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
    let yi: Vec<i32> = targets.iter().map(|&x| x as i32).collect();

    let out = eng
        .run(
            "tiny",
            "model_nll",
            &[Value::F32(&params.data), Value::I32(&ti), Value::I32(&yi)],
        )
        .unwrap();
    let reference = model_nll(&cfg, &params, &tokens, &targets, t);
    let err = rel_err(&out[0].f32, &reference);
    assert!(err < 2e-4, "model_nll rel err {err}");
}

#[test]
fn block_collect_artifact_matches_reference_taps() {
    let Some(eng) = engine() else { return };
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(44));
    let entry = eng.entry("tiny").unwrap();
    // pack block 1's params into the bare-name block layout
    let bl = entry.block_param_layout.clone();
    let mut bp = vec![0f32; bl.total];
    for e in &bl.entries {
        let src = params.view(&format!("blocks.1.{}", e.name));
        let size: usize = e.shape.iter().product();
        bp[e.offset..e.offset + size].copy_from_slice(src);
    }
    let (b, t) = (cfg.batch, cfg.seq);
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..b * t * cfg.d_model).map(|_| rng.normal() * 0.5).collect();

    let out = eng
        .run("tiny", "block_collect", &[Value::F32(&bp), Value::F32(&x)])
        .unwrap();
    assert_eq!(out.len(), 5);
    let taps = block_forward(&cfg, &params, "blocks.1.", &x, t);
    for (got, want, name) in [
        (&out[0].f32, &taps.y, "y"),
        (&out[1].f32, &taps.a_in, "a_in"),
        (&out[2].f32, &taps.o_in, "o_in"),
        (&out[3].f32, &taps.m_in, "m_in"),
        (&out[4].f32, &taps.d_in, "d_in"),
    ] {
        // tolerance note: with random init the attention output (o_in) is
        // near zero-mean (softmax ≈ uniform), so f32 accumulation noise is
        // large *relative* to its norm. A convention mismatch (RoPE order,
        // mask, eps) produces rel err ≈ O(1), far above this bound.
        let err = rel_err(got, want);
        assert!(err < 5e-2, "{name} rel err {err}");
    }
}

#[test]
fn block_lr_artifact_matches_reference() {
    let Some(eng) = engine() else { return };
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(45));
    let bf = exact_factors(&cfg, &params, 0);
    let (b, t) = (cfg.batch, cfg.seq);
    let mut rng = Rng::new(10);
    let x: Vec<f32> = (0..b * t * cfg.d_model).map(|_| rng.normal() * 0.5).collect();

    let out = eng
        .run(
            "tiny",
            "block_lr_fwd",
            &[
                Value::F32(&bf.factors.data),
                Value::F32(&bf.masks.data),
                Value::F32(&x),
            ],
        )
        .unwrap();
    let reference = block_lr_forward(&cfg, &bf, &x, t);
    let err = rel_err(&out[0].f32, &reference.y);
    assert!(err < 2e-3, "block_lr_fwd rel err {err}");
}

#[test]
fn model_lr_nll_artifact_matches_reference() {
    let Some(eng) = engine() else { return };
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(46));
    let blocks: Vec<_> = (0..cfg.n_layers)
        .map(|i| exact_factors(&cfg, &params, i))
        .collect();
    let (fs, ms) = concat_factors(&blocks);
    let (b, t) = (cfg.batch, cfg.seq);
    let mut rng = Rng::new(11);
    let tokens: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
    let targets: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
    let ti: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
    let yi: Vec<i32> = targets.iter().map(|&x| x as i32).collect();

    let out = eng
        .run(
            "tiny",
            "model_lr_nll",
            &[
                Value::F32(&params.data),
                Value::F32(&fs),
                Value::F32(&ms),
                Value::I32(&ti),
                Value::I32(&yi),
            ],
        )
        .unwrap();
    // with exact full-rank factors, the compressed model IS the dense model
    let reference = model_nll(&cfg, &params, &tokens, &targets, t);
    let err = rel_err(&out[0].f32, &reference);
    assert!(err < 5e-4, "model_lr_nll rel err {err}");
}

#[test]
fn refine_step_artifact_decreases_loss() {
    let Some(eng) = engine() else { return };
    let cfg = tiny();
    let entry = eng.entry("tiny").unwrap();
    let fsize = entry.factor_layout.total;
    let msize = entry.mask_layout.total;
    let mut rng = Rng::new(47);
    let mut train: Vec<f32> = (0..fsize).map(|_| rng.normal() * 0.05).collect();
    let mut m = vec![0f32; fsize];
    let mut v = vec![0f32; fsize];
    let masks = vec![1f32; msize];
    let (br, t, d) = (cfg.refine_batch, cfg.seq, cfg.d_model);
    let x: Vec<f32> = (0..br * t * d).map(|_| rng.normal() * 0.5).collect();
    let y: Vec<f32> = (0..br * t * d).map(|_| rng.normal() * 0.1).collect();

    let mut losses = Vec::new();
    for step in 0..20 {
        let out = eng
            .run(
                "tiny",
                "refine_step",
                &[
                    Value::F32(&train),
                    Value::F32(&m),
                    Value::F32(&v),
                    Value::ScalarI32(step),
                    Value::ScalarF32(1e-2),
                    Value::F32(&masks),
                    Value::F32(&x),
                    Value::F32(&y),
                ],
            )
            .unwrap();
        train = out[0].f32.clone();
        m = out[1].f32.clone();
        v = out[2].f32.clone();
        losses.push(out[3].f32[0]);
    }
    assert!(
        losses[19] < losses[0] * 0.8,
        "refine losses: {:?} -> {:?}",
        losses[0],
        losses[19]
    );
}

#[test]
fn train_step_artifact_decreases_loss() {
    let Some(eng) = engine() else { return };
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(48));
    let mut p = params.data.clone();
    let n = p.len();
    let mut m = vec![0f32; n];
    let mut v = vec![0f32; n];
    let mut rng = Rng::new(12);
    let (tb, t) = (cfg.train_batch, cfg.seq);
    let tokens: Vec<i32> = (0..tb * t).map(|_| rng.below(cfg.vocab) as i32).collect();
    let targets: Vec<i32> = tokens
        .iter()
        .map(|&x| ((x as usize + 1) % cfg.vocab) as i32)
        .collect();
    let mut losses = Vec::new();
    for step in 0..15 {
        let out = eng
            .run(
                "tiny",
                "train_step",
                &[
                    Value::F32(&p),
                    Value::F32(&m),
                    Value::F32(&v),
                    Value::ScalarI32(step),
                    Value::ScalarF32(3e-3),
                    Value::I32(&tokens),
                    Value::I32(&targets),
                ],
            )
            .unwrap();
        p = out[0].f32.clone();
        m = out[1].f32.clone();
        v = out[2].f32.clone();
        losses.push(out[3].f32[0]);
    }
    assert!(losses[14] < losses[0], "losses {losses:?}");
}

/// The serving client surface over the real model backends: tokens stream
/// before Done on both the dense and the low-rank KV-cached path. (Since
/// the serving layer decodes through the pure-Rust cached forward, this
/// runs without artifacts.)
#[test]
fn serving_streams_tokens_via_model_backends() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(50));
    let blocks: Vec<_> = (0..cfg.n_layers)
        .map(|i| exact_factors(&cfg, &params, i))
        .collect();
    for model in [
        ServedModel::Dense(params.clone()),
        ServedModel::Compressed(params.clone(), blocks),
    ] {
        let server = Server::start(cfg.clone(), model);
        let completion = server
            .submit(
                "the cat",
                GenParams {
                    max_new_tokens: 4,
                    ..Default::default()
                },
            )
            .unwrap();
        let mut tokens_seen = 0;
        let resp = loop {
            match completion.next_event() {
                Some(Event::Token(t)) => {
                    assert_eq!(t.index, tokens_seen, "stream order");
                    tokens_seen += 1;
                }
                Some(Event::Done(resp)) => break resp,
                other => panic!("unexpected event {other:?}"),
            }
        };
        assert_eq!(tokens_seen, 4, "all tokens streamed before Done");
        assert_eq!(resp.tokens_generated, 4);
        assert!(resp.ttft <= resp.latency);
        drop(completion);
        let metrics = server.shutdown();
        assert_eq!(metrics.tokens, 4);
    }
}

#[test]
fn pallas_lowrank_apply_matches_rust() {
    let Some(eng) = engine() else { return };
    let cfg = tiny();
    let entry = eng.entry("tiny").unwrap();
    let spec = entry.artifact("lowrank_apply").unwrap().clone();
    let (d, kq) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let l = spec.inputs[2].shape[0];
    assert_eq!(d, cfg.d_model);
    let mut rng = Rng::new(13);
    let u: Vec<f32> = (0..d * kq).map(|_| rng.normal()).collect();
    let v: Vec<f32> = (0..d * kq).map(|_| rng.normal()).collect();
    let x: Vec<f32> = (0..l * d).map(|_| rng.normal()).collect();
    let out = eng
        .run(
            "tiny",
            "lowrank_apply",
            &[Value::F32(&u), Value::F32(&v), Value::F32(&x)],
        )
        .unwrap();
    // reference: y = (x V) U^T
    let mut want = vec![0f32; l * d];
    for r in 0..l {
        let xr = &x[r * d..(r + 1) * d];
        let mut z = vec![0f32; kq];
        for i in 0..d {
            for p in 0..kq {
                z[p] += xr[i] * v[i * kq + p];
            }
        }
        for mrow in 0..d {
            let urow = &u[mrow * kq..(mrow + 1) * kq];
            want[r * d + mrow] = z.iter().zip(urow).map(|(a, b)| a * b).sum();
        }
    }
    let err = rel_err(&out[0].f32, &want);
    assert!(err < 5e-4, "pallas lowrank rel err {err}");
}
