//! Artifact manifests: the load-only AOT manifest and the read/write
//! compress-run checkpoint manifest.
//!
//! [`Manifest`] is parsed from artifacts/manifest.json (written by
//! python/compile/aot.py) and is the single source of truth for artifact
//! signatures and flat-tensor layouts; the Rust builtin configs are
//! validated against it.
//!
//! [`RunManifest`] is the versioned `run.json` a streaming compress run
//! (`compress/run.rs`) keeps next to its per-block shards: one
//! [`BlockEntry`] per layer with a status and content hashes, updated
//! atomically after each durable step so an interrupted run — kill -9
//! included — resumes at the last completed block.

use crate::model::config::Config;
use crate::model::params::Layout;
use crate::util::hash::{from_hex, to_hex};
use crate::util::io::write_bytes_atomic;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            _ => bail!("unsupported dtype '{s}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub config: Config,
    pub cov_chunk: usize,
    pub param_layout: Layout,
    pub block_param_layout: Layout,
    pub factor_layout: Layout,
    pub mask_layout: Layout,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigEntry>,
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .context("expected spec array")?
        .iter()
        .map(|s| {
            Ok(TensorSpec {
                shape: s
                    .req("shape")
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("non-integer shape dim"))
                    .collect::<Result<_>>()?,
                dtype: DType::parse(s.req("dtype").as_str().context("dtype")?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        // surface the offending file and byte position, JsonScan-style
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("corrupt manifest {}: {e}", path.display()))?;
        let mut configs = BTreeMap::new();
        for (name, entry) in j.req("configs").as_obj().context("configs")? {
            let dims = entry.req("dims");
            let config = Config::from_manifest(name, dims);
            // consistency: builtin config (if present) must agree
            if let Some(builtin) = Config::builtin(name) {
                if builtin != config {
                    bail!(
                        "config '{name}' in manifest disagrees with builtin; \
                         re-run `make artifacts`"
                    );
                }
            }
            let mut artifacts = BTreeMap::new();
            for (aname, a) in entry.req("artifacts").as_obj().context("artifacts")? {
                artifacts.insert(
                    aname.clone(),
                    ArtifactSpec {
                        name: aname.clone(),
                        file: dir.join(a.req("file").as_str().context("file")?),
                        inputs: parse_specs(a.req("inputs"))?,
                        outputs: parse_specs(a.req("outputs"))?,
                    },
                );
            }
            configs.insert(
                name.clone(),
                ConfigEntry {
                    cov_chunk: dims
                        .get("cov_chunk")
                        .and_then(|v| v.as_usize())
                        .with_context(|| {
                            format!("config '{name}': dims.cov_chunk missing or not an integer")
                        })?,
                    param_layout: Layout::from_manifest(entry.req("param_layout")),
                    // python emits block tensors as "blocks.0.<name>"; the
                    // rust block store uses bare names
                    block_param_layout: {
                        let lay = Layout::from_manifest(entry.req("block_param_layout"));
                        Layout::new(
                            lay.entries
                                .into_iter()
                                .map(|e| {
                                    let bare = e
                                        .name
                                        .strip_prefix("blocks.0.")
                                        .unwrap_or(&e.name)
                                        .to_string();
                                    (bare, e.shape)
                                })
                                .collect(),
                        )
                    },
                    factor_layout: Layout::from_manifest(entry.req("factor_layout")),
                    mask_layout: Layout::from_manifest(entry.req("mask_layout")),
                    config,
                    artifacts,
                },
            );
        }
        Ok(Manifest { dir, configs })
    }

    pub fn entry(&self, config: &str) -> Result<&ConfigEntry> {
        self.configs.get(config).with_context(|| {
            format!(
                "config '{config}' not in manifest (have: {:?}) — \
                 run `make artifacts CONFIGS={config}`",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }
}

impl ConfigEntry {
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!("artifact '{name}' missing for config '{}'", self.config.name)
        })
    }
}

// ---------------------------------------------------------------------------
// Compress-run checkpoint manifest
// ---------------------------------------------------------------------------

/// Format version of `run.json`. Bumped when the schema changes; a
/// mismatched file refuses to resume rather than misinterpreting state.
pub const RUN_MANIFEST_VERSION: u64 = 1;

/// Lifecycle of one block in a streaming compress run.
///
/// `Solved` is transient: factors exist in memory but the shard is not
/// durable yet, so resume treats it as unwritten and re-solves the block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockStatus {
    Pending,
    Solved,
    Written,
}

impl BlockStatus {
    pub fn name(&self) -> &'static str {
        match self {
            BlockStatus::Pending => "pending",
            BlockStatus::Solved => "solved",
            BlockStatus::Written => "written",
        }
    }

    pub fn parse(s: &str) -> Result<BlockStatus> {
        match s {
            "pending" => Ok(BlockStatus::Pending),
            "solved" => Ok(BlockStatus::Solved),
            "written" => Ok(BlockStatus::Written),
            _ => bail!("unknown block status '{s}'"),
        }
    }
}

/// Checkpoint record for one block: where its factor shard landed, the
/// content hash that guards it, and (for all but the last block) the
/// activation-stream snapshot the *next* block resumes from. File names
/// are relative to the run directory — never absolute — so manifests are
/// bitwise comparable across machines and working directories.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockEntry {
    pub status: BlockStatus,
    pub shard: Option<String>,
    pub shard_hash: Option<u64>,
    pub state: Option<String>,
    pub state_hash: Option<u64>,
}

impl BlockEntry {
    pub fn pending() -> BlockEntry {
        BlockEntry {
            status: BlockStatus::Pending,
            shard: None,
            shard_hash: None,
            state: None,
            state_hash: None,
        }
    }

    pub fn solved() -> BlockEntry {
        BlockEntry {
            status: BlockStatus::Solved,
            ..BlockEntry::pending()
        }
    }

    pub fn written(
        shard: String,
        shard_hash: u64,
        state: Option<String>,
        state_hash: Option<u64>,
    ) -> BlockEntry {
        BlockEntry {
            status: BlockStatus::Written,
            shard: Some(shard),
            shard_hash: Some(shard_hash),
            state,
            state_hash,
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj().set("status", self.status.name());
        if let Some(s) = &self.shard {
            j = j.set("shard", s.as_str());
        }
        if let Some(h) = self.shard_hash {
            j = j.set("shard_hash", to_hex(h).as_str());
        }
        if let Some(s) = &self.state {
            j = j.set("state", s.as_str());
        }
        if let Some(h) = self.state_hash {
            j = j.set("state_hash", to_hex(h).as_str());
        }
        j
    }

    fn from_json(j: &Json, block: usize) -> Result<BlockEntry> {
        let status = j
            .get("status")
            .and_then(Json::as_str)
            .with_context(|| format!("block {block}: missing 'status'"))?;
        let hex = |key: &str| -> Result<Option<u64>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => {
                    let s = v
                        .as_str()
                        .with_context(|| format!("block {block}: '{key}' not a string"))?;
                    Ok(Some(from_hex(s).with_context(|| {
                        format!("block {block}: '{key}' is not a 16-digit hex hash")
                    })?))
                }
            }
        };
        Ok(BlockEntry {
            status: BlockStatus::parse(status)
                .with_context(|| format!("block {block}"))?,
            shard: j.get("shard").and_then(Json::as_str).map(str::to_string),
            shard_hash: hex("shard_hash")?,
            state: j.get("state").and_then(Json::as_str).map(str::to_string),
            state_hash: hex("state_hash")?,
        })
    }
}

/// The `run.json` a [`CompressRun`](crate::compress::CompressRun) keeps in
/// its run directory: run identity (config/method/ratio plus an input
/// fingerprint) and one [`BlockEntry`] per layer. Contains no wall times,
/// thread counts, or absolute paths — by design, so the manifest of a
/// resumed run is bitwise identical to that of an uninterrupted one and
/// stable across thread counts.
#[derive(Clone, Debug, PartialEq)]
pub struct RunManifest {
    pub version: u64,
    pub config: String,
    pub method: String,
    pub ratio: f64,
    /// FNV-1a 64 over every input that determines the output bits
    /// (config dims, method knobs, ranks, calibration tokens, weights —
    /// thread count deliberately excluded). A resume whose inputs hash
    /// differently is refused.
    pub fingerprint: u64,
    pub complete: bool,
    pub artifact_hash: Option<u64>,
    pub blocks: Vec<BlockEntry>,
}

impl RunManifest {
    pub fn new(
        config: &str,
        method: &str,
        ratio: f64,
        n_layers: usize,
        fingerprint: u64,
    ) -> RunManifest {
        RunManifest {
            version: RUN_MANIFEST_VERSION,
            config: config.to_string(),
            method: method.to_string(),
            ratio,
            fingerprint,
            complete: false,
            artifact_hash: None,
            blocks: vec![BlockEntry::pending(); n_layers],
        }
    }

    /// The resume point: index of the first block without a durable
    /// shard. `None` when every block is written.
    pub fn first_unwritten(&self) -> Option<usize> {
        self.blocks
            .iter()
            .position(|b| b.status != BlockStatus::Written)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("version", self.version as usize)
            .set("config", self.config.as_str())
            .set("method", self.method.as_str())
            .set("ratio", self.ratio)
            .set("fingerprint", to_hex(self.fingerprint).as_str())
            .set("complete", self.complete);
        if let Some(h) = self.artifact_hash {
            j = j.set("artifact_hash", to_hex(h).as_str());
        }
        j.set(
            "blocks",
            Json::Arr(self.blocks.iter().map(BlockEntry::to_json).collect()),
        )
    }

    pub fn from_json(j: &Json) -> Result<RunManifest> {
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .context("missing 'version'")? as u64;
        if version != RUN_MANIFEST_VERSION {
            bail!(
                "run manifest version {version} but this build reads version \
                 {RUN_MANIFEST_VERSION} — finish the run with the build that \
                 started it, or remove the run directory to start over"
            );
        }
        let str_field = |key: &str| -> Result<String> {
            Ok(j.get(key)
                .and_then(Json::as_str)
                .with_context(|| format!("missing '{key}'"))?
                .to_string())
        };
        let fingerprint = str_field("fingerprint")?;
        let blocks = j
            .get("blocks")
            .and_then(Json::as_arr)
            .context("missing 'blocks'")?
            .iter()
            .enumerate()
            .map(|(i, b)| BlockEntry::from_json(b, i))
            .collect::<Result<Vec<_>>>()?;
        Ok(RunManifest {
            version,
            config: str_field("config")?,
            method: str_field("method")?,
            ratio: j
                .get("ratio")
                .and_then(Json::as_f64)
                .context("missing 'ratio'")?,
            fingerprint: from_hex(&fingerprint)
                .context("'fingerprint' is not a 16-digit hex hash")?,
            complete: j
                .get("complete")
                .and_then(Json::as_bool)
                .context("missing 'complete'")?,
            artifact_hash: match j.get("artifact_hash") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .and_then(from_hex)
                        .context("'artifact_hash' is not a 16-digit hex hash")?,
                ),
            },
            blocks,
        })
    }

    /// Atomically persist to `path` (tmp + fsync + rename): a crash mid-
    /// save leaves the previous manifest, never a torn one.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        write_bytes_atomic(path, text.as_bytes())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<RunManifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading run manifest {}", path.display()))?;
        // the JsonError Display carries the byte position
        let j = Json::parse(&text).map_err(|e| {
            anyhow::anyhow!(
                "corrupt compress-run manifest {}: {e} — the file cannot be \
                 trusted for resume; remove the run directory to start over",
                path.display()
            )
        })?;
        Self::from_json(&j)
            .with_context(|| format!("in run manifest {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests here run against the real artifacts when present (CI runs
    /// `make artifacts` first); otherwise they validate error paths.
    fn manifest() -> Option<Manifest> {
        Manifest::load("artifacts").ok()
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load("/nonexistent-path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(m) = manifest() else { return };
        let e = m.entry("tiny").unwrap();
        assert_eq!(e.config.d_model, 64);
        let a = e.artifact("model_fwd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(
            a.outputs[0].shape,
            vec![e.config.batch, e.config.seq, e.config.vocab]
        );
        assert!(a.file.exists());
    }

    #[test]
    fn layouts_match_rust_side() {
        let Some(m) = manifest() else { return };
        let e = m.entry("tiny").unwrap();
        assert_eq!(
            e.param_layout,
            crate::model::params::param_layout(&e.config)
        );
        assert_eq!(
            e.factor_layout,
            crate::model::params::factor_layout(&e.config)
        );
        assert_eq!(e.mask_layout, crate::model::params::mask_layout(&e.config));
        assert_eq!(
            e.block_param_layout,
            crate::model::params::block_param_layout(&e.config)
        );
    }

    #[test]
    fn unknown_names_error() {
        let Some(m) = manifest() else { return };
        assert!(m.entry("no-such-config").is_err());
        assert!(m.entry("tiny").unwrap().artifact("no-such").is_err());
    }

    #[test]
    fn corrupt_aot_manifest_reports_file_and_byte() {
        let dir = std::env::temp_dir().join("aasvd-manifest-tests/corrupt-aot");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"configs\": {").unwrap();
        let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(err.contains("manifest.json"), "{err}");
        assert!(err.contains("byte"), "{err}");
    }

    // ---- run manifest ----------------------------------------------------

    fn sample_run() -> RunManifest {
        let mut m = RunManifest::new("synth", "anchored", 0.6, 3, 0xabcd1234ef567890);
        m.blocks[0] = BlockEntry::written(
            "block_0.aat".to_string(),
            0x1111222233334444,
            Some("state_1.aat".to_string()),
            Some(0x5555666677778888),
        );
        m.blocks[1] = BlockEntry::solved();
        m
    }

    #[test]
    fn run_manifest_roundtrips_and_is_bitwise_stable() {
        let m = sample_run();
        let text = m.to_json().to_string_pretty();
        let back = RunManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(m, back);
        // re-serialization is byte-identical — the property the resume
        // tests lean on when comparing manifests across runs
        assert_eq!(back.to_json().to_string_pretty(), text);
        assert_eq!(m.first_unwritten(), Some(1));
    }

    #[test]
    fn run_manifest_save_load_roundtrip() {
        let dir = std::env::temp_dir().join("aasvd-manifest-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run_roundtrip.json");
        let mut m = sample_run();
        m.complete = true;
        m.artifact_hash = Some(0x9999aaaabbbbcccc);
        m.save(&path).unwrap();
        assert_eq!(RunManifest::load(&path).unwrap(), m);
    }

    #[test]
    fn truncated_run_manifest_refuses_resume_with_position() {
        let dir = std::env::temp_dir().join("aasvd-manifest-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run_truncated.json");
        sample_run().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = format!("{:#}", RunManifest::load(&path).unwrap_err());
        assert!(err.contains("run_truncated.json"), "{err}");
        assert!(err.contains("byte"), "{err}");
        assert!(err.contains("remove the run directory"), "{err}");
    }

    #[test]
    fn wrong_version_refuses_resume() {
        let mut m = sample_run();
        m.version = RUN_MANIFEST_VERSION + 1;
        let text = m.to_json().to_string_pretty();
        let err = format!(
            "{:#}",
            RunManifest::from_json(&Json::parse(&text).unwrap()).unwrap_err()
        );
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn malformed_fields_name_the_key() {
        let good = sample_run().to_json().to_string_pretty();
        for (needle, replacement, want) in [
            ("\"status\": \"solved\"", "\"status\": \"maybe\"", "status"),
            (
                "\"shard_hash\": \"1111222233334444\"",
                "\"shard_hash\": \"zzzz\"",
                "shard_hash",
            ),
            (
                "\"fingerprint\": \"abcd1234ef567890\"",
                "\"fingerprint\": \"nope\"",
                "fingerprint",
            ),
        ] {
            let bad = good.replace(needle, replacement);
            assert_ne!(bad, good, "replacement '{needle}' did not apply");
            let err = format!(
                "{:#}",
                RunManifest::from_json(&Json::parse(&bad).unwrap()).unwrap_err()
            );
            assert!(err.contains(want), "expected '{want}' in: {err}");
        }
    }
}
