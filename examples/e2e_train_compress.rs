//! End-to-end driver (the DESIGN.md mandated validation run): proves all
//! three layers compose on a real small workload.
//!
//!   1. PRETRAIN the base model from scratch on the synthetic corpus by
//!      driving the fused-AdamW `train_step` HLO artifact from Rust,
//!      logging the loss curve (L2+L3).
//!   2. COMPRESS it with SVD-LLM (baseline) and AA-SVD (ours) at 0.8/0.6
//!      via the covariance kernels + closed-form solver + block refinement
//!      (L1+L2+L3).
//!   3. EVALUATE perplexity on three corpora + seven zero-shot tasks, and
//!      SERVE the compressed model with the continuous-batching engine,
//!      reporting latency/throughput.
//!
//! Results land in results/e2e.json and EXPERIMENTS.md quotes the run.

use aasvd::compress::Method;
use aasvd::data::Domain;
use aasvd::eval::{display_ppl, Table};
use aasvd::experiments::{eval_compressed_method, eval_dense, setup, Knobs};
use aasvd::serve::{GenParams, ServedModel, Server, ServerOptions};
use aasvd::util::cli::Args;
use aasvd::util::json::Json;
use anyhow::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let args = Args::parse_env("end-to-end: pretrain -> compress -> eval -> serve");
    let knobs = Knobs::parse(&args, "base");
    let n_requests = args.usize("requests", 24, "serving requests");
    args.finish_or_help();

    // ---- 1. pretrain (or reuse checkpoint) --------------------------------
    let t0 = Instant::now();
    let ctx = setup(&knobs)?; // pretrains if checkpoints/<cfg>.aat is absent
    println!(
        "[e2e] model '{}' ready ({} params) in {:.0}s",
        ctx.cfg.name,
        ctx.params.data.len(),
        t0.elapsed().as_secs_f64()
    );

    // ---- 2+3. compress + evaluate -----------------------------------------
    let mut table = Table::new(
        &format!("E2E — '{}' train→compress→eval", ctx.cfg.name),
        &["ratio", "method", "wiki", "ptb", "c4", "acc"],
    );
    let dense = eval_dense(&ctx)?;
    table.row(vec![
        "1.0".into(),
        "dense".into(),
        display_ppl(dense.ppl_of(Domain::Wiki)),
        display_ppl(dense.ppl_of(Domain::Ptb)),
        display_ppl(dense.ppl_of(Domain::C4)),
        format!("{:.3}", dense.avg_acc),
    ]);
    let mut best_blocks = None;
    let mut rows_json = Vec::new();
    for ratio in [0.8, 0.6] {
        for method in [Method::svd_llm(), Method::aa_svd(knobs.refine())] {
            let (ev, cm) = eval_compressed_method(&ctx, &method, ratio)?;
            table.row(vec![
                format!("{ratio}"),
                ev.method.clone(),
                display_ppl(ev.ppl_of(Domain::Wiki)),
                display_ppl(ev.ppl_of(Domain::Ptb)),
                display_ppl(ev.ppl_of(Domain::C4)),
                format!("{:.3}", ev.avg_acc),
            ]);
            rows_json.push(
                Json::obj()
                    .set("ratio", ratio)
                    .set("method", ev.method.as_str())
                    .set("wiki_ppl", ev.ppl_of(Domain::Wiki))
                    .set("acc", ev.avg_acc)
                    .set("secs", ev.secs),
            );
            if method.name == "aa_svd" && ratio == 0.6 {
                best_blocks = Some(cm.blocks);
            }
        }
    }
    table.emit("e2e")?;

    // ---- 4. serve the compressed model ------------------------------------
    let blocks = best_blocks.expect("aa_svd@0.6 blocks");
    // closed loop submits every request up front: size the admission
    // queue to the request count so none are shed
    let server = Server::start_with(
        ctx.cfg.clone(),
        ServedModel::Compressed(ctx.params.clone(), blocks),
        ServerOptions {
            max_queue: n_requests.max(1),
            ..Default::default()
        },
    );
    let prompts = aasvd::serve::batcher::bench_prompts(n_requests, 7);
    let completions: Vec<_> = prompts
        .iter()
        .map(|p| {
            server
                .submit(
                    p,
                    GenParams {
                        max_new_tokens: 24,
                        temperature: 0.0,
                        ..Default::default()
                    },
                )
                .map_err(|e| anyhow::anyhow!("submit failed: {e}"))
        })
        .collect::<Result<_>>()?;
    for (i, completion) in completions.into_iter().enumerate() {
        let resp = completion
            .wait()
            .map_err(|e| anyhow::anyhow!("request lost: {e}"))?;
        if i < 3 {
            println!("[serve] '{}' -> '{}'", prompts[i], resp.text.trim_end());
        }
    }
    let metrics = server.shutdown();
    println!("[serve] {}", metrics.summary());

    aasvd::util::io::write_text(
        "results/e2e.json",
        &Json::obj()
            .set("rows", Json::Arr(rows_json))
            .set("serve_tokens_per_sec", metrics.tokens_per_sec())
            .set("serve_batch_occupancy", metrics.mean_batch_occupancy())
            .to_string_pretty(),
    )?;
    Ok(())
}
