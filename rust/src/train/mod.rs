//! Pretraining driver (produces the base models the paper compresses).

pub mod pretrain;

pub use pretrain::{load_or_pretrain, pretrain, PretrainOptions, PretrainResult};
