//! Tokenizers: byte-level (default, vocab 256) and a small trained BPE.
//!
//! The model family uses byte-level tokens so the Rust and JAX sides never
//! need to share a vocabulary file; the BPE implementation exists for the
//! tokenizer-ablation example and is fully self-contained.

use std::collections::BTreeMap;

pub trait Tokenizer {
    fn encode(&self, text: &str) -> Vec<u32>;
    fn decode(&self, tokens: &[u32]) -> String;
    fn vocab_size(&self) -> usize;
}

/// Identity byte tokenizer: token = byte value.
#[derive(Clone, Debug, Default)]
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32).collect()
    }

    fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| t as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn vocab_size(&self) -> usize {
        256
    }
}

/// Byte-pair encoding trained greedily on a corpus sample.
#[derive(Clone, Debug)]
pub struct BpeTokenizer {
    /// merge list in training order: (left, right) -> new id
    pub merges: Vec<(u32, u32)>,
    #[allow(dead_code)] // kept for incremental re-training extensions
    merge_index: BTreeMap<(u32, u32), u32>,
    /// id -> byte string
    pieces: Vec<Vec<u8>>,
}

impl BpeTokenizer {
    /// Train `n_merges` merges on `corpus`.
    pub fn train(corpus: &str, n_merges: usize) -> BpeTokenizer {
        let mut pieces: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        let mut merges = Vec::new();
        let mut merge_index = BTreeMap::new();
        let mut seq: Vec<u32> = corpus.bytes().map(|b| b as u32).collect();

        for _ in 0..n_merges {
            // count adjacent pairs
            let mut counts: BTreeMap<(u32, u32), usize> = BTreeMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &cnt)) = counts.iter().max_by_key(|(_, &c)| c) else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let new_id = pieces.len() as u32;
            let mut piece = pieces[pair.0 as usize].clone();
            piece.extend_from_slice(&pieces[pair.1 as usize]);
            pieces.push(piece);
            merges.push(pair);
            merge_index.insert(pair, new_id);
            // apply the merge to the working sequence
            seq = Self::apply_merge(&seq, pair, new_id);
        }
        BpeTokenizer {
            merges,
            merge_index,
            pieces,
        }
    }

    fn apply_merge(seq: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
        let mut out = Vec::with_capacity(seq.len());
        let mut i = 0;
        while i < seq.len() {
            if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                out.push(new_id);
                i += 2;
            } else {
                out.push(seq[i]);
                i += 1;
            }
        }
        out
    }
}

impl Tokenizer for BpeTokenizer {
    fn encode(&self, text: &str) -> Vec<u32> {
        let mut seq: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        // apply merges in training order (classical BPE encode)
        for (i, &pair) in self.merges.iter().enumerate() {
            let new_id = 256 + i as u32;
            if seq.len() < 2 {
                break;
            }
            seq = Self::apply_merge(&seq, pair, new_id);
        }
        seq
    }

    fn decode(&self, tokens: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &t in tokens {
            bytes.extend_from_slice(&self.pieces[t as usize]);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn vocab_size(&self) -> usize {
        self.pieces.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let t = ByteTokenizer;
        let s = "the quick brown fox! 123";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert_eq!(t.vocab_size(), 256);
    }

    #[test]
    fn bpe_roundtrip() {
        let corpus = "the cat sat on the mat. the cat ate the rat. ".repeat(20);
        let t = BpeTokenizer::train(&corpus, 50);
        let s = "the cat sat on the rat";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn bpe_compresses_training_domain() {
        let corpus = "abcabcabcabc ".repeat(50);
        let t = BpeTokenizer::train(&corpus, 30);
        let encoded = t.encode("abcabcabc");
        assert!(encoded.len() < 9, "bpe should shorten: {}", encoded.len());
    }

    #[test]
    fn bpe_handles_unseen_bytes() {
        let t = BpeTokenizer::train("aaaa bbbb", 5);
        let s = "zzz 999 \u{1F600}"; // includes multibyte utf-8
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn bpe_vocab_grows_with_merges() {
        let corpus = "hello world hello world hello world";
        let t = BpeTokenizer::train(corpus, 10);
        assert!(t.vocab_size() > 256);
        assert!(t.vocab_size() <= 266);
    }
}
