//! Serving example: load (or build) a compressed model and serve a Poisson
//! arrival stream of generation requests through the continuous-batching
//! engine, reporting tail latency, throughput, queue pressure and shed
//! load vs the dense model.
//!
//! Demonstrates the full client surface: bounded admission (`Overloaded`
//! submissions are dropped, mirroring a load-shedding frontend), streaming
//! `Completion` handles, per-request deadlines and stop sequences.

use aasvd::compress::{compress_model, Method};
use aasvd::experiments::{setup, Knobs};
use aasvd::serve::batcher::{bench_prompts, poisson_arrivals};
use aasvd::serve::{
    GenParams, ServedModel, Server, ServerOptions, SubmitError, WaitError,
};
use aasvd::util::cli::Args;
use anyhow::Result;
use std::time::{Duration, Instant};

fn drive(server: &Server, n: usize, rate: f64) -> Result<()> {
    let prompts = bench_prompts(n, 11);
    let arrivals = poisson_arrivals(n, rate, 13);
    let start = Instant::now();
    let mut completions = Vec::new();
    let mut shed = 0usize;
    for (p, &at) in prompts.iter().zip(&arrivals) {
        let now = start.elapsed().as_secs_f64();
        if at > now {
            std::thread::sleep(Duration::from_secs_f64(at - now));
        }
        let params = GenParams {
            max_new_tokens: 16,
            temperature: 0.8,
            top_k: Some(32),
            stop_sequences: vec![".".into()],
            deadline: Some(Duration::from_secs(30)),
            ..Default::default()
        };
        match server.submit(p, params) {
            Ok(c) => completions.push(c),
            Err(SubmitError::Overloaded) => shed += 1, // counted in metrics too
            Err(e) => anyhow::bail!("submit failed: {e}"),
        }
    }
    for c in completions {
        match c.wait() {
            Ok(_) | Err(WaitError::Cancelled(_)) => {}
            Err(e) => anyhow::bail!("request lost: {e}"),
        }
    }
    if shed > 0 {
        println!("  shed {shed}/{n} requests at admission");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse_env("serve a compressed model under Poisson load");
    let knobs = Knobs::parse(&args, "small");
    let n = args.usize("requests", 40, "number of requests");
    let rate = args.f64("rate", 8.0, "arrival rate (req/s)");
    let ratio = args.f64("ratio", 0.6, "compression ratio");
    let max_queue = args.usize("max-queue", 32, "admission queue bound");
    args.finish_or_help();

    let ctx = setup(&knobs)?;
    println!("[serve] compressing {} @ {ratio} with aa_svd...", ctx.cfg.name);
    let cm = compress_model(
        &ctx.engine,
        &ctx.cfg,
        &ctx.params,
        &ctx.calib,
        &Method::aa_svd(knobs.refine()),
        ratio,
    )?;

    for (label, model) in [
        ("dense", ServedModel::Dense(ctx.params.clone())),
        (
            "aa_svd",
            ServedModel::Compressed(ctx.params.clone(), cm.blocks.clone()),
        ),
    ] {
        let server = Server::start_with(
            ctx.cfg.clone(),
            model,
            ServerOptions {
                max_queue,
                ..Default::default()
            },
        );
        drive(&server, n, rate)?;
        let metrics = server.shutdown();
        println!("[{label}] {}", metrics.summary());
    }
    Ok(())
}
