//! Structured-pruning baselines for the Table 3/4 comparisons.
//!
//! Paper substitution (DESIGN.md §3): LLM-Pruner / Wanda-sp / SliceGPT /
//! BlockPruner are closed testbeds, so we implement the corresponding
//! mechanism classes in-repo, all budgeted by the same parameter-count
//! accounting used for the SVD methods:
//!  - magnitude channel pruning (LLM-Pruner-like): drop MLP channels and
//!    attention head groups by weight norm,
//!  - activation-aware channel pruning (Wanda-sp-like): importance =
//!    ‖W_col‖ · E[x²]^0.5 from the calibration covariance diagonal,
//!  - PCA slicing (SliceGPT-like): project every block linear onto the top
//!    principal subspace of its calibration inputs,
//!  - block dropping (BlockPruner-like): remove whole transformer blocks.
//!
//! All baselines *materialize modified dense parameters* so the unchanged
//! model_fwd artifact evaluates them.

use super::cov::CovTriple;
use super::pipeline::{collect_dense_taps_for_pruning, embed_batches, Collector};
use crate::data::TokenBatch;
use crate::linalg::{eigh_with, Matrix};
use crate::model::{Config, FlatStore};
use crate::util::pool::Pool;
use anyhow::Result;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PruneMethod {
    Magnitude,  // LLM-Pruner-like
    WandaSp,    // activation-aware
    SliceGpt,   // PCA slicing
    BlockDrop,  // BlockPruner-like
}

pub const ALL_PRUNERS: [PruneMethod; 4] = [
    PruneMethod::Magnitude,
    PruneMethod::WandaSp,
    PruneMethod::SliceGpt,
    PruneMethod::BlockDrop,
];

impl PruneMethod {
    pub fn name(&self) -> &'static str {
        match self {
            PruneMethod::Magnitude => "llm_pruner",
            PruneMethod::WandaSp => "wanda_sp",
            PruneMethod::SliceGpt => "slicegpt",
            PruneMethod::BlockDrop => "blockpruner",
        }
    }

    pub fn needs_activations(&self) -> bool {
        matches!(self, PruneMethod::WandaSp | PruneMethod::SliceGpt)
    }
}

/// Result: modified dense parameters + surviving parameter count.
pub struct PrunedModel {
    pub params: FlatStore,
    pub kept_params: f64,
}

/// Prune MLP hidden channels of one block to `keep` of `d_ff`, zeroing the
/// dropped rows of gate/up and columns of down. Importance given per channel.
fn prune_mlp_channels(cfg: &Config, params: &mut FlatStore, block: usize, importance: &[f64], keep: usize) {
    let f = cfg.d_ff;
    let d = cfg.d_model;
    let mut idx: Vec<usize> = (0..f).collect();
    idx.sort_by(|&a, &b| importance[b].total_cmp(&importance[a]));
    let dropped: Vec<usize> = idx[keep..].to_vec();
    for lin in ["w_gate", "w_up"] {
        let w = params.view_mut(&format!("blocks.{block}.{lin}"));
        for &ch in &dropped {
            w[ch * d..(ch + 1) * d].fill(0.0);
        }
    }
    let w = params.view_mut(&format!("blocks.{block}.w_down"));
    for &ch in &dropped {
        for row in 0..d {
            w[row * f + ch] = 0.0;
        }
    }
}

/// Prune attention "channels" (head-dim groups): zero head h entirely in
/// q/k/v rows and wo columns.
fn prune_heads(cfg: &Config, params: &mut FlatStore, block: usize, importance: &[f64], keep: usize) {
    let d = cfg.d_model;
    let hd = cfg.head_dim();
    let mut idx: Vec<usize> = (0..cfg.n_heads).collect();
    idx.sort_by(|&a, &b| importance[b].total_cmp(&importance[a]));
    for &h in &idx[keep..] {
        for lin in ["wq", "wk", "wv"] {
            let w = params.view_mut(&format!("blocks.{block}.{lin}"));
            w[h * hd * d..(h + 1) * hd * d].fill(0.0);
        }
        let w = params.view_mut(&format!("blocks.{block}.wo"));
        for row in 0..d {
            w[row * d + h * hd..row * d + (h + 1) * hd].fill(0.0);
        }
    }
}

/// Weight-norm importance of MLP channels / heads.
fn magnitude_importance(cfg: &Config, params: &FlatStore, block: usize) -> (Vec<f64>, Vec<f64>) {
    let f = cfg.d_ff;
    let d = cfg.d_model;
    let mut mlp = vec![0f64; f];
    for lin in ["w_gate", "w_up"] {
        let w = params.view(&format!("blocks.{block}.{lin}"));
        for ch in 0..f {
            mlp[ch] += w[ch * d..(ch + 1) * d]
                .iter()
                .map(|&x| (x as f64).powi(2))
                // aasvd-lint: allow(float-reduce): sequential per-channel weight-norm in fixed slice order; single-threaded importance scoring
                .sum::<f64>();
        }
    }
    let hd = cfg.head_dim();
    let mut heads = vec![0f64; cfg.n_heads];
    let wv = params.view(&format!("blocks.{block}.wv"));
    for h in 0..cfg.n_heads {
        heads[h] = wv[h * hd * d..(h + 1) * hd * d]
            .iter()
            .map(|&x| (x as f64).powi(2))
            .sum();
    }
    (mlp, heads)
}

/// Prune to parameter ratio `rho` with the chosen method.
pub fn prune_model<C: Collector>(
    collector: &C,
    cfg: &Config,
    params: &FlatStore,
    calib: &[TokenBatch],
    method: PruneMethod,
    rho: f64,
) -> Result<PrunedModel> {
    let mut out = params.clone();
    let dense_block = cfg.block_linear_params() as f64;

    match method {
        PruneMethod::BlockDrop => {
            // drop ceil((1-rho)·L) whole blocks, shallowest-importance =
            // middle blocks first (standard BlockPruner heuristic shape)
            let n_drop = ((1.0 - rho) * cfg.n_layers as f64).round() as usize;
            let order = block_drop_order(cfg.n_layers);
            for &b in order.iter().take(n_drop) {
                // zero wo + w_down -> block output = input (residual pass)
                out.view_mut(&format!("blocks.{b}.wo")).fill(0.0);
                out.view_mut(&format!("blocks.{b}.w_down")).fill(0.0);
            }
            let kept = (cfg.n_layers - n_drop) as f64 * dense_block;
            return Ok(PrunedModel {
                params: out,
                kept_params: kept + fixed_params(cfg),
            });
        }
        _ => {}
    }

    // channel-level methods: split the budget between MLP and attention
    // proportionally to their dense sizes
    let mlp_params = (3 * cfg.d_model * cfg.d_ff) as f64;
    let attn_params = (4 * cfg.d_model * cfg.d_model) as f64;
    let keep_f = ((rho * mlp_params) / (3 * cfg.d_model) as f64).round() as usize;
    let keep_f = keep_f.clamp(1, cfg.d_ff);
    let keep_h = ((rho * attn_params) / (4 * cfg.d_model * cfg.head_dim()) as f64)
        .round() as usize;
    let keep_h = keep_h.clamp(1, cfg.n_heads);

    // activations (for Wanda / SliceGPT)
    let acts = if method.needs_activations() {
        Some(collect_calibration_covs(collector, cfg, params, calib)?)
    } else {
        None
    };
    // worker pool for the per-block eigensolves / projections below
    let pool = Pool::auto();

    for b in 0..cfg.n_layers {
        match method {
            PruneMethod::Magnitude => {
                let (mlp, heads) = magnitude_importance(cfg, params, b);
                prune_mlp_channels(cfg, &mut out, b, &mlp, keep_f);
                prune_heads(cfg, &mut out, b, &heads, keep_h);
            }
            PruneMethod::WandaSp => {
                let (mut mlp, mut heads) = magnitude_importance(cfg, params, b);
                let covs = acts.as_ref().unwrap();
                // scale by input activation energy at the right taps
                let m_scale = covs[b].1.channel_scales(); // m_in tap, dim d
                let d_scale = covs[b].2.channel_scales(); // d_in tap, dim ff
                // gate/up columns see m_in (dim d): use mean energy as a
                // global factor; channel identity lives in d_in for w_down
                let m_mean: f64 =
                    // aasvd-lint: allow(float-reduce): sequential mean over channel scales in fixed order; single-threaded importance scoring
                    m_scale.iter().sum::<f64>() / m_scale.len() as f64;
                for ch in 0..cfg.d_ff {
                    mlp[ch] = mlp[ch] * m_mean + d_scale[ch] * d_scale[ch];
                }
                let a_scale = covs[b].0.channel_scales(); // a_in, dim d
                let hd = cfg.head_dim();
                for h in 0..cfg.n_heads {
                    // aasvd-lint: allow(float-reduce): sequential energy sum in fixed slice order; single-threaded importance scoring
                    let e: f64 = a_scale.iter().map(|s| s * s).sum::<f64>();
                    heads[h] *= e / hd as f64;
                }
                prune_mlp_channels(cfg, &mut out, b, &mlp, keep_f);
                prune_heads(cfg, &mut out, b, &heads, keep_h);
            }
            PruneMethod::SliceGpt => {
                // project q/k/v/gate/up inputs onto top-q eigvecs of the
                // block-input covariance: W <- W P Pᵀ (same storage shape;
                // accounted as q/d of the input dim kept)
                let covs = acts.as_ref().unwrap();
                let q_keep = ((rho * cfg.d_model as f64).round() as usize)
                    .clamp(1, cfg.d_model);
                let (_, qmat) = eigh_with(&covs[b].0.s_orig, &pool);
                let p = qmat.cols_range(0, q_keep); // [d, q]
                let proj = p.matmul_bt_with(&p, &pool); // P Pᵀ [d, d]
                for lin in ["wq", "wk", "wv", "w_gate", "w_up"] {
                    let (m, n) = cfg.linear_dims(lin);
                    let name = format!("blocks.{b}.{lin}");
                    let w = Matrix::from_f32(m, n, params.view(&name));
                    let wp = w.matmul_with(&proj, &pool).to_f32();
                    out.view_mut(&name).copy_from_slice(&wp);
                }
            }
            PruneMethod::BlockDrop => unreachable!(),
        }
    }

    let kept_block = match method {
        PruneMethod::Magnitude | PruneMethod::WandaSp => {
            (3 * keep_f * cfg.d_model + 4 * keep_h * cfg.head_dim() * cfg.d_model) as f64
        }
        PruneMethod::SliceGpt => {
            // sliced inputs: q/d of each projected linear + dense wo/w_down
            let q_keep = ((rho * cfg.d_model as f64).round() as usize)
                .clamp(1, cfg.d_model) as f64;
            let dd = cfg.d_model as f64;
            let ff = cfg.d_ff as f64;
            3.0 * dd * q_keep + 2.0 * ff * q_keep + dd * dd + dd * ff
        }
        PruneMethod::BlockDrop => unreachable!(),
    };
    Ok(PrunedModel {
        params: out,
        kept_params: cfg.n_layers as f64 * kept_block + fixed_params(cfg),
    })
}

fn fixed_params(cfg: &Config) -> f64 {
    (2 * cfg.vocab * cfg.d_model + cfg.d_model + cfg.n_layers * 2 * cfg.d_model) as f64
}

/// Middle-out block drop order (first/last blocks are load-bearing).
fn block_drop_order(n: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (1..n.saturating_sub(1)).collect();
    let mid = (n / 2) as i64;
    order.sort_by_key(|&b| (b as i64 - mid).abs());
    for b in [n - 1, 0] {
        if b < n && !order.contains(&b) {
            order.push(b);
        }
    }
    order
}

/// Per-block (a_in, m_in, d_in) covariance triples on calibration data.
/// Accumulation fans out over the auto-resolved pool; partials merge in
/// batch order so the result is thread-count invariant.
fn collect_calibration_covs<C: Collector>(
    collector: &C,
    cfg: &Config,
    params: &FlatStore,
    calib: &[TokenBatch],
) -> Result<Vec<(CovTriple, CovTriple, CovTriple)>> {
    let xs = embed_batches(cfg, params, calib);
    collect_dense_taps_for_pruning(collector, cfg, params, xs, &Pool::auto())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::util::rng::Rng;

    #[test]
    fn names_and_flags() {
        assert_eq!(PruneMethod::Magnitude.name(), "llm_pruner");
        assert!(PruneMethod::WandaSp.needs_activations());
        assert!(!PruneMethod::BlockDrop.needs_activations());
    }

    #[test]
    fn block_drop_order_prefers_middle() {
        let order = block_drop_order(8);
        assert_eq!(order[0], 4);
        assert!(!order.contains(&0) || order.last() == Some(&0));
    }

    #[test]
    fn magnitude_prune_zeroes_expected_counts() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(1));
        let mut out = params.clone();
        let (mlp, heads) = magnitude_importance(&cfg, &params, 0);
        prune_mlp_channels(&cfg, &mut out, 0, &mlp, cfg.d_ff / 2);
        prune_heads(&cfg, &mut out, 0, &heads, 1);
        // half the gate rows must be zero
        let w = out.view("blocks.0.w_gate");
        let zero_rows = (0..cfg.d_ff)
            .filter(|&ch| {
                w[ch * cfg.d_model..(ch + 1) * cfg.d_model]
                    .iter()
                    .all(|&x| x == 0.0)
            })
            .count();
        assert_eq!(zero_rows, cfg.d_ff - cfg.d_ff / 2);
        // one head left in wv
        let wv = out.view("blocks.0.wv");
        let hd = cfg.head_dim();
        let live_heads = (0..cfg.n_heads)
            .filter(|&h| {
                wv[h * hd * cfg.d_model..(h + 1) * hd * cfg.d_model]
                    .iter()
                    .any(|&x| x != 0.0)
            })
            .count();
        assert_eq!(live_heads, 1);
    }

    #[test]
    fn importance_ordering_respected() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(2));
        let mut out = params.clone();
        // hand importance: keep channels 0 and 1
        let mut imp = vec![0.0; cfg.d_ff];
        imp[0] = 10.0;
        imp[1] = 9.0;
        prune_mlp_channels(&cfg, &mut out, 0, &imp, 2);
        let w = out.view("blocks.0.w_gate");
        assert!(w[..cfg.d_model].iter().any(|&x| x != 0.0));
        assert!(w[2 * cfg.d_model..3 * cfg.d_model].iter().all(|&x| x == 0.0));
    }
}
