//! Serving metrics: latency percentiles, throughput, queue pressure and
//! request-lifecycle counters.
//!
//! TTFT is recorded at true first-token *emission* (the moment the
//! `Event::Token` is sent), not at request completion.

use crate::util::stats::{mean, percentile};

/// A per-tick sample series with bounded memory. The engine pushes one
/// sample per decode iteration, forever — an unbounded `Vec` is a slow
/// memory leak on a long-lived server. `BoundedSeries` keeps every
/// `stride`-th sample; when the retained buffer hits its cap it drops
/// every other retained sample and doubles the stride, so arbitrarily
/// long runs keep an evenly spaced sketch at fixed memory. The running
/// `peak()` and the total sample `count()` are tracked outside the
/// buffer and stay **exact** regardless of decimation.
#[derive(Clone, Debug)]
pub struct BoundedSeries {
    samples: Vec<f64>,
    /// retain every `stride`-th pushed sample
    stride: usize,
    /// pushes to skip before the next retained sample
    skip: usize,
    /// total samples ever pushed (exact)
    count: usize,
    /// exact running maximum over every pushed sample (0.0 floor, like
    /// the nonnegative residency/byte series this tracks)
    peak: f64,
    cap: usize,
}

/// Default retained-sample cap (~32KiB of f64 per series).
const SERIES_CAP: usize = 4096;

impl Default for BoundedSeries {
    fn default() -> Self {
        BoundedSeries::with_cap(SERIES_CAP)
    }
}

impl BoundedSeries {
    pub fn with_cap(cap: usize) -> Self {
        BoundedSeries {
            samples: Vec::new(),
            stride: 1,
            skip: 0,
            count: 0,
            peak: 0.0,
            cap: cap.max(2),
        }
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        if v > self.peak {
            self.peak = v;
        }
        if self.skip > 0 {
            self.skip -= 1;
            return;
        }
        if self.samples.len() >= self.cap {
            let mut i = 0usize;
            self.samples.retain(|_| {
                let keep = i % 2 == 0;
                i += 1;
                keep
            });
            self.stride *= 2;
        }
        self.samples.push(v);
        self.skip = self.stride - 1;
    }

    /// Exact maximum over every sample ever pushed (0.0 when empty).
    pub fn peak(&self) -> f64 {
        self.peak
    }

    /// Exact number of samples ever pushed.
    pub fn count(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The retained (possibly decimated) sketch, in push order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub ttfts: Vec<f64>,
    pub latencies: Vec<f64>,
    pub tokens: usize,
    pub wall_secs: f64,
    pub batch_sizes: Vec<f64>,
    /// admission-queue depth sampled once per decode iteration
    pub queue_depths: Vec<f64>,
    /// submissions refused with `SubmitError::Overloaded`
    pub rejected: usize,
    /// requests retired before completion (client cancel, dropped handle,
    /// or deadline)
    pub cancelled: usize,
    /// subset of `cancelled` retired because their deadline expired
    pub deadline_expired: usize,
    /// prompt tokens absorbed at admission (prefill passes)
    pub prefill_tokens: usize,
    /// tokens absorbed one-at-a-time after prefill (cached decode steps,
    /// or oracle recomputes in `DecodeMode::Recompute`)
    pub decode_tokens: usize,
    /// KV-cache bytes resident across all live sessions, sampled once per
    /// decode iteration (all zeros in `DecodeMode::Recompute`); bounded
    /// by decimation, with `peak_cache_bytes()` exact
    pub cache_bytes: BoundedSeries,
    /// KV pool blocks resident, sampled once per decode iteration (empty
    /// unless paged KV is active)
    pub kv_blocks_in_use: BoundedSeries,
    /// KV pool block budget (0 = paged KV inactive; gates the kv summary)
    pub kv_blocks_capacity: usize,
    /// high-water mark of pool residency over the run (exact)
    pub kv_peak_blocks: usize,
    /// blocks still resident after drain + prefix-cache reset — with no
    /// live sessions this must be 0; anything else is a block leak
    pub kv_blocks_leaked: usize,
    /// prefix nodes evicted to reclaim blocks under pool pressure
    pub kv_evictions: u64,
    /// requests retired with `CancelReason::KvPressure` (projected block
    /// footprint can never fit the pool)
    pub kv_pressure_rejected: usize,
    /// prefix-cache lookups (one per paged prefill when the cache is on)
    pub prefix_lookups: usize,
    /// subset of `prefix_lookups` that reused at least one cached block
    pub prefix_hits: usize,
    /// prompt positions skipped at prefill via prefix reuse
    pub prefix_tokens_reused: usize,
    /// stacked `decode_batch` calls the engine issued (zero in
    /// `DecodeMode::Recompute`, which advances slots via the oracle)
    pub decode_batches: usize,
    /// rows stacked into each `decode_batch` call — the batch-occupancy
    /// histogram of the batched decode path (one entry per call)
    pub decode_batch_rows: Vec<f64>,
    /// TCP connections the HTTP front door accepted (zero when serving
    /// through the in-process API only)
    pub http_connections: usize,
    /// HTTP responses by status class, as written to the socket
    pub http_2xx: usize,
    pub http_4xx: usize,
    pub http_5xx: usize,
    /// subset of 4xx: requests shed with 429 (connection cap or a full
    /// admission queue mapped from `SubmitError::Overloaded`)
    pub http_429: usize,
    /// subset of 4xx: 408s (slow-loris reads past the read timeout, or a
    /// request deadline expiring before the first token)
    pub http_408: usize,
    /// streams abandoned by the client mid-response (nginx-style 499
    /// accounting — nothing useful can be written to a dead socket)
    pub http_499: usize,
    /// request bytes read / response bytes written at the socket
    pub http_bytes_in: usize,
    pub http_bytes_out: usize,
    /// per-request TTFT measured at the socket: request receipt to the
    /// first SSE token event hitting the wire
    pub http_ttfts: Vec<f64>,
}

impl ServeMetrics {
    pub fn record(&mut self, ttft: f64, latency: f64, tokens: usize) {
        self.ttfts.push(ttft);
        self.latencies.push(latency);
        self.tokens += tokens;
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.wall_secs.max(1e-9)
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        mean(&self.batch_sizes)
    }

    pub fn mean_queue_depth(&self) -> f64 {
        mean(&self.queue_depths)
    }

    /// Peak KV-cache residency over the run (0.0 when nothing was
    /// cached). Exact even after the series decimates.
    pub fn peak_cache_bytes(&self) -> f64 {
        self.cache_bytes.peak()
    }

    /// Fraction of prefix-cache lookups that reused cached blocks (0.0
    /// with no lookups).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Mean rows per stacked `decode_batch` call (0.0 with no calls).
    pub fn mean_decode_batch_rows(&self) -> f64 {
        if self.decode_batch_rows.is_empty() {
            0.0
        } else {
            mean(&self.decode_batch_rows)
        }
    }

    /// Batch-occupancy histogram of the batched decode path:
    /// `(rows_in_batch, call_count)` pairs, ascending by batch size.
    pub fn decode_batch_histogram(&self) -> Vec<(usize, usize)> {
        let mut counts = std::collections::BTreeMap::new();
        for &rows in &self.decode_batch_rows {
            *counts.entry(rows as usize).or_insert(0usize) += 1;
        }
        counts.into_iter().collect()
    }

    pub fn summary(&self) -> String {
        // with zero completed requests every latency statistic is
        // meaningless — print n/a rather than 0ms (or NaN)
        let ms = |xs: &[f64], q: f64| -> String {
            if xs.is_empty() {
                "n/a".into()
            } else {
                format!("{:.0}ms", 1e3 * percentile(xs, q))
            }
        };
        let occ = if self.batch_sizes.is_empty() {
            "n/a".into()
        } else {
            format!("{:.2}", self.mean_batch_occupancy())
        };
        let tput = if self.latencies.is_empty() {
            String::from("n/a")
        } else {
            format!("{:.1} tok/s", self.tokens_per_sec())
        };
        let requests = self.latencies.len();
        let tp50 = ms(&self.ttfts, 50.0);
        let tp95 = ms(&self.ttfts, 95.0);
        let lp50 = ms(&self.latencies, 50.0);
        let lp95 = ms(&self.latencies, 95.0);
        let qm = if self.queue_depths.is_empty() {
            String::from("n/a")
        } else {
            format!("{:.2}", self.mean_queue_depth())
        };
        let kv = if self.cache_bytes.is_empty() {
            String::from("n/a")
        } else {
            format!("{:.1}KiB", self.peak_cache_bytes() / 1024.0)
        };
        let batch_rows = if self.decode_batch_rows.is_empty() {
            String::from("n/a")
        } else {
            format!("{:.2}", self.mean_decode_batch_rows())
        };
        let mut s = format!(
            "requests={requests} rejected={} cancelled={} (deadline={}) tokens={} \
             prefill_toks={} decode_toks={} decode_batches={} batch_rows={batch_rows} \
             throughput={tput} ttft p50={tp50} p95={tp95} \
             latency p50={lp50} p95={lp95} batch_occ={occ} queue_mean={qm} \
             kv_peak={kv}",
            self.rejected,
            self.cancelled,
            self.deadline_expired,
            self.tokens,
            self.prefill_tokens,
            self.decode_tokens,
            self.decode_batches,
        );
        // the kv line only exists when paged KV was configured, so dense
        // per-session runs keep the historical summary
        if self.kv_blocks_capacity > 0 {
            let hit_rate = if self.prefix_lookups == 0 {
                String::from("n/a")
            } else {
                format!("{:.0}%", 100.0 * self.prefix_hit_rate())
            };
            s.push_str(&format!(
                " | kv: blocks_peak={}/{} leaked={} evictions={} pressure_rejected={} \
                 prefix_hits={}/{} ({hit_rate}) prefill_saved={}",
                self.kv_peak_blocks,
                self.kv_blocks_capacity,
                self.kv_blocks_leaked,
                self.kv_evictions,
                self.kv_pressure_rejected,
                self.prefix_hits,
                self.prefix_lookups,
                self.prefix_tokens_reused,
            ));
        }
        // the HTTP line only exists when a front door actually served
        // traffic, so in-process-only runs keep the historical summary
        if self.http_connections > 0 {
            s.push_str(&format!(
                " | http: conns={} 2xx={} 4xx={} 5xx={} (429={} 408={} 499={}) \
                 in={}B out={}B ttft p50={} p95={}",
                self.http_connections,
                self.http_2xx,
                self.http_4xx,
                self.http_5xx,
                self.http_429,
                self.http_408,
                self.http_499,
                self.http_bytes_in,
                self.http_bytes_out,
                ms(&self.http_ttfts, 50.0),
                ms(&self.http_ttfts, 95.0),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = ServeMetrics::default();
        m.record(0.1, 0.5, 10);
        m.record(0.2, 0.6, 20);
        m.wall_secs = 3.0;
        assert!((m.tokens_per_sec() - 10.0).abs() < 1e-9);
        assert!(m.summary().contains("requests=2"));
    }

    #[test]
    fn empty_summary_prints_na_not_nan() {
        let m = ServeMetrics::default();
        let s = m.summary();
        assert!(s.contains("requests=0"), "{s}");
        assert!(s.contains("n/a"), "{s}");
        assert!(!s.contains("NaN"), "{s}");
    }

    #[test]
    fn lifecycle_counters_surface_in_summary() {
        let m = ServeMetrics {
            rejected: 3,
            cancelled: 2,
            deadline_expired: 1,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("rejected=3"), "{s}");
        assert!(s.contains("cancelled=2"), "{s}");
        assert!(s.contains("deadline=1"), "{s}");
    }

    #[test]
    fn decode_batch_occupancy_histogram_and_summary() {
        let m = ServeMetrics {
            decode_batches: 5,
            decode_batch_rows: vec![1.0, 4.0, 4.0, 8.0, 4.0],
            ..Default::default()
        };
        assert!((m.mean_decode_batch_rows() - 4.2).abs() < 1e-9);
        assert_eq!(m.decode_batch_histogram(), vec![(1, 1), (4, 3), (8, 1)]);
        let s = m.summary();
        assert!(s.contains("decode_batches=5"), "{s}");
        assert!(s.contains("batch_rows=4.20"), "{s}");
        // and with no batched calls the field degrades to n/a, not NaN
        let empty = ServeMetrics::default();
        assert_eq!(empty.mean_decode_batch_rows(), 0.0);
        assert!(empty.decode_batch_histogram().is_empty());
        assert!(empty.summary().contains("batch_rows=n/a"));
    }

    #[test]
    fn http_counters_surface_only_when_the_front_door_served() {
        // in-process-only runs: no http line at all
        let quiet = ServeMetrics::default();
        assert!(!quiet.summary().contains("http:"), "{}", quiet.summary());
        let m = ServeMetrics {
            http_connections: 7,
            http_2xx: 5,
            http_4xx: 2,
            http_429: 1,
            http_408: 1,
            http_bytes_in: 640,
            http_bytes_out: 1280,
            http_ttfts: vec![0.010, 0.020, 0.030],
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("http: conns=7"), "{s}");
        assert!(s.contains("2xx=5"), "{s}");
        assert!(s.contains("429=1"), "{s}");
        assert!(s.contains("in=640B out=1280B"), "{s}");
        assert!(!s.contains("NaN"), "{s}");
    }

    #[test]
    fn prefill_decode_and_cache_counters_surface_in_summary() {
        let mut m = ServeMetrics {
            prefill_tokens: 12,
            decode_tokens: 34,
            ..Default::default()
        };
        for v in [1024.0, 4096.0, 2048.0] {
            m.cache_bytes.push(v);
        }
        assert!((m.peak_cache_bytes() - 4096.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("prefill_toks=12"), "{s}");
        assert!(s.contains("decode_toks=34"), "{s}");
        assert!(s.contains("kv_peak=4.0KiB"), "{s}");
    }

    #[test]
    fn bounded_series_stays_bounded_with_exact_peak_and_count() {
        let mut s = BoundedSeries::with_cap(8);
        for i in 0..10_000usize {
            // peak lands mid-run, between retained strides
            let v = if i == 7_321 { 1e9 } else { (i % 97) as f64 };
            s.push(v);
        }
        assert_eq!(s.count(), 10_000);
        assert!(s.samples().len() <= 8, "retained {} > cap", s.samples().len());
        assert!((s.peak() - 1e9).abs() < 1e-9, "peak must survive decimation");
        assert!(!s.is_empty());
        let empty = BoundedSeries::default();
        assert!(empty.is_empty());
        assert_eq!(empty.peak(), 0.0);
    }

    #[test]
    fn bounded_series_keeps_an_evenly_spaced_sketch() {
        let mut s = BoundedSeries::with_cap(4);
        for i in 0..16 {
            s.push(i as f64);
        }
        // retained samples stay in push order and start at the first push
        let kept = s.samples();
        assert_eq!(kept.first(), Some(&0.0));
        assert!(kept.windows(2).all(|w| w[0] < w[1]), "{kept:?}");
    }

    #[test]
    fn kv_counters_surface_only_when_paged() {
        let quiet = ServeMetrics::default();
        assert!(!quiet.summary().contains("| kv:"), "{}", quiet.summary());
        let m = ServeMetrics {
            kv_blocks_capacity: 64,
            kv_peak_blocks: 48,
            kv_blocks_leaked: 0,
            kv_evictions: 3,
            kv_pressure_rejected: 2,
            prefix_lookups: 10,
            prefix_hits: 7,
            prefix_tokens_reused: 448,
            ..Default::default()
        };
        assert!((m.prefix_hit_rate() - 0.7).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("kv: blocks_peak=48/64"), "{s}");
        assert!(s.contains("leaked=0"), "{s}");
        assert!(s.contains("evictions=3"), "{s}");
        assert!(s.contains("pressure_rejected=2"), "{s}");
        assert!(s.contains("prefix_hits=7/10 (70%)"), "{s}");
        assert!(s.contains("prefill_saved=448"), "{s}");
        assert!(!s.contains("NaN"), "{s}");
    }
}
