//! Request/response types for the serving engine.

use std::sync::mpsc::Sender;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// 0.0 = greedy
    pub temperature: f32,
    /// stop early when this byte is generated (e.g. b'.'), if set
    pub stop_byte: Option<u8>,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_new_tokens: 32,
            temperature: 0.0,
            stop_byte: None,
        }
    }
}

pub struct GenRequest {
    pub id: u64,
    pub prompt: String,
    pub params: GenParams,
    pub submitted: Instant,
    pub respond: Sender<GenResponse>,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub tokens_generated: usize,
    /// seconds from submit to first generated token
    pub ttft: f64,
    /// seconds from submit to completion
    pub latency: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let p = GenParams::default();
        assert_eq!(p.temperature, 0.0);
        assert!(p.max_new_tokens > 0);
        assert!(p.stop_byte.is_none());
    }
}
