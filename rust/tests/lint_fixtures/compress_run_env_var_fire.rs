// aasvd-lint: path=src/compress/run.rs

pub fn resume_dir() -> Option<String> {
    std::env::var("AASVD_RESUME_DIR").ok()
}
