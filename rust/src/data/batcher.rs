//! Batch assembly for training, calibration and evaluation.

use crate::util::rng::Rng;

/// Fixed-shape token batches [B, T] with next-token targets, drawn from a
/// token stream. Pads the final partial batch by repeating earlier windows
/// and reports the number of *real* rows so metrics can mask padding.
pub struct Batcher {
    pub batch: usize,
    pub seq: usize,
}

#[derive(Clone, Debug)]
pub struct TokenBatch {
    pub tokens: Vec<i32>,  // [B * T]
    pub targets: Vec<i32>, // [B * T]
    pub real_rows: usize,
}

impl Batcher {
    pub fn new(batch: usize, seq: usize) -> Batcher {
        Batcher { batch, seq }
    }

    /// Deterministic contiguous windows (for eval / calibration).
    pub fn sequential(&self, stream: &[u32], max_batches: usize) -> Vec<TokenBatch> {
        let windows = super::corpus::Corpus::windows(
            stream,
            self.seq,
            max_batches * self.batch,
        );
        self.pack(windows)
    }

    /// Random windows (for pretraining).
    pub fn random(&self, stream: &[u32], n_batches: usize, rng: &mut Rng) -> Vec<TokenBatch> {
        let mut windows = Vec::with_capacity(n_batches * self.batch);
        let limit = stream.len().saturating_sub(self.seq + 1);
        assert!(limit > 0, "stream shorter than seq");
        for _ in 0..n_batches * self.batch {
            let start = rng.below(limit);
            windows.push((
                stream[start..start + self.seq].to_vec(),
                stream[start + 1..start + self.seq + 1].to_vec(),
            ));
        }
        self.pack(windows)
    }

    fn pack(&self, windows: Vec<(Vec<u32>, Vec<u32>)>) -> Vec<TokenBatch> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < windows.len() {
            let n_real = (windows.len() - i).min(self.batch);
            let mut tokens = Vec::with_capacity(self.batch * self.seq);
            let mut targets = Vec::with_capacity(self.batch * self.seq);
            for row in 0..self.batch {
                // pad by cycling through this batch's real rows
                let (x, y) = &windows[i + row.min(n_real - 1).min(row % n_real)];
                tokens.extend(x.iter().map(|&t| t as i32));
                targets.extend(y.iter().map(|&t| t as i32));
            }
            out.push(TokenBatch {
                tokens,
                targets,
                real_rows: n_real,
            });
            i += n_real;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_batches_cover_stream() {
        let stream: Vec<u32> = (0..1000).map(|i| (i % 200) as u32).collect();
        let b = Batcher::new(4, 16);
        let batches = b.sequential(&stream, 100);
        let total_real: usize = batches.iter().map(|x| x.real_rows).sum();
        assert_eq!(total_real, 1000 / 16 - 1 + 1); // floor((1000-1)/16)=62
        for tb in &batches {
            assert_eq!(tb.tokens.len(), 4 * 16);
            assert_eq!(tb.targets.len(), 4 * 16);
        }
    }

    #[test]
    fn targets_shift_by_one() {
        let stream: Vec<u32> = (0..200).collect();
        let b = Batcher::new(2, 10);
        let batches = b.sequential(&stream, 3);
        let tb = &batches[0];
        for i in 0..9 {
            assert_eq!(tb.tokens[i + 1], tb.targets[i]);
        }
    }

    #[test]
    fn partial_final_batch_pads() {
        let stream: Vec<u32> = (0..50).collect(); // 3 windows of 16
        let b = Batcher::new(4, 16);
        let batches = b.sequential(&stream, 10);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].real_rows, 3);
        assert_eq!(batches[0].tokens.len(), 4 * 16);
    }

    #[test]
    fn random_is_seeded() {
        let stream: Vec<u32> = (0..5000).map(|i| (i * 7 % 250) as u32).collect();
        let b = Batcher::new(4, 32);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = b.random(&stream, 3, &mut r1);
        let c = b.random(&stream, 3, &mut r2);
        assert_eq!(a[0].tokens, c[0].tokens);
        assert_eq!(a.len(), 3);
    }
}
