//! Learning-rate schedule for block refinement: linear warmup + cosine
//! decay (paper §B.2).

#[derive(Clone, Copy, Debug)]
pub struct CosineSchedule {
    pub base_lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub min_lr_frac: f64,
}

impl CosineSchedule {
    pub fn new(base_lr: f64, warmup_steps: usize, total_steps: usize) -> CosineSchedule {
        CosineSchedule {
            base_lr,
            warmup_steps: warmup_steps.min(total_steps),
            total_steps: total_steps.max(1),
            min_lr_frac: 0.05,
        }
    }

    pub fn lr(&self, step: usize) -> f64 {
        if step < self.warmup_steps {
            return self.base_lr * (step + 1) as f64 / self.warmup_steps.max(1) as f64;
        }
        let t = (step - self.warmup_steps) as f64
            / (self.total_steps - self.warmup_steps).max(1) as f64;
        let t = t.min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        self.base_lr * (self.min_lr_frac + (1.0 - self.min_lr_frac) * cos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_to_base() {
        let s = CosineSchedule::new(1e-3, 10, 100);
        assert!(s.lr(0) < 1e-3 * 0.2);
        assert!((s.lr(9) - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn decays_to_min_fraction() {
        let s = CosineSchedule::new(1e-3, 10, 100);
        let end = s.lr(99);
        assert!(end < 1e-4 + 1e-3 * 0.06);
        assert!(end >= 1e-3 * 0.05 - 1e-12);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = CosineSchedule::new(1e-3, 5, 50);
        for i in 5..49 {
            assert!(s.lr(i) >= s.lr(i + 1) - 1e-15);
        }
    }

    #[test]
    fn steps_past_total_are_clamped() {
        let s = CosineSchedule::new(1e-3, 0, 10);
        assert!((s.lr(10_000) - s.lr(10)).abs() < 1e-12);
    }
}
