//! Low-rank (compressed) model representation + pure-Rust reference forward.
//!
//! A compressed block stores, per linear W[m,n], factors U[m,kmax] and
//! V[n,kmax] (kmax = min(m,n)) plus a rank mask of 0/1 entries. Effective
//! weights are W' = (U ⊙ mask) V^T; the padding-to-kmax trick lets a single
//! AOT artifact serve every rank allocation (see python/compile/model.py).

use super::config::{Config, BLOCK_LINEARS};
use super::forward::{
    attention, attention_step, linear, linear_batch, rmsnorm, silu, BlockTaps, KvSeq,
    KvSeqStore,
};
use super::params::{factor_layout, mask_layout, FlatStore};
use crate::util::pool::Pool;

/// One compressed block: trainables + rank masks.
#[derive(Clone, Debug)]
pub struct BlockFactors {
    pub factors: FlatStore, // attn_norm, mlp_norm, {lin}.u, {lin}.v
    pub masks: FlatStore,   // {lin}.mask
}

impl BlockFactors {
    pub fn zeros(cfg: &Config) -> BlockFactors {
        BlockFactors {
            factors: FlatStore::zeros(factor_layout(cfg)),
            masks: FlatStore::zeros(mask_layout(cfg)),
        }
    }

    /// Effective rank (mask support) of a linear.
    pub fn rank(&self, lin: &str) -> usize {
        self.masks
            .view(&format!("{lin}.mask"))
            .iter()
            .filter(|&&m| m != 0.0)
            .count()
    }

    /// Set mask = [1]*k ++ [0]*(kmax-k).
    pub fn set_rank(&mut self, lin: &str, k: usize) {
        let mask = self.masks.view_mut(&format!("{lin}.mask"));
        for (i, v) in mask.iter_mut().enumerate() {
            *v = if i < k { 1.0 } else { 0.0 };
        }
    }

    /// Stored parameter count under the standard (two-factor) scheme,
    /// counting only active ranks: k(m+n) per linear + norm gains.
    pub fn stored_params(&self, cfg: &Config) -> usize {
        let mut total = 2 * cfg.d_model;
        for lin in BLOCK_LINEARS {
            let (m, n) = cfg.linear_dims(lin);
            total += self.rank(lin) * (m + n);
        }
        total
    }

    /// y = (U ⊙ mask) V^T x for one linear; x: [rows, n] -> [rows, m].
    pub fn apply_linear(&self, cfg: &Config, lin: &str, x: &[f32], out: &mut [f32]) {
        let (m, n) = cfg.linear_dims(lin);
        let k = cfg.kmax(lin);
        let u = self.factors.view(&format!("{lin}.u"));
        let v = self.factors.view(&format!("{lin}.v"));
        let mask = self.masks.view(&format!("{lin}.mask"));
        let rows = x.len() / n;
        assert_eq!(out.len(), rows * m);
        // z = x V (V stored [n, k] => z_j = sum_i x_i V[i, j]), then mask,
        // then y = z U^T
        let mut z = vec![0.0f32; rows * k];
        for (xr, zr) in x.chunks_exact(n).zip(z.chunks_exact_mut(k)) {
            for (i, &xv) in xr.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let vrow = &v[i * k..(i + 1) * k];
                for (zv, &vv) in zr.iter_mut().zip(vrow) {
                    *zv += xv * vv;
                }
            }
            for (zv, &mv) in zr.iter_mut().zip(mask) {
                *zv *= mv;
            }
        }
        linear(&z, u, k, m, out);
    }

    /// Materialize the effective dense weight W' = (U ⊙ mask) V^T
    /// (for error profiling / tests).
    pub fn dense_weight(&self, cfg: &Config, lin: &str) -> Vec<f32> {
        let (m, n) = cfg.linear_dims(lin);
        let k = cfg.kmax(lin);
        let u = self.factors.view(&format!("{lin}.u"));
        let v = self.factors.view(&format!("{lin}.v"));
        let mask = self.masks.view(&format!("{lin}.mask"));
        let mut w = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let uv = u[i * k + p] * mask[p];
                if uv == 0.0 {
                    continue;
                }
                for j in 0..n {
                    w[i * n + j] += uv * v[j * k + p];
                }
            }
        }
        w
    }
}

/// Compressed-block forward with taps (X'_j inputs for Algorithm 2).
pub fn block_lr_forward(
    cfg: &Config,
    bf: &BlockFactors,
    x: &[f32],
    t: usize,
) -> BlockTaps {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let rows = x.len() / d;

    let mut a_in = vec![0.0; x.len()];
    rmsnorm(x, bf.factors.view("attn_norm"), d, &mut a_in);

    let mut q = vec![0.0; rows * d];
    let mut k = vec![0.0; rows * d];
    let mut v = vec![0.0; rows * d];
    bf.apply_linear(cfg, "wq", &a_in, &mut q);
    bf.apply_linear(cfg, "wk", &a_in, &mut k);
    bf.apply_linear(cfg, "wv", &a_in, &mut v);
    let o_in = attention(cfg, &mut q, &mut k, &v, t);

    let mut attn_out = vec![0.0; rows * d];
    bf.apply_linear(cfg, "wo", &o_in, &mut attn_out);
    let h: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

    let mut m_in = vec![0.0; h.len()];
    rmsnorm(&h, bf.factors.view("mlp_norm"), d, &mut m_in);
    let mut gate = vec![0.0; rows * f];
    let mut up = vec![0.0; rows * f];
    bf.apply_linear(cfg, "w_gate", &m_in, &mut gate);
    bf.apply_linear(cfg, "w_up", &m_in, &mut up);
    let d_in: Vec<f32> = gate
        .iter()
        .zip(&up)
        .map(|(&gv, &uv)| silu(gv) * uv)
        .collect();
    let mut down = vec![0.0; rows * d];
    bf.apply_linear(cfg, "w_down", &d_in, &mut down);
    let y: Vec<f32> = h.iter().zip(&down).map(|(a, b)| a + b).collect();

    BlockTaps {
        y,
        a_in,
        o_in,
        m_in,
        d_in,
    }
}

/// One-position compressed block step against the layer's KV cache —
/// the low-rank twin of [`crate::model::forward::block_forward_step`],
/// sharing the same cached attention kernel so dense and compressed
/// models decode through one cached path.
pub fn block_lr_forward_step<K: KvSeq>(
    cfg: &Config,
    bf: &BlockFactors,
    layer: &mut K,
    x: &[f32],
) -> Vec<f32> {
    let (d, f) = (cfg.d_model, cfg.d_ff);

    let mut a_in = vec![0.0; d];
    rmsnorm(x, bf.factors.view("attn_norm"), d, &mut a_in);

    let mut q = vec![0.0; d];
    let mut k = vec![0.0; d];
    let mut v = vec![0.0; d];
    bf.apply_linear(cfg, "wq", &a_in, &mut q);
    bf.apply_linear(cfg, "wk", &a_in, &mut k);
    bf.apply_linear(cfg, "wv", &a_in, &mut v);
    let o_in = attention_step(cfg, layer, &mut q, &mut k, &v);

    let mut attn_out = vec![0.0; d];
    bf.apply_linear(cfg, "wo", &o_in, &mut attn_out);
    let h: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

    let mut m_in = vec![0.0; d];
    rmsnorm(&h, bf.factors.view("mlp_norm"), d, &mut m_in);
    let mut gate = vec![0.0; f];
    let mut up = vec![0.0; f];
    bf.apply_linear(cfg, "w_gate", &m_in, &mut gate);
    bf.apply_linear(cfg, "w_up", &m_in, &mut up);
    let d_in: Vec<f32> = gate
        .iter()
        .zip(&up)
        .map(|(&gv, &uv)| silu(gv) * uv)
        .collect();
    let mut down = vec![0.0; d];
    bf.apply_linear(cfg, "w_down", &d_in, &mut down);
    h.iter().zip(&down).map(|(a, b)| a + b).collect()
}

/// Batched one-position compressed block step — the low-rank twin of
/// [`crate::model::forward::block_forward_step_batch`]: the batch is cut
/// into row bands on `pool`, stacked factored projections run through the
/// multi-row [`BlockFactors::apply_linear`] kernel, attention stays a
/// per-session [`attention_step`]. Rows never mix, so each output row is
/// bitwise identical to [`block_lr_forward_step`] at any worker count.
pub fn block_lr_forward_step_batch<K: KvSeq + Send>(
    cfg: &Config,
    bf: &BlockFactors,
    layers: &mut [&mut K],
    x: &[f32],
    pool: &Pool,
) -> Vec<f32> {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let b = layers.len();
    assert_eq!(x.len(), b * d);
    if b == 0 {
        return Vec::new();
    }

    let mut y = vec![0.0f32; b * d];
    let bands = if pool.threads() <= 1 {
        1
    } else {
        pool.threads().min(b)
    };
    let rows_per = b.div_ceil(bands);
    let jobs: Vec<_> = x
        .chunks(rows_per * d)
        .zip(y.chunks_mut(rows_per * d))
        .zip(layers.chunks_mut(rows_per))
        .map(|((xb, yb), lb)| {
            move || {
                let rb = lb.len();
                let mut a_in = vec![0.0; rb * d];
                rmsnorm(xb, bf.factors.view("attn_norm"), d, &mut a_in);

                let mut q = vec![0.0; rb * d];
                let mut k = vec![0.0; rb * d];
                let mut v = vec![0.0; rb * d];
                bf.apply_linear(cfg, "wq", &a_in, &mut q);
                bf.apply_linear(cfg, "wk", &a_in, &mut k);
                bf.apply_linear(cfg, "wv", &a_in, &mut v);

                let mut o_in = vec![0.0; rb * d];
                for (r, layer) in lb.iter_mut().enumerate() {
                    let row = attention_step(
                        cfg,
                        layer,
                        &mut q[r * d..(r + 1) * d],
                        &mut k[r * d..(r + 1) * d],
                        &v[r * d..(r + 1) * d],
                    );
                    o_in[r * d..(r + 1) * d].copy_from_slice(&row);
                }

                let mut attn_out = vec![0.0; rb * d];
                bf.apply_linear(cfg, "wo", &o_in, &mut attn_out);
                let h: Vec<f32> = xb.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

                let mut m_in = vec![0.0; rb * d];
                rmsnorm(&h, bf.factors.view("mlp_norm"), d, &mut m_in);
                let mut gate = vec![0.0; rb * f];
                let mut up = vec![0.0; rb * f];
                bf.apply_linear(cfg, "w_gate", &m_in, &mut gate);
                bf.apply_linear(cfg, "w_up", &m_in, &mut up);
                let d_in: Vec<f32> = gate
                    .iter()
                    .zip(&up)
                    .map(|(&gv, &uv)| silu(gv) * uv)
                    .collect();
                let mut down = vec![0.0; rb * d];
                bf.apply_linear(cfg, "w_down", &d_in, &mut down);
                for (yv, (hv, dv)) in yb.iter_mut().zip(h.iter().zip(&down)) {
                    *yv = hv + dv;
                }
            }
        })
        .collect();
    pool.run(jobs);
    y
}

/// One KV-cached decode step through the compressed model. Bitwise
/// identical to the last row of [`model_lr_forward`] over the same prefix
/// (the cache-exactness contract; enforced by tests/kv_cache.rs).
pub fn model_lr_forward_step<S: KvSeqStore>(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[BlockFactors],
    cache: &mut S,
    token: u32,
) -> Vec<f32> {
    assert_eq!(blocks.len(), cfg.n_layers);
    assert_eq!(cache.n_layers(), cfg.n_layers);
    let d = cfg.d_model;
    let tok = token as usize;
    assert!(tok < cfg.vocab, "token {tok} out of range");
    let embed = params.view("embed");
    let mut x = embed[tok * d..(tok + 1) * d].to_vec();
    for (blk, bf) in blocks.iter().enumerate() {
        x = block_lr_forward_step(cfg, bf, cache.layer_mut(blk), &x);
    }
    cache.advance();
    let mut hn = vec![0.0; d];
    rmsnorm(&x, params.view("final_norm"), d, &mut hn);
    let mut logits = vec![0.0; cfg.vocab];
    linear(&hn, params.view("lm_head"), d, cfg.vocab, &mut logits);
    logits
}

/// Batched KV-cached decode through the compressed model: one stacked
/// [B, d] pass per layer, one logits row per session. Row i is bitwise
/// identical to [`model_lr_forward_step`] on cache i with token i, at any
/// pool width — the low-rank twin of
/// [`crate::model::forward::model_forward_step_batch`].
pub fn model_lr_forward_step_batch<S: KvSeqStore>(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[BlockFactors],
    caches: &mut [&mut S],
    tokens: &[u32],
    pool: &Pool,
) -> Vec<Vec<f32>> {
    assert_eq!(blocks.len(), cfg.n_layers);
    assert_eq!(caches.len(), tokens.len());
    let b = tokens.len();
    if b == 0 {
        return Vec::new();
    }
    for c in caches.iter() {
        assert_eq!(c.n_layers(), cfg.n_layers);
    }
    let d = cfg.d_model;
    let embed = params.view("embed");
    let mut x = vec![0.0f32; b * d];
    for (i, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < cfg.vocab, "token {tok} out of range");
        x[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    for (blk, bf) in blocks.iter().enumerate() {
        let mut layers: Vec<&mut S::Layer> =
            caches.iter_mut().map(|c| c.layer_mut(blk)).collect();
        x = block_lr_forward_step_batch(cfg, bf, &mut layers, &x, pool);
    }
    for c in caches.iter_mut() {
        c.advance();
    }
    let mut hn = vec![0.0; b * d];
    rmsnorm(&x, params.view("final_norm"), d, &mut hn);
    let mut logits = vec![0.0f32; b * cfg.vocab];
    linear_batch(&hn, params.view("lm_head"), d, cfg.vocab, pool, &mut logits);
    logits.chunks_exact(cfg.vocab).map(|r| r.to_vec()).collect()
}

/// Prefill the compressed model: absorb a whole prompt into `cache`,
/// returning the logits row at its last position.
pub fn model_lr_forward_prefill<S: KvSeqStore>(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[BlockFactors],
    cache: &mut S,
    tokens: &[u32],
) -> Vec<f32> {
    assert!(!tokens.is_empty(), "prefill needs at least one token");
    let mut logits = Vec::new();
    for &tok in tokens {
        logits = model_lr_forward_step(cfg, params, blocks, cache, tok);
    }
    logits
}

/// Compressed full-model forward (dense embed/head + low-rank blocks).
pub fn model_lr_forward(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[BlockFactors],
    tokens: &[u32],
    t: usize,
) -> Vec<f32> {
    assert_eq!(blocks.len(), cfg.n_layers);
    let d = cfg.d_model;
    let b = tokens.len() / t;
    let embed = params.view("embed");
    let mut x = vec![0.0f32; b * t * d];
    for (i, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        x[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    for bf in blocks {
        x = block_lr_forward(cfg, bf, &x, t).y;
    }
    let mut hn = vec![0.0; x.len()];
    rmsnorm(&x, params.view("final_norm"), d, &mut hn);
    let mut logits = vec![0.0; b * t * cfg.vocab];
    linear(&hn, params.view("lm_head"), d, cfg.vocab, &mut logits);
    logits
}

/// Concatenate per-block factor (and mask) vectors in block order — the
/// flat inputs of the model_lr_* artifacts.
pub fn concat_factors(blocks: &[BlockFactors]) -> (Vec<f32>, Vec<f32>) {
    let mut fs = Vec::new();
    let mut ms = Vec::new();
    for b in blocks {
        fs.extend_from_slice(&b.factors.data);
        ms.extend_from_slice(&b.masks.data);
    }
    (fs, ms)
}

/// Save compressed blocks to a tensor archive.
pub fn save_blocks(
    blocks: &[BlockFactors],
    path: impl AsRef<std::path::Path>,
) -> anyhow::Result<()> {
    use crate::util::io::{Tensor, TensorArchive};
    let mut arch = TensorArchive::new();
    for (i, b) in blocks.iter().enumerate() {
        arch.insert(
            &format!("blocks.{i}.factors"),
            Tensor::new(vec![b.factors.data.len()], b.factors.data.clone()),
        );
        arch.insert(
            &format!("blocks.{i}.masks"),
            Tensor::new(vec![b.masks.data.len()], b.masks.data.clone()),
        );
    }
    arch.save(path)
}

/// Load compressed blocks saved by `save_blocks`.
pub fn load_blocks(
    cfg: &Config,
    path: impl AsRef<std::path::Path>,
) -> anyhow::Result<Vec<BlockFactors>> {
    use crate::util::io::TensorArchive;
    let arch = TensorArchive::load(path)?;
    let mut out = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let mut bf = BlockFactors::zeros(cfg);
        let f = arch
            .get(&format!("blocks.{i}.factors"))
            .ok_or_else(|| anyhow::anyhow!("missing block {i} factors"))?;
        let m = arch
            .get(&format!("blocks.{i}.masks"))
            .ok_or_else(|| anyhow::anyhow!("missing block {i} masks"))?;
        anyhow::ensure!(f.data.len() == bf.factors.data.len(), "factor size");
        anyhow::ensure!(m.data.len() == bf.masks.data.len(), "mask size");
        bf.factors.data.copy_from_slice(&f.data);
        bf.masks.data.copy_from_slice(&m.data);
        out.push(bf);
    }
    Ok(out)
}

/// Exact full-rank factorization of a dense block (U = W, V = I or
/// U = I, V = W^T) — used to initialize refinement sanity tests.
pub fn exact_factors(cfg: &Config, params: &FlatStore, block: usize) -> BlockFactors {
    let mut bf = BlockFactors::zeros(cfg);
    let prefix = format!("blocks.{block}.");
    bf.factors
        .view_mut("attn_norm")
        .copy_from_slice(params.view(&format!("{prefix}attn_norm")));
    bf.factors
        .view_mut("mlp_norm")
        .copy_from_slice(params.view(&format!("{prefix}mlp_norm")));
    for lin in BLOCK_LINEARS {
        let (m, n) = cfg.linear_dims(lin);
        let k = cfg.kmax(lin);
        let w = params.view(&format!("{prefix}{lin}")).to_vec();
        {
            let u = bf.factors.view_mut(&format!("{lin}.u"));
            if k == n {
                u.copy_from_slice(&w); // U = W [m, n=k]
            } else {
                // k == m: U = I_m
                for i in 0..m {
                    u[i * k + i] = 1.0;
                }
            }
        }
        {
            let v = bf.factors.view_mut(&format!("{lin}.v"));
            if k == n {
                // V = I_n
                for i in 0..n {
                    v[i * k + i] = 1.0;
                }
            } else {
                // V = W^T [n, k=m]
                for i in 0..n {
                    for j in 0..k {
                        v[i * k + j] = w[j * n + i];
                    }
                }
            }
        }
        bf.set_rank(lin, k);
    }
    bf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{block_forward, KvCache};
    use crate::model::init::init_params;
    use crate::testkit::approx::assert_close_f32;
    use crate::util::rng::Rng;

    fn setup() -> (Config, FlatStore) {
        let cfg = Config::builtin("tiny").unwrap();
        let p = init_params(&cfg, &mut Rng::new(11));
        (cfg, p)
    }

    #[test]
    fn exact_factors_match_dense_block() {
        let (cfg, p) = setup();
        let bf = exact_factors(&cfg, &p, 0);
        let t = cfg.seq;
        let mut rng = Rng::new(12);
        let x: Vec<f32> = (0..2 * t * cfg.d_model).map(|_| rng.normal() * 0.5).collect();
        let dense = block_forward(&cfg, &p, "blocks.0.", &x, t);
        let lowr = block_lr_forward(&cfg, &bf, &x, t);
        assert_close_f32(&dense.y, &lowr.y, 2e-4);
        assert_close_f32(&dense.d_in, &lowr.d_in, 2e-4);
    }

    #[test]
    fn dense_weight_matches_apply() {
        let (cfg, p) = setup();
        let bf = exact_factors(&cfg, &p, 1);
        for lin in BLOCK_LINEARS {
            let w = bf.dense_weight(&cfg, lin);
            assert_close_f32(&w, p.view(&format!("blocks.1.{lin}")), 1e-5);
        }
    }

    #[test]
    fn mask_truncates_rank() {
        let (cfg, p) = setup();
        let mut bf = exact_factors(&cfg, &p, 0);
        let lin = "wq";
        let (m, n) = cfg.linear_dims(lin);
        bf.set_rank(lin, 3);
        assert_eq!(bf.rank(lin), 3);
        let w = bf.dense_weight(&cfg, lin);
        // materialized weight must have rank <= 3: check via linalg svd
        let mat = crate::linalg::Matrix::from_f32(m, n, &w);
        let sv = crate::linalg::svd(&mat);
        for &s in sv.s.iter().skip(3) {
            assert!(s < 1e-5 * sv.s[0].max(1e-9), "rank leak: {s}");
        }
    }

    #[test]
    fn stored_params_counts_active_ranks() {
        let (cfg, _) = setup();
        let mut bf = BlockFactors::zeros(&cfg);
        for lin in BLOCK_LINEARS {
            bf.set_rank(lin, 2);
        }
        let expect: usize = 2 * cfg.d_model
            + BLOCK_LINEARS
                .iter()
                .map(|l| {
                    let (m, n) = cfg.linear_dims(l);
                    2 * (m + n)
                })
                .sum::<usize>();
        assert_eq!(bf.stored_params(&cfg), expect);
    }

    #[test]
    fn model_lr_forward_with_exact_factors_matches_dense() {
        let (cfg, p) = setup();
        let blocks: Vec<BlockFactors> =
            (0..cfg.n_layers).map(|i| exact_factors(&cfg, &p, i)).collect();
        let t = cfg.seq;
        let tokens: Vec<u32> = (0..t).map(|i| (i * 7 % cfg.vocab) as u32).collect();
        let dense = crate::model::forward::model_forward(&cfg, &p, &tokens, t);
        let lowr = model_lr_forward(&cfg, &p, &blocks, &tokens, t);
        assert_close_f32(&dense, &lowr, 5e-4);
    }

    #[test]
    fn lr_cached_step_matches_full_forward_bitwise() {
        let (cfg, p) = setup();
        let mut blocks: Vec<BlockFactors> =
            (0..cfg.n_layers).map(|i| exact_factors(&cfg, &p, i)).collect();
        // truncate some ranks so the masked low-rank path is exercised,
        // not just the exact full-rank factorization
        for bf in blocks.iter_mut() {
            bf.set_rank("wq", 5);
            bf.set_rank("w_up", 7);
        }
        let mut rng = Rng::new(18);
        let n = cfg.seq + 4;
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut cache = KvCache::new(cfg.n_layers);
        for (pos, &tok) in tokens.iter().enumerate() {
            let step = model_lr_forward_step(&cfg, &p, &blocks, &mut cache, tok);
            let full = model_lr_forward(&cfg, &p, &blocks, &tokens[..=pos], pos + 1);
            let want = &full[pos * cfg.vocab..];
            for (i, (a, b)) in step.iter().zip(want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "pos {pos} logit {i}: {a} vs {b}");
            }
        }
        assert_eq!(cache.len, n);
    }

    #[test]
    fn lr_batched_step_rows_match_single_steps_bitwise() {
        let (cfg, p) = setup();
        let mut blocks: Vec<BlockFactors> =
            (0..cfg.n_layers).map(|i| exact_factors(&cfg, &p, i)).collect();
        for bf in blocks.iter_mut() {
            bf.set_rank("wv", 4);
            bf.set_rank("w_down", 6);
        }
        let b = 3;
        let prompts: Vec<Vec<u32>> = (0..b)
            .map(|r| (0..2 + r).map(|i| ((i * 23 + r * 5) % cfg.vocab) as u32).collect())
            .collect();
        let mut batched: Vec<KvCache> = prompts
            .iter()
            .map(|pr| {
                let mut c = KvCache::new(cfg.n_layers);
                model_lr_forward_prefill(&cfg, &p, &blocks, &mut c, pr);
                c
            })
            .collect();
        let mut solo = batched.clone();
        let pool = Pool::exact(2);
        for step in 0..3usize {
            let toks: Vec<u32> =
                (0..b).map(|r| ((r * 31 + step * 17) % cfg.vocab) as u32).collect();
            let mut refs: Vec<&mut KvCache> = batched.iter_mut().collect();
            let rows =
                model_lr_forward_step_batch(&cfg, &p, &blocks, &mut refs, &toks, &pool);
            for (r, row) in rows.iter().enumerate() {
                let want = model_lr_forward_step(&cfg, &p, &blocks, &mut solo[r], toks[r]);
                for (i, (a, b_)) in row.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b_.to_bits(),
                        "row {r} step {step} logit {i}: {a} vs {b_}"
                    );
                }
            }
        }
        for (cb, cs) in batched.iter().zip(&solo) {
            assert_eq!(cb.len, cs.len);
            for (lb, ls) in cb.layers.iter().zip(&cs.layers) {
                assert_eq!(lb.k, ls.k);
                assert_eq!(lb.v, ls.v);
            }
        }
    }

    #[test]
    fn lr_prefill_equals_step_loop() {
        let (cfg, p) = setup();
        let blocks: Vec<BlockFactors> =
            (0..cfg.n_layers).map(|i| exact_factors(&cfg, &p, i)).collect();
        let tokens: Vec<u32> = (0..9).map(|i| (i * 11 % cfg.vocab) as u32).collect();
        let mut c1 = KvCache::new(cfg.n_layers);
        let pre = model_lr_forward_prefill(&cfg, &p, &blocks, &mut c1, &tokens);
        let mut c2 = KvCache::new(cfg.n_layers);
        let mut step = Vec::new();
        for &tok in &tokens {
            step = model_lr_forward_step(&cfg, &p, &blocks, &mut c2, tok);
        }
        assert_eq!(pre, step);
        assert_eq!(c1.len, c2.len);
    }

    #[test]
    fn concat_factors_order_and_length() {
        let (cfg, p) = setup();
        let blocks: Vec<BlockFactors> =
            (0..cfg.n_layers).map(|i| exact_factors(&cfg, &p, i)).collect();
        let (fs, ms) = concat_factors(&blocks);
        assert_eq!(fs.len(), cfg.n_layers * blocks[0].factors.data.len());
        assert_eq!(ms.len(), cfg.n_layers * blocks[0].masks.data.len());
        assert_eq!(&fs[..8], &blocks[0].factors.data[..8]);
    }
}
