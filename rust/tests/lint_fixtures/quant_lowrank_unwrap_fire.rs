// aasvd-lint: path=src/model/quant_lowrank.rs

// The int8 artifact decode path sits on the serving boot surface: a
// panic here kills the server at load time instead of surfacing a typed
// error naming the broken tensor. serve-unwrap fires.
pub fn first_scale(scales: &[f32]) -> f32 {
    *scales.first().unwrap()
}
