//! Int8 factor quantization — the storage format behind Dobi-style
//! remapping (paper §B.4, the AA-SVDᵠ rows).
//!
//! We implement the *actual* precision reduction, not just the accounting:
//! factor matrices are quantized symmetrically to int8 with f32 scales,
//! one scale per column per row-group ([`QUANT_GROUP_ROWS`] rows share a
//! scale; short matrices get a single group, so this degrades to plain
//! per-column scaling). Dequantization is exactly `q as f32 * scale`,
//! which the fused serving kernels (`model::forward::qlinear`) reproduce
//! in-register — so "dequantize then multiply" and "multiply fused" are
//! the same f32 sequence, bit for bit.
//!
//! Non-finite input is a typed [`QuantError`], never silent: the
//! saturating `as i8` cast would otherwise map NaN to 0 and corrupt the
//! factors without a trace.

use std::fmt;

/// Rows per scale group: long columns get one scale per
/// `QUANT_GROUP_ROWS` rows so a single outlier only inflates its own
/// group's step size. Matrices with `rows <= QUANT_GROUP_ROWS` keep the
/// historical one-scale-per-column layout.
pub const QUANT_GROUP_ROWS: usize = 256;

/// Typed rejection of non-finite input to quantization.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantError {
    pub row: usize,
    pub col: usize,
    pub value: f32,
}

impl fmt::Display for QuantError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "non-finite value {} at [{}, {}] cannot be int8-quantized",
            self.value, self.row, self.col
        )
    }
}

impl std::error::Error for QuantError {}

/// A symmetric int8 quantized matrix [rows, cols] with per-column,
/// per-row-group f32 scales (`scales` is [n_groups, cols] row-major;
/// matrix row `i` uses scale row `i / group_rows`).
#[derive(Clone, Debug)]
pub struct QuantMatrix {
    pub rows: usize,
    pub cols: usize,
    /// rows covered by one scale group (the last group may be shorter)
    pub group_rows: usize,
    pub data: Vec<i8>,
    /// [n_groups, cols] row-major
    pub scales: Vec<f32>,
}

impl QuantMatrix {
    /// Quantize with the default group policy: one group per
    /// [`QUANT_GROUP_ROWS`] rows (a single group for short matrices).
    pub fn quantize(x: &[f32], rows: usize, cols: usize) -> Result<QuantMatrix, QuantError> {
        Self::quantize_grouped(x, rows, cols, rows.min(QUANT_GROUP_ROWS).max(1))
    }

    /// Quantize with an explicit group height (must match at load time —
    /// the `.aat` serialization records it).
    pub fn quantize_grouped(
        x: &[f32],
        rows: usize,
        cols: usize,
        group_rows: usize,
    ) -> Result<QuantMatrix, QuantError> {
        assert_eq!(x.len(), rows * cols);
        assert!(group_rows >= 1, "group_rows must be positive");
        if rows == 0 || cols == 0 {
            return Ok(QuantMatrix {
                rows,
                cols,
                group_rows,
                data: Vec::new(),
                scales: Vec::new(),
            });
        }
        // reject non-finite input before any arithmetic: the saturating
        // `as i8` cast would silently map NaN to 0
        for (i, xr) in x.chunks_exact(cols).enumerate() {
            for (j, &v) in xr.iter().enumerate() {
                if !v.is_finite() {
                    return Err(QuantError {
                        row: i,
                        col: j,
                        value: v,
                    });
                }
            }
        }
        // scale pass: per-group max|x| per column, row-major over each
        // group with a per-column accumulator row (no idx % cols)
        let n_groups = rows.div_ceil(group_rows);
        let mut scales = vec![0f32; n_groups * cols];
        for (g, rows_chunk) in x.chunks(group_rows * cols).enumerate() {
            let smax = &mut scales[g * cols..(g + 1) * cols];
            for xr in rows_chunk.chunks_exact(cols) {
                for (s, &v) in smax.iter_mut().zip(xr) {
                    *s = s.max(v.abs());
                }
            }
            for s in smax.iter_mut() {
                *s = if *s > 0.0 { *s / 127.0 } else { 1.0 };
            }
        }
        // quantize pass: zip each row with its group's scale row
        let mut data = Vec::with_capacity(rows * cols);
        for (i, xr) in x.chunks_exact(cols).enumerate() {
            let srow = &scales[(i / group_rows) * cols..][..cols];
            for (&v, &s) in xr.iter().zip(srow) {
                data.push((v / s).round().clamp(-127.0, 127.0) as i8);
            }
        }
        Ok(QuantMatrix {
            rows,
            cols,
            group_rows,
            data,
            scales,
        })
    }

    /// Scale groups (rows of `scales`).
    pub fn n_groups(&self) -> usize {
        self.rows.div_ceil(self.group_rows)
    }

    /// The `cols` scales covering matrix row `i` (its group's scale row).
    pub fn scale_row(&self, i: usize) -> &[f32] {
        let g = i / self.group_rows;
        &self.scales[g * self.cols..(g + 1) * self.cols]
    }

    /// Reconstruct f32 values: exactly `q as f32 * scale` per element —
    /// the oracle the fused kernels are bitwise-equal to.
    pub fn dequantize(&self) -> Vec<f32> {
        if self.cols == 0 {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(self.data.len());
        for (i, qr) in self.data.chunks_exact(self.cols).enumerate() {
            let srow = self.scale_row(i);
            for (&q, &s) in qr.iter().zip(srow) {
                out.push(q as f32 * s);
            }
        }
        out
    }

    /// Storage in bytes: 1 byte/entry + 4 bytes per stored scale.
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len()
    }
}

/// Balance per-component column norms between U and V in place:
/// (u_p, v_p) <- (u_p·s, v_p/s) with s = sqrt(‖v_p‖/‖u_p‖), leaving the
/// product U Vᵀ unchanged. The whitening solve (V = R⁻ᵀ V_k) can give tail
/// components tiny u_p but enormous v_p; int8 quantization error is
/// relative *per column*, so an unbalanced pair converts small relative
/// error into large absolute error in W'. This is the √Σ split Dobi-style
/// remapping stores.
pub fn balance_factor_columns(u: &mut [f32], m: usize, v: &mut [f32], n: usize, k: usize) {
    for p in 0..k {
        // aasvd-lint: allow(float-reduce): sequential column-norm in fixed index order; single-threaded, identical on every run
        let nu: f64 = (0..m).map(|i| (u[i * k + p] as f64).powi(2)).sum::<f64>().sqrt();
        // aasvd-lint: allow(float-reduce): sequential column-norm in fixed index order; single-threaded, identical on every run
        let nv: f64 = (0..n).map(|i| (v[i * k + p] as f64).powi(2)).sum::<f64>().sqrt();
        if nu <= 1e-30 || nv <= 1e-30 {
            continue;
        }
        let s = (nv / nu).sqrt() as f32;
        for i in 0..m {
            u[i * k + p] *= s;
        }
        for i in 0..n {
            v[i * k + p] /= s;
        }
    }
}

/// Quantize+dequantize a factor pair in place (simulating int8 storage),
/// returning the round-trip relative error of each factor.
/// Columns are norm-balanced first (see `balance_factor_columns`).
pub fn quantize_factors_inplace(
    u: &mut [f32],
    m: usize,
    v: &mut [f32],
    n: usize,
    k: usize,
) -> Result<(f64, f64), QuantError> {
    balance_factor_columns(u, m, v, n, k);
    let qu = QuantMatrix::quantize(u, m, k)?;
    let qv = QuantMatrix::quantize(v, n, k)?;
    let du = qu.dequantize();
    let dv = qv.dequantize();
    let eu = rel(u, &du);
    let ev = rel(v, &dv);
    u.copy_from_slice(&du);
    v.copy_from_slice(&dv);
    Ok((eu, ev))
}

fn rel(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0f64;
    let mut den = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (x as f64).powi(2);
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_within_8bit_bound() {
        let mut rng = Rng::new(1);
        let (rows, cols) = (64, 16);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let q = QuantMatrix::quantize(&x, rows, cols).unwrap();
        assert_eq!(q.n_groups(), 1, "64 rows fit one scale group");
        let d = q.dequantize();
        // max error per entry <= scale/2
        for i in 0..rows {
            let srow = q.scale_row(i);
            for j in 0..cols {
                let err = (x[i * cols + j] - d[i * cols + j]).abs();
                assert!(err <= srow[j] * 0.5 + 1e-7);
            }
        }
        assert!(rel(&x, &d) < 0.01, "rel {}", rel(&x, &d));
    }

    #[test]
    fn zero_matrix_safe() {
        let x = vec![0f32; 12];
        let q = QuantMatrix::quantize(&x, 3, 4).unwrap();
        assert_eq!(q.dequantize(), x);
    }

    #[test]
    fn per_column_scales_adapt() {
        // column 1 is 100x column 0: per-column scaling keeps both accurate
        let x = vec![0.01f32, 1.0, -0.02, 2.0, 0.015, -1.5];
        let q = QuantMatrix::quantize(&x, 3, 2).unwrap();
        let d = q.dequantize();
        assert!(rel(&x, &d) < 0.01);
    }

    #[test]
    fn bytes_accounting() {
        let q = QuantMatrix::quantize(&[1.0; 50], 10, 5).unwrap();
        assert_eq!(q.bytes(), 50 + 20);
    }

    #[test]
    fn non_finite_input_is_a_typed_error() {
        let mut x = vec![1.0f32; 12];
        x[7] = f32::NAN; // row 1, col 3 of a [3, 4]
        let err = QuantMatrix::quantize(&x, 3, 4).unwrap_err();
        assert_eq!((err.row, err.col), (1, 3));
        assert!(err.value.is_nan());
        assert!(err.to_string().contains("non-finite"));
        x[7] = f32::INFINITY;
        assert!(QuantMatrix::quantize(&x, 3, 4).is_err());
        // the in-place factor path surfaces the same error
        let mut u = vec![1.0f32; 8];
        let mut v = vec![f32::NEG_INFINITY; 8];
        assert!(quantize_factors_inplace(&mut u, 4, &mut v, 4, 2).is_err());
    }

    #[test]
    fn long_columns_get_grouped_scales() {
        let (rows, cols) = (600, 3);
        // magnitude jumps 100x past row 255: group scales keep the small
        // region accurate where a single column scale could not
        let x: Vec<f32> = (0..rows * cols)
            .map(|idx| {
                let i = idx / cols;
                let base = 0.01 + (idx % 7) as f32 * 0.003;
                if i >= QUANT_GROUP_ROWS {
                    base * 100.0
                } else {
                    base
                }
            })
            .collect();
        let q = QuantMatrix::quantize(&x, rows, cols).unwrap();
        assert_eq!(q.group_rows, QUANT_GROUP_ROWS);
        assert_eq!(q.n_groups(), 3);
        assert_eq!(q.scales.len(), 3 * cols);
        assert_eq!(q.bytes(), rows * cols + 4 * 3 * cols);
        let d = q.dequantize();
        assert!(rel(&x, &d) < 0.01, "rel {}", rel(&x, &d));
        // the first group's scale reflects the small region only
        assert!(q.scale_row(0)[0] < q.scale_row(QUANT_GROUP_ROWS)[0] / 50.0);
        // a forced single group is legal but coarser on the small rows
        let single = QuantMatrix::quantize_grouped(&x, rows, cols, rows).unwrap();
        assert_eq!(single.n_groups(), 1);
        let ds = single.dequantize();
        let head = rows.min(QUANT_GROUP_ROWS) * cols;
        assert!(rel(&x[..head], &d[..head]) < rel(&x[..head], &ds[..head]));
    }

    #[test]
    fn balancing_preserves_product_and_fixes_quant_damage() {
        let mut rng = Rng::new(3);
        let (m, n, k) = (16, 16, 6);
        // adversarial imbalance: column p has u ~ 1e-3, v ~ 1e3
        let mut u: Vec<f32> = (0..m * k).map(|_| rng.normal() * 1e-3).collect();
        let mut v: Vec<f32> = (0..n * k).map(|_| rng.normal() * 1e3).collect();
        let dense = |u: &[f32], v: &[f32]| -> Vec<f32> {
            let mut w = vec![0f32; m * n];
            for i in 0..m {
                for p in 0..k {
                    for j in 0..n {
                        w[i * n + j] += u[i * k + p] * v[j * k + p];
                    }
                }
            }
            w
        };
        let before = dense(&u, &v);
        balance_factor_columns(&mut u, m, &mut v, n, k);
        let after = dense(&u, &v);
        assert!(rel(&before, &after) < 1e-5, "balance changed the product");
        // per-column norms now equal
        for p in 0..k {
            let nu: f32 = (0..m).map(|i| u[i * k + p] * u[i * k + p]).sum::<f32>().sqrt();
            let nv: f32 = (0..n).map(|i| v[i * k + p] * v[i * k + p]).sum::<f32>().sqrt();
            assert!((nu / nv - 1.0).abs() < 1e-3);
        }
        // quantization after balancing keeps the product accurate
        let (eu, ev) = quantize_factors_inplace(&mut u, m, &mut v, n, k).unwrap();
        assert!(eu < 0.02 && ev < 0.02);
        let quantized = dense(&u, &v);
        assert!(rel(&before, &quantized) < 0.05, "rel {}", rel(&before, &quantized));
    }

    #[test]
    fn inplace_returns_errors() {
        let mut rng = Rng::new(2);
        let (m, n, k) = (20, 30, 8);
        let mut u: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let mut v: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let orig_u = u.clone();
        let (eu, ev) = quantize_factors_inplace(&mut u, m, &mut v, n, k).unwrap();
        assert!(eu > 0.0 && eu < 0.02);
        assert!(ev > 0.0 && ev < 0.02);
        assert_ne!(u, orig_u); // actually changed
    }
}
