//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//!
//! Used for (a) the EVD variant of the whitening factorization L = Q Λ^{1/2}
//! (the SVD-LLM-V2 construction in Appendix A.2) and (b) the Gram-matrix
//! route to the truncated SVD in `svd.rs`.

use super::matrix::Matrix;

/// Eigendecomposition of a symmetric matrix: S = Q diag(λ) Q^T.
/// Returns (eigenvalues descending, Q with matching column order).
pub fn eigh(s: &Matrix) -> (Vec<f64>, Matrix) {
    assert_eq!(s.rows, s.cols, "eigh needs a square matrix");
    let n = s.rows;
    let mut a = s.clone();
    a.symmetrize();
    let mut q = Matrix::identity(n);

    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius mass
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a.get(i, j) * a.get(i, j);
            }
        }
        let diag_scale: f64 = (0..n)
            .map(|i| a.get(i, i) * a.get(i, i))
            .sum::<f64>()
            .max(1e-300);
        if off <= 1e-26 * diag_scale {
            break;
        }
        for p in 0..n {
            for r in (p + 1)..n {
                let apr = a.get(p, r);
                if apr.abs() < 1e-300 {
                    continue;
                }
                let app = a.get(p, p);
                let arr = a.get(r, r);
                // Jacobi rotation: tan via the stable formula
                let tau = (arr - app) / (2.0 * apr);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s_ = t * c;

                // A <- J^T A J (only rows/cols p, r change)
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akr = a.get(k, r);
                    a.set(k, p, c * akp - s_ * akr);
                    a.set(k, r, s_ * akp + c * akr);
                }
                for k in 0..n {
                    let apk = a.get(p, k);
                    let ark = a.get(r, k);
                    a.set(p, k, c * apk - s_ * ark);
                    a.set(r, k, s_ * apk + c * ark);
                }
                // accumulate Q <- Q J
                for k in 0..n {
                    let qkp = q.get(k, p);
                    let qkr = q.get(k, r);
                    q.set(k, p, c * qkp - s_ * qkr);
                    q.set(k, r, s_ * qkp + c * qkr);
                }
            }
        }
    }

    // extract, sort descending
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (a.get(i, i), i)).collect();
    pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    let vals: Vec<f64> = pairs.iter().map(|&(v, _)| v).collect();
    let mut qs = Matrix::zeros(n, n);
    for (newj, &(_, oldj)) in pairs.iter().enumerate() {
        for i in 0..n {
            qs.set(i, newj, q.get(i, oldj));
        }
    }
    (vals, qs)
}

/// Whitening factor L = Q Λ^{1/2} with eigenvalues clamped at `floor·λmax`
/// (rank-deficient-safe EVD alternative to Cholesky; Appendix A.2).
pub fn evd_whitening_factor(s: &Matrix, floor: f64) -> Matrix {
    let n = s.rows;
    let (vals, q) = eigh(s);
    let lmax = vals.first().copied().unwrap_or(1.0).max(1e-300);
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        let lam = vals[j].max(floor * lmax);
        let sq = lam.sqrt();
        for i in 0..n {
            l.set(i, j, q.get(i, j) * sq);
        }
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;
    use crate::util::rng::Rng;

    fn reconstruct(vals: &[f64], q: &Matrix) -> Matrix {
        let n = vals.len();
        let mut lam_qt = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                lam_qt.set(i, j, vals[i] * q.get(j, i));
            }
        }
        q.matmul(&lam_qt)
    }

    #[test]
    fn diag_matrix_eigs() {
        let s = Matrix::from_vec(3, 3, vec![3., 0., 0., 0., 1., 0., 0., 0., 2.]);
        let (vals, _) = eigh(&s);
        assert_close(&vals, &[3.0, 2.0, 1.0], 1e-12);
    }

    #[test]
    fn hand_2x2() {
        // [[2,1],[1,2]] -> eigs 3, 1
        let s = Matrix::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let (vals, q) = eigh(&s);
        assert_close(&vals, &[3.0, 1.0], 1e-12);
        let rec = reconstruct(&vals, &q);
        assert_close(&rec.data, &s.data, 1e-12);
    }

    #[test]
    fn random_spd_reconstructs_and_orthogonal() {
        let mut rng = Rng::new(7);
        for n in [2, 5, 17, 40] {
            let s = Matrix::random_spd(n, &mut rng);
            let (vals, q) = eigh(&s);
            // descending
            for w in vals.windows(2) {
                assert!(w[0] >= w[1] - 1e-9);
            }
            // orthogonal
            let qtq = q.matmul_at(&q);
            assert_close(&qtq.data, &Matrix::identity(n).data, 1e-9);
            // reconstruction
            let rec = reconstruct(&vals, &q);
            let rel = rec.sub(&s).frob_norm() / s.frob_norm();
            assert!(rel < 1e-10, "n={n} rel={rel}");
        }
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(8);
        let n = 12;
        let s = Matrix::random_spd(n, &mut rng);
        let tr: f64 = (0..n).map(|i| s.get(i, i)).sum();
        let (vals, _) = eigh(&s);
        assert!((vals.iter().sum::<f64>() - tr).abs() < 1e-8 * tr.abs());
    }

    #[test]
    fn evd_whitening_factor_reconstructs_pd() {
        let mut rng = Rng::new(9);
        let s = Matrix::random_spd(10, &mut rng);
        let l = evd_whitening_factor(&s, 0.0);
        let rec = l.matmul_bt(&l);
        let rel = rec.sub(&s).frob_norm() / s.frob_norm();
        assert!(rel < 1e-10, "rel={rel}");
    }

    #[test]
    fn evd_whitening_floor_regularizes_singular() {
        let x = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let s = x.matmul_bt(&x); // rank 1
        let l = evd_whitening_factor(&s, 1e-6);
        // L must be invertible: all columns have nonzero norm
        for j in 0..3 {
            let norm: f64 = (0..3).map(|i| l.get(i, j) * l.get(i, j)).sum();
            assert!(norm > 0.0);
        }
    }
}
