//! Binary tensor archive: the on-disk format for model weights, optimizer
//! state and cached activations ("`.aat`" — AA-SVD tensors).
//!
//! Version 1 layout (little-endian, f32-only):
//!   magic  b"AAT1"
//!   u32    n_tensors
//!   per tensor:
//!     u32        name_len, name bytes (utf-8)
//!     u32        n_dims,  u64 dims[n_dims]
//!     u64        data_len (f32 count), f32 data[data_len]
//!
//! Version 2 (b"AAT2") adds one dtype byte per record, right after the
//! name (0 = f32, 1 = i8), so quantized artifacts store int8 factor
//! matrices at their real size. Readers accept both magics; writers emit
//! AAT1 whenever no i8 tensor is present, so every pre-quantization
//! artifact stays byte-identical.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let n = dims.iter().product();
        Tensor {
            dims,
            data: vec![0.0; n],
        }
    }
}

/// An int8 tensor (AAT2 records with dtype byte 1); payload is raw i8
/// bytes, dequantization scales travel as a sibling f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI8 {
    pub dims: Vec<usize>,
    pub data: Vec<i8>,
}

impl TensorI8 {
    pub fn new(dims: Vec<usize>, data: Vec<i8>) -> TensorI8 {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        TensorI8 { dims, data }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TensorArchive {
    pub tensors: BTreeMap<String, Tensor>,
    pub tensors_i8: BTreeMap<String, TensorI8>,
}

impl TensorArchive {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors_i8.remove(name);
        self.tensors.insert(name.to_string(), t);
    }

    pub fn insert_i8(&mut self, name: &str, t: TensorI8) {
        self.tensors.remove(name);
        self.tensors_i8.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    pub fn get_i8(&self, name: &str) -> Option<&TensorI8> {
        self.tensors_i8.get(name)
    }

    /// Serialize to the on-disk byte layout — the exact bytes [`save`]
    /// writes (tensors in name order; AAT1 when every tensor is f32,
    /// AAT2 as soon as one int8 tensor is present).
    ///
    /// [`save`]: TensorArchive::save
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        if self.tensors_i8.is_empty() {
            buf.extend_from_slice(b"AAT1");
            buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
            for (name, t) in &self.tensors {
                tensor_bytes_into(&mut buf, name, t);
            }
            return buf;
        }
        buf.extend_from_slice(b"AAT2");
        let total = self.tensors.len() + self.tensors_i8.len();
        buf.extend_from_slice(&(total as u32).to_le_bytes());
        // one global name order across both dtypes (insert/insert_i8 keep
        // the maps disjoint)
        let mut names: Vec<&String> =
            self.tensors.keys().chain(self.tensors_i8.keys()).collect();
        names.sort();
        for name in names {
            if let Some(t) = self.tensors.get(name.as_str()) {
                tensor_bytes_into_v2(&mut buf, name, t);
            } else if let Some(t) = self.tensors_i8.get(name.as_str()) {
                tensor_i8_bytes_into_v2(&mut buf, name, t);
            }
        }
        buf
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        write_bytes_atomic(path, &self.to_bytes())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TensorArchive> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?
            .read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }

    /// Decode the [`to_bytes`] layout (the checkpoint protocol hashes
    /// file bytes before decoding, so it reads then parses).
    ///
    /// [`to_bytes`]: TensorArchive::to_bytes
    pub fn from_bytes(buf: &[u8]) -> Result<TensorArchive> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated tensor archive");
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let v2 = match take(&mut pos, 4)? {
            b"AAT1" => false,
            b"AAT2" => true,
            _ => bail!("bad magic: not a tensor archive"),
        };
        let n_tensors = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut arch = TensorArchive::new();
        for _ in 0..n_tensors {
            let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
            let dtype = if v2 { take(&mut pos, 1)?[0] } else { DTYPE_F32 };
            let n_dims = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let mut dims = Vec::with_capacity(n_dims);
            for _ in 0..n_dims {
                dims.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize);
            }
            let len = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
            if dims.iter().product::<usize>() != len {
                bail!("tensor '{name}' dims/data mismatch");
            }
            match dtype {
                DTYPE_F32 => {
                    let bytes = take(&mut pos, len * 4)?;
                    let data: Vec<f32> = bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    arch.tensors.insert(name, Tensor { dims, data });
                }
                DTYPE_I8 => {
                    let bytes = take(&mut pos, len)?;
                    let data: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
                    arch.tensors_i8.insert(name, TensorI8 { dims, data });
                }
                d => bail!("tensor '{name}' has unknown dtype {d}"),
            }
        }
        Ok(arch)
    }
}

/// AAT2 dtype bytes.
const DTYPE_F32: u8 = 0;
const DTYPE_I8: u8 = 1;

/// Serialize one named tensor record (the AAT1 per-tensor wire layout).
fn tensor_bytes_into(buf: &mut Vec<u8>, name: &str, t: &Tensor) {
    buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
    buf.extend_from_slice(name.as_bytes());
    buf.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
    for &d in &t.dims {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
    for &x in &t.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// AAT2 record header: name, dtype byte, dims, element count.
fn record_header_v2(buf: &mut Vec<u8>, name: &str, dtype: u8, dims: &[usize], len: usize) {
    buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
    buf.extend_from_slice(name.as_bytes());
    buf.push(dtype);
    buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for &d in dims {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    buf.extend_from_slice(&(len as u64).to_le_bytes());
}

fn tensor_bytes_into_v2(buf: &mut Vec<u8>, name: &str, t: &Tensor) {
    record_header_v2(buf, name, DTYPE_F32, &t.dims, t.data.len());
    for &x in &t.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn tensor_i8_bytes_into_v2(buf: &mut Vec<u8>, name: &str, t: &TensorI8) {
    record_header_v2(buf, name, DTYPE_I8, &t.dims, t.data.len());
    for &x in &t.data {
        buf.push(x as u8);
    }
}

/// Atomically replace `path` with `bytes`: write a sibling `.tmp` file,
/// fsync, rename. A crash at any instant (kill -9 included) leaves
/// either the old file or the complete new one, never a torn write —
/// the durability primitive under the compress-run checkpoint protocol.
pub fn write_bytes_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    f.sync_all()
        .with_context(|| format!("syncing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// Streaming `.aat` writer: appends tensors one at a time, so a
/// whole-model artifact can be assembled from per-block shards without
/// ever holding more than one tensor in memory. Bytes go to `<path>.tmp`
/// and land at `path` atomically on [`finish`], which also returns the
/// FNV-1a 64 of everything written (the hash the run manifest records).
/// Output is byte-identical to [`TensorArchive::save`] when tensors are
/// appended in name order.
///
/// [`finish`]: ArchiveWriter::finish
pub struct ArchiveWriter {
    path: std::path::PathBuf,
    tmp: std::path::PathBuf,
    file: std::io::BufWriter<std::fs::File>,
    declared: usize,
    written: usize,
    /// AAT2 stream: dtype byte per record, i8 tensors allowed
    v2: bool,
    hash: crate::util::hash::Fnv64,
}

impl ArchiveWriter {
    /// Start an AAT1 (f32-only) archive holding exactly `n_tensors`.
    pub fn create(path: impl AsRef<Path>, n_tensors: usize) -> Result<ArchiveWriter> {
        Self::create_versioned(path, n_tensors, false)
    }

    /// Start an AAT2 archive: records carry a dtype byte and may be int8
    /// ([`append_i8`]) — the quantized-artifact stream format.
    ///
    /// [`append_i8`]: ArchiveWriter::append_i8
    pub fn create_v2(path: impl AsRef<Path>, n_tensors: usize) -> Result<ArchiveWriter> {
        Self::create_versioned(path, n_tensors, true)
    }

    fn create_versioned(
        path: impl AsRef<Path>,
        n_tensors: usize,
        v2: bool,
    ) -> Result<ArchiveWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let tmp = path.with_extension("tmp");
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = ArchiveWriter {
            path,
            tmp,
            file: std::io::BufWriter::new(file),
            declared: n_tensors,
            written: 0,
            v2,
            hash: crate::util::hash::Fnv64::new(),
        };
        let mut header = Vec::with_capacity(8);
        header.extend_from_slice(if v2 { b"AAT2" } else { b"AAT1" });
        header.extend_from_slice(&(n_tensors as u32).to_le_bytes());
        w.emit(&header)?;
        Ok(w)
    }

    fn emit(&mut self, bytes: &[u8]) -> Result<()> {
        self.hash.update(bytes);
        self.file
            .write_all(bytes)
            .with_context(|| format!("writing {}", self.tmp.display()))
    }

    /// Append the next tensor. Order is the caller's contract — readers
    /// index by name, but byte-level reproducibility needs a fixed order.
    pub fn append(&mut self, name: &str, t: &Tensor) -> Result<()> {
        anyhow::ensure!(
            self.written < self.declared,
            "archive {} declared {} tensors, '{name}' would be one more",
            self.path.display(),
            self.declared
        );
        let mut rec = Vec::new();
        if self.v2 {
            tensor_bytes_into_v2(&mut rec, name, t);
        } else {
            tensor_bytes_into(&mut rec, name, t);
        }
        self.emit(&rec)?;
        self.written += 1;
        Ok(())
    }

    /// Append an int8 tensor (AAT2 streams only).
    pub fn append_i8(&mut self, name: &str, t: &TensorI8) -> Result<()> {
        anyhow::ensure!(
            self.v2,
            "archive {} is AAT1 (f32-only); int8 tensors need create_v2",
            self.path.display()
        );
        anyhow::ensure!(
            self.written < self.declared,
            "archive {} declared {} tensors, '{name}' would be one more",
            self.path.display(),
            self.declared
        );
        let mut rec = Vec::new();
        tensor_i8_bytes_into_v2(&mut rec, name, t);
        self.emit(&rec)?;
        self.written += 1;
        Ok(())
    }

    /// Flush, fsync, rename into place; returns the content hash.
    pub fn finish(mut self) -> Result<u64> {
        anyhow::ensure!(
            self.written == self.declared,
            "archive {} declared {} tensors but only {} were appended",
            self.path.display(),
            self.declared,
            self.written
        );
        self.file
            .flush()
            .with_context(|| format!("flushing {}", self.tmp.display()))?;
        self.file
            .get_ref()
            .sync_all()
            .with_context(|| format!("syncing {}", self.tmp.display()))?;
        std::fs::rename(&self.tmp, &self.path).with_context(|| {
            format!("renaming {} -> {}", self.tmp.display(), self.path.display())
        })?;
        Ok(self.hash.finish())
    }
}

/// Write a string to a file, creating parent directories.
pub fn write_text(path: impl AsRef<Path>, text: &str) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path.as_ref(), text)
        .with_context(|| format!("writing {}", path.as_ref().display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("aasvd-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn archive_roundtrip() {
        let mut a = TensorArchive::new();
        a.insert("w", Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        a.insert("b", Tensor::new(vec![4], vec![0.5; 4]));
        let p = tmpfile("roundtrip.aat");
        a.save(&p).unwrap();
        let b = TensorArchive::load(&p).unwrap();
        assert_eq!(a.tensors, b.tensors);
    }

    #[test]
    fn empty_archive_roundtrip() {
        let a = TensorArchive::new();
        let p = tmpfile("empty.aat");
        a.save(&p).unwrap();
        assert_eq!(TensorArchive::load(&p).unwrap().tensors.len(), 0);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmpfile("garbage.aat");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(TensorArchive::load(&p).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut a = TensorArchive::new();
        a.insert("w", Tensor::new(vec![8], vec![1.0; 8]));
        let p = tmpfile("trunc.aat");
        a.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(TensorArchive::load(&p).is_err());
    }

    #[test]
    #[should_panic]
    fn tensor_dims_must_match_data() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn f32_only_archives_stay_aat1() {
        let mut a = TensorArchive::new();
        a.insert("w", Tensor::new(vec![3], vec![1., 2., 3.]));
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..4], b"AAT1", "pre-quantization artifacts must not change");
    }

    #[test]
    fn mixed_archive_roundtrips_as_aat2() {
        let mut a = TensorArchive::new();
        a.insert("u_s", Tensor::new(vec![2, 3], vec![0.5; 6]));
        a.insert_i8("u_q", TensorI8::new(vec![4, 3], vec![-128, -1, 0, 1, 127, 5, 6, 7, 8, 9, 10, 11]));
        let bytes = a.to_bytes();
        assert_eq!(&bytes[..4], b"AAT2");
        let b = TensorArchive::from_bytes(&bytes).unwrap();
        assert_eq!(a.tensors, b.tensors);
        assert_eq!(a.tensors_i8, b.tensors_i8);
        let p = tmpfile("mixed.aat");
        a.save(&p).unwrap();
        let c = TensorArchive::load(&p).unwrap();
        assert_eq!(a.tensors_i8, c.tensors_i8);
    }

    #[test]
    fn insert_keeps_dtype_maps_disjoint() {
        let mut a = TensorArchive::new();
        a.insert("x", Tensor::new(vec![1], vec![1.0]));
        a.insert_i8("x", TensorI8::new(vec![1], vec![7]));
        assert!(a.get("x").is_none());
        assert_eq!(a.get_i8("x").unwrap().data, vec![7]);
        a.insert("x", Tensor::new(vec![1], vec![2.0]));
        assert!(a.get_i8("x").is_none());
    }

    #[test]
    fn streaming_v2_writer_matches_archive_bytes() {
        let mut a = TensorArchive::new();
        a.insert_i8("a_q", TensorI8::new(vec![2, 2], vec![1, -2, 3, -4]));
        a.insert("b_s", Tensor::new(vec![2], vec![0.25, 0.5]));
        let p = tmpfile("stream_v2.aat");
        // append in global name order — byte-identical to save()
        let mut w = ArchiveWriter::create_v2(&p, 2).unwrap();
        w.append_i8("a_q", a.get_i8("a_q").unwrap()).unwrap();
        w.append("b_s", a.get("b_s").unwrap()).unwrap();
        let hash = w.finish().unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(bytes, a.to_bytes());
        assert_eq!(hash, crate::util::hash::fnv1a64(&bytes));
    }

    #[test]
    fn v1_writer_rejects_i8_tensors() {
        let p = tmpfile("v1_no_i8.aat");
        let mut w = ArchiveWriter::create(&p, 1).unwrap();
        let err = w
            .append_i8("q", &TensorI8::new(vec![1], vec![3]))
            .unwrap_err();
        assert!(err.to_string().contains("create_v2"), "{err}");
    }

    #[test]
    fn rejects_unknown_dtype() {
        let mut a = TensorArchive::new();
        a.insert_i8("q", TensorI8::new(vec![1], vec![3]));
        let mut bytes = a.to_bytes();
        // dtype byte sits right after the 4-byte magic + 4-byte count +
        // 4-byte name length + 1-byte name
        bytes[13] = 9;
        let err = TensorArchive::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("dtype"), "{err}");
    }
}
