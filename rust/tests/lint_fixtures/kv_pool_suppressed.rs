// aasvd-lint: path=src/serve/kv_pool.rs

use std::collections::BTreeMap;

pub fn lru_victim(clocks: &BTreeMap<Vec<u32>, u64>) -> Option<&Vec<u32>> {
    // aasvd-lint: allow(serve-unwrap): fixture justification — caller holds the non-empty invariant
    let (key, _) = clocks.iter().min_by_key(|(_, c)| **c).unwrap();
    Some(key)
}
