//! HTTP/1.1 request-head parsing with strict limits.
//!
//! Deliberately minimal (std::net only, no framework — see README "HTTP
//! API"): request line + headers, CRLF-framed, with hard caps on head
//! size, header count and body length. Every malformed input maps to a
//! typed [`ParseError`] carrying the 4xx/5xx status the connection
//! handler writes back, so the error surface is testable without a
//! socket.

use std::fmt;

/// Hard limits applied while reading and parsing one request.
#[derive(Clone, Debug)]
pub struct Limits {
    /// Cap on the request head (request line + headers + framing). A
    /// head that exceeds this before its terminating blank line is shed
    /// with 431.
    pub max_head_bytes: usize,
    /// Cap on the number of header fields (431 beyond it).
    pub max_headers: usize,
    /// Cap on the declared `content-length` (413 beyond it).
    pub max_body_bytes: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits {
            max_head_bytes: 8 * 1024,
            max_headers: 64,
            max_body_bytes: 64 * 1024,
        }
    }
}

/// Why a request could not be parsed, with its wire status.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParseError {
    /// request line is not `METHOD SP TARGET SP HTTP/x.y`
    BadRequestLine,
    /// a header line has no `name: value` shape
    BadHeader,
    /// a version this server does not speak (only HTTP/1.0 and 1.1)
    UnsupportedVersion,
    /// head exceeded `Limits::max_head_bytes`
    HeadTooLarge,
    /// more than `Limits::max_headers` header fields
    TooManyHeaders,
    /// `content-length` present but not a base-10 integer
    BadContentLength,
}

impl ParseError {
    /// The HTTP status this error maps to on the wire.
    pub fn status(&self) -> u16 {
        match self {
            ParseError::BadRequestLine | ParseError::BadHeader | ParseError::BadContentLength => {
                400
            }
            ParseError::UnsupportedVersion => 505,
            ParseError::HeadTooLarge | ParseError::TooManyHeaders => 431,
        }
    }

    /// One-line detail for the error body.
    pub fn detail(&self) -> &'static str {
        match self {
            ParseError::BadRequestLine => "malformed request line",
            ParseError::BadHeader => "malformed header field",
            ParseError::UnsupportedVersion => "only HTTP/1.0 and HTTP/1.1 are supported",
            ParseError::HeadTooLarge => "request head too large",
            ParseError::TooManyHeaders => "too many header fields",
            ParseError::BadContentLength => "content-length is not a valid integer",
        }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} ({})", self.detail(), self.status())
    }
}

impl std::error::Error for ParseError {}

/// A parsed request head. Header names are lowercased at parse time so
/// lookups are case-insensitive, per RFC 9110.
#[derive(Clone, Debug)]
pub struct RequestHead {
    pub method: String,
    pub target: String,
    pub version: String,
    pub headers: Vec<(String, String)>,
}

impl RequestHead {
    /// First value of `name` (callers pass lowercase).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// The declared body length: `Ok(None)` when absent, `Err` when
    /// present but unparseable.
    pub fn content_length(&self) -> Result<Option<usize>, ParseError> {
        match self.header("content-length") {
            None => Ok(None),
            Some(v) => v
                .trim()
                .parse::<usize>()
                .map(Some)
                .map_err(|_| ParseError::BadContentLength),
        }
    }
}

/// Index just past the head terminator (`\r\n\r\n`) in `buf`, if the
/// full head has arrived.
pub fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Parse a complete request head (everything up to and including the
/// blank line). The connection handler enforces `max_head_bytes` while
/// reading; this enforces shape and header count.
pub fn parse_head(head: &[u8], limits: &Limits) -> Result<RequestHead, ParseError> {
    let text = std::str::from_utf8(head).map_err(|_| ParseError::BadRequestLine)?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().ok_or(ParseError::BadRequestLine)?;

    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or("");
    let target = parts.next().ok_or(ParseError::BadRequestLine)?;
    let version = parts.next().ok_or(ParseError::BadRequestLine)?;
    if parts.next().is_some() {
        return Err(ParseError::BadRequestLine);
    }
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(ParseError::BadRequestLine);
    }
    if target.is_empty() || !target.starts_with('/') {
        return Err(ParseError::BadRequestLine);
    }
    if !matches!(version, "HTTP/1.1" | "HTTP/1.0") {
        return if version.starts_with("HTTP/") {
            Err(ParseError::UnsupportedVersion)
        } else {
            Err(ParseError::BadRequestLine)
        };
    }

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            // the blank line terminating the head (split leaves one or
            // two empty tail fragments from `\r\n\r\n`)
            continue;
        }
        let (name, value) = line.split_once(':').ok_or(ParseError::BadHeader)?;
        // no whitespace is allowed inside a field name (RFC 9112 §5.1)
        if name.is_empty() || name.contains(' ') || name.contains('\t') {
            return Err(ParseError::BadHeader);
        }
        if headers.len() >= limits.max_headers {
            return Err(ParseError::TooManyHeaders);
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    Ok(RequestHead {
        method: method.to_string(),
        target: target.to_string(),
        version: version.to_string(),
        headers,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn head(s: &str) -> Result<RequestHead, ParseError> {
        parse_head(s.as_bytes(), &Limits::default())
    }

    #[test]
    fn parses_a_well_formed_head() {
        let h = head(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 12\r\n\r\n",
        )
        .unwrap();
        assert_eq!(h.method, "POST");
        assert_eq!(h.target, "/v1/completions");
        assert_eq!(h.version, "HTTP/1.1");
        // names lowercase, values trimmed
        assert_eq!(h.header("host"), Some("x"));
        assert_eq!(h.content_length().unwrap(), Some(12));
    }

    #[test]
    fn find_head_end_needs_the_blank_line() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\nHost: x\r\n"), None);
        assert_eq!(
            find_head_end(b"GET / HTTP/1.1\r\nHost: x\r\n\r\nBODY"),
            Some(28)
        );
    }

    #[test]
    fn malformed_request_lines_are_400() {
        for bad in [
            "GARBAGE\r\n\r\n",
            "GET\r\n\r\n",
            "get / HTTP/1.1\r\n\r\n",
            "GET  / HTTP/1.1\r\n\r\n",
            "GET noslash HTTP/1.1\r\n\r\n",
            "GET / HTTP/1.1 extra\r\n\r\n",
            "GET / FTP/1.1\r\n\r\n",
        ] {
            let e = head(bad).unwrap_err();
            assert_eq!(e.status(), 400, "{bad:?} -> {e:?}");
        }
    }

    #[test]
    fn unsupported_version_is_505() {
        let e = head("GET / HTTP/2.0\r\n\r\n").unwrap_err();
        assert_eq!(e, ParseError::UnsupportedVersion);
        assert_eq!(e.status(), 505);
    }

    #[test]
    fn malformed_headers_are_400() {
        for bad in [
            "GET / HTTP/1.1\r\nnocolon\r\n\r\n",
            "GET / HTTP/1.1\r\n: novalue-name\r\n\r\n",
            "GET / HTTP/1.1\r\nbad name: x\r\n\r\n",
        ] {
            let e = head(bad).unwrap_err();
            assert_eq!(e, ParseError::BadHeader, "{bad:?}");
            assert_eq!(e.status(), 400);
        }
    }

    #[test]
    fn header_count_cap_is_431() {
        let mut s = String::from("GET / HTTP/1.1\r\n");
        for i in 0..100 {
            s.push_str(&format!("x-h{i}: v\r\n"));
        }
        s.push_str("\r\n");
        let e = head(&s).unwrap_err();
        assert_eq!(e, ParseError::TooManyHeaders);
        assert_eq!(e.status(), 431);
    }

    #[test]
    fn bad_content_length_is_400() {
        let h = head("POST / HTTP/1.1\r\ncontent-length: nope\r\n\r\n").unwrap();
        let e = h.content_length().unwrap_err();
        assert_eq!(e, ParseError::BadContentLength);
        assert_eq!(e.status(), 400);
        // absent is None, not an error
        let h = head("POST / HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(h.content_length().unwrap(), None);
    }
}
