//! Thread-count invariance of the parallel compression path.
//!
//! The contract (see util::pool): every parallel reduction merges partials
//! in a fixed order and every banded matrix kernel accumulates each output
//! element in the same order as the sequential kernel, so worker count
//! never changes results. These tests pin that end to end — from raw
//! matmuls up to full `compress_model` artifacts — without needing the
//! PJRT artifacts (the pure-Rust [`ReferenceCollector`] drives collection).

use aasvd::compress::{compress_model, CovTriple, Method, Objective, ReferenceCollector};
use aasvd::data::{Batcher, Corpus, Domain, TokenBatch};
use aasvd::linalg::{eigh_values_with, eigh_with, svd_k_with, Matrix};
use aasvd::model::forward::{model_forward_prefill, model_forward_step_batch, KvCache};
use aasvd::model::init::init_params;
use aasvd::model::lowrank::{
    exact_factors, model_lr_forward_prefill, model_lr_forward_step_batch, BlockFactors,
};
use aasvd::model::Config;
use aasvd::testkit::approx::rel_err;
use aasvd::util::pool::Pool;
use aasvd::util::rng::Rng;

fn full_calib(cfg: &Config, n_batches: usize, seed: u64) -> Vec<TokenBatch> {
    let corpus = Corpus::generate(Domain::Wiki, 20_000, seed);
    let batcher = Batcher::new(cfg.batch, cfg.seq);
    let calib: Vec<_> = batcher
        .sequential(&corpus.train, n_batches)
        .into_iter()
        .filter(|b| b.real_rows == cfg.batch)
        .collect();
    assert!(calib.len() >= 2, "need at least two full calibration batches");
    calib
}

/// Banded-parallel matmul/gram against a naive triple loop: both
/// accumulate each element over k ascending, so they match bitwise.
#[test]
fn tiled_parallel_matmul_and_gram_match_naive_reference() {
    let mut rng = Rng::new(31);
    let (m, k, n) = (93, 140, 57);
    let a = Matrix::random(m, k, &mut rng, 1.0);
    let b = Matrix::random(k, n, &mut rng, 1.0);

    let mut naive = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += a.get(i, p) * b.get(p, j);
            }
            naive.set(i, j, acc);
        }
    }
    for threads in [1usize, 2, 4] {
        let pool = Pool::exact(threads);
        assert_eq!(
            a.matmul_with(&b, &pool).data,
            naive.data,
            "matmul diverged from naive at {threads} threads"
        );
    }

    // gram: Aᵀ A, parallel vs sequential, bitwise
    let g1 = a.matmul_at_with(&a, &Pool::exact(1));
    let g4 = a.matmul_at_with(&a, &Pool::exact(4));
    assert_eq!(g1.data, g4.data, "gram accumulation diverged across threads");
}

/// The tridiagonal eigensolver's parallel stages (Householder matvec and
/// rank-2 updates, Q back-transformation, QL rotation replay) are
/// row-banded with fixed accumulation order — eigenpairs must be bitwise
/// equal for any worker count. n = 384 puts *every* stage — including the
/// accumulation-order-sensitive dot-product stages, whose early-step work
/// is 2·(n−1)² — above the banding work threshold (2^18), so multi-thread
/// runs genuinely multi-band everywhere.
#[test]
fn eigh_thread_count_invariant() {
    let mut rng = Rng::new(33);
    let s = Matrix::random_spd(384, &mut rng);
    let (v1, q1) = eigh_with(&s, &Pool::exact(1));
    for threads in [2usize, 4] {
        let (vn, qn) = eigh_with(&s, &Pool::exact(threads));
        assert_eq!(v1, vn, "eigenvalues diverged at {threads} threads");
        assert_eq!(q1.data, qn.data, "eigenvectors diverged at {threads} threads");
    }
    // the eigenvalues-only fast path shares the reduction + QL recurrence:
    // same spectrum, bitwise, at any width
    for threads in [1usize, 4] {
        assert_eq!(
            v1,
            eigh_values_with(&s, &Pool::exact(threads)),
            "values-only path diverged at {threads} threads"
        );
    }
}

/// Pool-threaded truncated SVD (Gram product -> eigh -> back-projection):
/// bitwise equal factors for any worker count, both orientations.
#[test]
fn svd_k_thread_count_invariant() {
    let mut rng = Rng::new(34);
    for (m, n, k) in [(300usize, 180usize, 64usize), (180, 300, 64)] {
        let a = Matrix::random(m, n, &mut rng, 1.0);
        let r1 = svd_k_with(&a, k, &Pool::exact(1));
        for threads in [2usize, 4] {
            let rn = svd_k_with(&a, k, &Pool::exact(threads));
            assert_eq!(r1.s, rn.s, "{m}x{n}: sigma diverged at {threads} threads");
            assert_eq!(r1.u.data, rn.u.data, "{m}x{n}: U diverged at {threads} threads");
            assert_eq!(r1.v.data, rn.v.data, "{m}x{n}: V diverged at {threads} threads");
        }
    }
}

/// Covariance accumulation partials merge in batch order — bitwise equal
/// for any worker count.
#[test]
fn covariance_accumulation_thread_count_invariant() {
    let mut rng = Rng::new(32);
    let d = 24;
    let batches: Vec<Vec<f32>> = (0..5)
        .map(|_| (0..64 * d).map(|_| rng.normal()).collect())
        .collect();
    let views: Vec<&[f32]> = batches.iter().map(|b| b.as_slice()).collect();
    let c1 = CovTriple::accumulate_same(&Pool::exact(1), d, &views);
    for threads in [2usize, 4, 8] {
        let cn = CovTriple::accumulate_same(&Pool::exact(threads), d, &views);
        assert_eq!(
            c1.s_orig.data, cn.s_orig.data,
            "covariance diverged at {threads} threads"
        );
        assert_eq!(c1.tokens, cn.tokens);
    }
}

fn assert_f32_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

/// The batched decode step kernels band the stacked [B, d] pass over the
/// pool's workers; band cuts change with worker count but no computation
/// ever mixes rows, so logits *and* the KV rows each step appends must be
/// bitwise equal at any width — the same artifact-equality contract as
/// the compression entries below. Dense and low-rank paths both pinned.
#[test]
fn batched_decode_step_kernels_thread_count_invariant() {
    let cfg = Config::builtin("tiny").unwrap();
    let params = init_params(&cfg, &mut Rng::new(55));
    let mut blocks: Vec<BlockFactors> =
        (0..cfg.n_layers).map(|i| exact_factors(&cfg, &params, i)).collect();
    for bf in blocks.iter_mut() {
        bf.set_rank("wk", 6);
        bf.set_rank("w_gate", 9);
    }
    let b = 8;
    let prompts: Vec<Vec<u32>> = (0..b)
        .map(|r| (0..2 + r).map(|i| ((i * 17 + r * 3) % cfg.vocab) as u32).collect())
        .collect();

    // (per-step logits, final caches) for one worker count
    let run = |threads: usize, lowrank: bool| -> (Vec<Vec<Vec<f32>>>, Vec<KvCache>) {
        let pool = Pool::exact(threads);
        let mut caches: Vec<KvCache> = prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(cfg.n_layers);
                if lowrank {
                    model_lr_forward_prefill(&cfg, &params, &blocks, &mut c, p);
                } else {
                    model_forward_prefill(&cfg, &params, &mut c, p);
                }
                c
            })
            .collect();
        let mut steps = Vec::new();
        for step in 0..5usize {
            let toks: Vec<u32> =
                (0..b).map(|r| ((r * 29 + step * 11) % cfg.vocab) as u32).collect();
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            steps.push(if lowrank {
                model_lr_forward_step_batch(&cfg, &params, &blocks, &mut refs, &toks, &pool)
            } else {
                model_forward_step_batch(&cfg, &params, &mut refs, &toks, &pool)
            });
        }
        (steps, caches)
    };

    for lowrank in [false, true] {
        let label = if lowrank { "lowrank" } else { "dense" };
        let (steps1, caches1) = run(1, lowrank);
        let (steps4, caches4) = run(4, lowrank);
        for (step, (s1, s4)) in steps1.iter().zip(&steps4).enumerate() {
            for (row, (r1, r4)) in s1.iter().zip(s4).enumerate() {
                assert_f32_bits_eq(r1, r4, &format!("{label} step {step} row {row}"));
            }
        }
        for (row, (c1, c4)) in caches1.iter().zip(&caches4).enumerate() {
            assert_eq!(c1.len, c4.len, "{label} row {row}: cache length");
            for (blk, (l1, l4)) in c1.layers.iter().zip(&c4.layers).enumerate() {
                assert_f32_bits_eq(&l1.k, &l4.k, &format!("{label} row {row} blk {blk} K"));
                assert_f32_bits_eq(&l1.v, &l4.v, &format!("{label} row {row} blk {blk} V"));
            }
        }
    }
}

/// Full Algorithm 2 on the synthetic tiny model: 1-thread and 4-thread
/// runs must produce equal artifacts (factors and rank masks), for both a
/// shift-collecting objective (anchored) and a same-input one.
#[test]
fn compress_model_artifacts_equal_across_thread_counts() {
    let cfg = Config::builtin("tiny").unwrap();
    let params = aasvd::model::init::init_params(&cfg, &mut Rng::new(9));
    let calib = full_calib(&cfg, 3, 11);

    for objective in [Objective::Anchored, Objective::InputAware] {
        let solo = Method::builder(format!("{}_t1", objective.name()))
            .objective(objective)
            .threads(1)
            .build();
        let quad = Method::builder(format!("{}_t4", objective.name()))
            .objective(objective)
            .threads(4)
            .build();
        let c1 =
            compress_model(&ReferenceCollector, &cfg, &params, &calib, &solo, 0.6).unwrap();
        let c4 =
            compress_model(&ReferenceCollector, &cfg, &params, &calib, &quad, 0.6).unwrap();
        assert_eq!(c1.blocks.len(), c4.blocks.len());
        for (i, (b1, b4)) in c1.blocks.iter().zip(&c4.blocks).enumerate() {
            let re = rel_err(&b1.factors.data, &b4.factors.data);
            assert!(
                re <= 1e-12,
                "{} block {i}: factors diverge across thread counts (rel err {re:.3e})",
                objective.name()
            );
            assert_eq!(
                b1.masks.data, b4.masks.data,
                "{} block {i}: rank masks diverge",
                objective.name()
            );
        }
        // and the artifacts are sane, not just equal
        for b in &c1.blocks {
            assert!(b.factors.data.iter().all(|v| v.is_finite()));
        }
    }
}

/// The quantized path (extra per-linear state) must also be invariant.
#[test]
fn quantized_compress_thread_count_invariant() {
    let cfg = Config::builtin("tiny").unwrap();
    let params = aasvd::model::init::init_params(&cfg, &mut Rng::new(10));
    let calib = full_calib(&cfg, 2, 13);

    let build = |threads: usize| {
        Method::builder(format!("dobi_q_t{threads}"))
            .objective(Objective::ShiftAware)
            .quant()
            .threads(threads)
            .build()
    };
    let c1 = compress_model(&ReferenceCollector, &cfg, &params, &calib, &build(1), 0.7)
        .unwrap();
    let c4 = compress_model(&ReferenceCollector, &cfg, &params, &calib, &build(4), 0.7)
        .unwrap();
    for (b1, b4) in c1.blocks.iter().zip(&c4.blocks) {
        assert!(rel_err(&b1.factors.data, &b4.factors.data) <= 1e-12);
    }
    assert!((c1.report.quant_err - c4.report.quant_err).abs() <= 1e-12);
}
