//! Serving client-surface lifecycle tests on the artifact-free synthetic
//! backend: streaming order, cancellation (explicit and drop), deadlines,
//! admission control, stop sequences and seeded sampling determinism.
//! These run everywhere — no PJRT artifacts required.

use aasvd::model::Config;
use aasvd::serve::{
    CancelReason, DecodeMode, Event, GenParams, ModelBackend, Prefill, Server,
    ServerOptions, Session, SubmitError, SyntheticBackend, WaitError,
};
use std::time::Duration;

fn synthetic_server(options: ServerOptions, step_delay: Duration) -> Server {
    let cfg = Config::builtin("tiny").unwrap();
    let backend_cfg = cfg.clone();
    Server::with_backend(cfg, options, move || {
        Ok(Box::new(SyntheticBackend::with_delay(backend_cfg, step_delay)) as Box<dyn ModelBackend>)
    })
}

/// Streaming: tokens arrive as individual events, in order, before Done,
/// and the terminal response equals their concatenation.
#[test]
fn streams_tokens_before_done() {
    let server = synthetic_server(ServerOptions::default(), Duration::ZERO);
    let completion = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 4,
                ..Default::default()
            },
        )
        .unwrap();

    let mut streamed = String::new();
    let mut next_index = 0usize;
    let mut last_at = 0.0f64;
    let resp = loop {
        match completion.next_event() {
            Some(Event::Token(t)) => {
                assert_eq!(t.index, next_index, "tokens must stream in order");
                assert!(t.at >= last_at, "event timestamps must be monotone");
                next_index += 1;
                last_at = t.at;
                streamed.push(t.ch);
            }
            Some(Event::Done(resp)) => break resp,
            other => panic!("unexpected event {other:?}"),
        }
    };
    // the first Event::Token was observed before Event::Done
    assert_eq!(next_index, 4);
    assert_eq!(resp.tokens_generated, 4);
    assert_eq!(resp.text, streamed);
    // synthetic backend decodes the successor chain greedily
    assert_eq!(resp.text, "bcde");
    assert!(resp.ttft <= resp.latency);

    let metrics = server.shutdown();
    assert_eq!(metrics.tokens, 4);
    assert_eq!(metrics.cancelled, 0);
}

/// Cancellation: a cancelled request gets a terminal Cancelled event, its
/// slot frees, and later requests still complete.
#[test]
fn cancel_frees_slot_for_later_requests() {
    let server = synthetic_server(
        ServerOptions {
            max_batch: 1,
            ..Default::default()
        },
        Duration::from_millis(5),
    );
    let a = server
        .submit(
            "x",
            GenParams {
                max_new_tokens: 100_000,
                ..Default::default()
            },
        )
        .unwrap();
    // wait until decoding has demonstrably started
    match a.next_event() {
        Some(Event::Token(_)) => {}
        other => panic!("expected a first token, got {other:?}"),
    }
    a.cancel();
    loop {
        match a.next_event() {
            Some(Event::Token(_)) => continue, // tokens already in flight
            Some(Event::Cancelled { reason, .. }) => {
                assert_eq!(reason, CancelReason::Client);
                break;
            }
            other => panic!("expected cancellation, got {other:?}"),
        }
    }

    // the slot is free again: a fresh request completes
    let b = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 3,
                ..Default::default()
            },
        )
        .unwrap();
    let resp = b.wait().expect("post-cancel request must complete");
    assert_eq!(resp.tokens_generated, 3);

    let metrics = server.shutdown();
    assert_eq!(metrics.cancelled, 1);
    assert_eq!(metrics.deadline_expired, 0);
}

/// Dropping the Completion handle cancels the request.
#[test]
fn dropping_handle_cancels_request() {
    let server = synthetic_server(
        ServerOptions {
            max_batch: 1,
            ..Default::default()
        },
        Duration::from_millis(5),
    );
    let a = server
        .submit(
            "x",
            GenParams {
                max_new_tokens: 100_000,
                ..Default::default()
            },
        )
        .unwrap();
    drop(a);
    let b = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 2,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(b.wait().unwrap().tokens_generated, 2);
    let metrics = server.shutdown();
    assert_eq!(metrics.cancelled, 1);
}

/// Backpressure: with a bounded queue and a busy decode slot, submit
/// returns Overloaded instead of blocking, and queued work still drains.
#[test]
fn bounded_queue_rejects_with_overloaded() {
    let server = synthetic_server(
        ServerOptions {
            max_queue: 1,
            max_batch: 1,
            poll_interval: Duration::from_millis(1),
            ..Default::default()
        },
        Duration::from_millis(40),
    );
    // occupy the single decode slot with a long request
    let a = server
        .submit(
            "x",
            GenParams {
                max_new_tokens: 1000,
                ..Default::default()
            },
        )
        .unwrap();
    match a.next_event() {
        Some(Event::Token(_)) => {} // worker is now decoding `a`
        other => panic!("expected a first token, got {other:?}"),
    }
    // fill the admission queue (the worker cannot drain it: slot is busy)
    let b = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 1,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(server.queue_depth(), 1);
    // queue full -> immediate, non-blocking rejection
    let overloaded = server.submit("c", GenParams::default());
    assert!(matches!(overloaded, Err(SubmitError::Overloaded)));

    // cancel the hog; the queued request is admitted and completes
    drop(a);
    let resp = b.wait().expect("queued request must survive the rejection");
    assert_eq!(resp.tokens_generated, 1);

    let metrics = server.shutdown();
    assert!(metrics.rejected >= 1, "rejections must be counted");
    assert_eq!(metrics.cancelled, 1);
}

/// Deadlines: a request whose budget expires is retired with
/// CancelReason::Deadline and counted separately.
#[test]
fn deadline_expiry_cancels_request() {
    let server = synthetic_server(ServerOptions::default(), Duration::from_millis(15));
    let c = server
        .submit(
            "x",
            GenParams {
                max_new_tokens: 100_000,
                deadline: Some(Duration::from_millis(1)),
                ..Default::default()
            },
        )
        .unwrap();
    match c.wait() {
        Err(WaitError::Cancelled(CancelReason::Deadline)) => {}
        other => panic!("expected deadline cancellation, got {other:?}"),
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.cancelled, 1);
    assert_eq!(metrics.deadline_expired, 1);
}

/// Stop sequences end generation as soon as the generated text ends with
/// any of them.
#[test]
fn stop_sequences_end_generation() {
    let server = synthetic_server(ServerOptions::default(), Duration::ZERO);
    let resp = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 100,
                stop_sequences: vec!["zz".into(), "de".into()],
                ..Default::default()
            },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.text, "bcde");
    assert_eq!(resp.tokens_generated, 4);
    server.shutdown();
}

/// A fixed per-request seed makes sampled decoding reproducible even when
/// requests share a continuous batch.
#[test]
fn seeded_sampling_is_deterministic() {
    let server = synthetic_server(ServerOptions::default(), Duration::ZERO);
    let params = GenParams {
        max_new_tokens: 12,
        temperature: 1.0,
        top_k: Some(8),
        seed: Some(42),
        ..Default::default()
    };
    let a = server.submit("hello", params.clone()).unwrap();
    let b = server.submit("hello", params).unwrap();
    let (ra, rb) = (a.wait().unwrap(), b.wait().unwrap());
    assert_eq!(ra.text, rb.text);
    server.shutdown();
}

/// The KV-cached decode path and the full-prefix recompute oracle
/// (`DecodeMode::Recompute`) generate identical text — the engine-level
/// face of the cache-exactness contract, on the synthetic backend.
#[test]
fn cached_and_recompute_modes_generate_identical_text() {
    let run = |mode: DecodeMode| -> (String, f64) {
        let server = synthetic_server(
            ServerOptions {
                decode: mode,
                ..Default::default()
            },
            Duration::ZERO,
        );
        let resp = server
            .submit(
                "a",
                GenParams {
                    max_new_tokens: 9,
                    ..Default::default()
                },
            )
            .unwrap()
            .wait()
            .unwrap();
        let metrics = server.shutdown();
        assert_eq!(metrics.prefill_tokens, 1);
        assert_eq!(metrics.decode_tokens, 8);
        (resp.text, metrics.peak_cache_bytes())
    };
    let (cached_text, _) = run(DecodeMode::Cached);
    let (recompute_text, recompute_kv) = run(DecodeMode::Recompute);
    assert_eq!(cached_text, recompute_text);
    assert_eq!(cached_text, "bcdefghij");
    // the recompute oracle never holds a cache
    assert_eq!(recompute_kv, 0.0);
}

/// `ServerOptions::max_context` bounds a request's total context: a
/// request hitting the cap completes with what it has (bounding KV-cache
/// growth), instead of decoding to max_new_tokens.
#[test]
fn max_context_caps_generation() {
    let server = synthetic_server(
        ServerOptions {
            max_context: 10,
            ..Default::default()
        },
        Duration::ZERO,
    );
    let resp = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 100,
                ..Default::default()
            },
        )
        .unwrap()
        .wait()
        .unwrap();
    // prompt (1 token) + 9 generated = the 10-token context cap
    assert_eq!(resp.tokens_generated, 9);
    assert_eq!(resp.text, "bcdefghij");

    // an over-long prompt is clamped to its most recent max_context
    // tokens at admission — prefill cost and KV allocation are bounded,
    // not just generation
    let resp = server
        .submit(
            "this prompt is longer than the ten-token context cap",
            GenParams {
                max_new_tokens: 100,
                ..Default::default()
            },
        )
        .unwrap()
        .wait()
        .unwrap();
    // clamped prompt fills the cap, leaving room to emit one token
    assert_eq!(resp.tokens_generated, 1);
    let metrics = server.shutdown();
    // 1 (short prompt) + 10 (clamped long prompt)
    assert_eq!(metrics.prefill_tokens, 11);
}

/// A synthetic backend that fails prefill for prompts starting with '!'
/// and fails decode_step when asked to absorb `fail_on_step_token`.
struct FlakyBackend {
    inner: SyntheticBackend,
    fail_on_step_token: Option<i32>,
}

impl ModelBackend for FlakyBackend {
    fn artifact(&self) -> &'static str {
        "flaky"
    }

    fn prefill(&mut self, tokens: &[i32]) -> anyhow::Result<Prefill> {
        anyhow::ensure!(
            tokens.first() != Some(&(b'!' as i32)),
            "poisoned prompt"
        );
        self.inner.prefill(tokens)
    }

    fn decode_step(&mut self, session: &mut Session, token: i32) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.fail_on_step_token != Some(token), "poisoned token");
        self.inner.decode_step(session, token)
    }

    fn oracle_logits(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        self.inner.oracle_logits(tokens)
    }
}

/// A backend failure retires only the failing request (with
/// `CancelReason::Backend`); the worker and its other requests survive.
#[test]
fn backend_failure_retires_only_that_request() {
    let cfg = Config::builtin("tiny").unwrap();
    let backend_cfg = cfg.clone();
    let server = Server::with_backend(cfg, ServerOptions::default(), move || {
        Ok(Box::new(FlakyBackend {
            inner: SyntheticBackend::new(backend_cfg),
            fail_on_step_token: Some(b'x' as i32),
        }) as Box<dyn ModelBackend>)
    });

    // prefill failure at admission
    let bad = server
        .submit(
            "!boom",
            GenParams {
                max_new_tokens: 4,
                ..Default::default()
            },
        )
        .unwrap();
    match bad.wait() {
        Err(WaitError::Cancelled(CancelReason::Backend)) => {}
        other => panic!("expected backend cancellation, got {other:?}"),
    }

    // decode-step failure mid-request: greedy from "w" samples 'x', whose
    // absorption fails; the request retires after streaming that token
    let mid = server
        .submit(
            "w",
            GenParams {
                max_new_tokens: 10,
                ..Default::default()
            },
        )
        .unwrap();
    match mid.wait() {
        Err(WaitError::Cancelled(CancelReason::Backend)) => {}
        other => panic!("expected backend cancellation, got {other:?}"),
    }

    // a healthy request still completes on the same worker
    let good = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 3,
                ..Default::default()
            },
        )
        .unwrap();
    let resp = good.wait().expect("healthy request survives the failures");
    assert_eq!(resp.text, "bcd");

    let metrics = server.shutdown();
    assert_eq!(metrics.cancelled, 2);
}

/// Shutdown drains queued requests rather than dropping them.
#[test]
fn shutdown_drains_queued_requests() {
    let server = synthetic_server(ServerOptions::default(), Duration::ZERO);
    let completions: Vec<_> = (0..8)
        .map(|_| {
            server
                .submit(
                    "a",
                    GenParams {
                        max_new_tokens: 2,
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    let metrics = server.shutdown();
    assert_eq!(metrics.latencies.len(), 8);
    for c in completions {
        assert_eq!(c.wait().unwrap().tokens_generated, 2);
    }
}
