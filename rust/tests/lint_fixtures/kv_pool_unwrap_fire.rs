// aasvd-lint: path=src/serve/kv_pool.rs

pub fn first_block(blocks: &[usize]) -> usize {
    *blocks.first().unwrap()
}
