//! Algorithm 2: end-to-end block-wise compression with local refinement.
//!
//! The coordinator walks the model block by block, maintaining two
//! activation streams over the calibration set:
//!   X  — inputs produced by the *original* dense network
//!   X' — inputs produced by the *partially compressed* network
//! Within a block, linears are compressed in topological groups sharing a
//! tap position (q/k/v → wo → gate/up → w_down; covariances shared within a
//! group, paper §B.1), re-collecting shifted taps after each group so X'_j
//! always reflects a valid partial compression state. After all linears,
//! block-level refinement (refine::driver) jointly tunes the factors
//! against the dense block's outputs on original inputs.
//!
//! Activations come from a [`Collector`]: the PJRT engine artifacts on the
//! hot path, or the pure-Rust reference forward ([`ReferenceCollector`])
//! for artifact-free tools, tests and benches. The CPU-heavy stages —
//! batch collection (reference path), covariance accumulation, and the
//! per-linear closed-form solves inside each group — fan out over a
//! [`Pool`] sized by [`Method`]'s `threads` knob. Every parallel reduction
//! merges partials in a fixed order, so compressed artifacts are
//! identical for any worker count (the block-sequential error-propagation
//! order of the paper is never reordered).
//!
//! The block loop itself lives in [`super::run::CompressRun`], the
//! streaming session behind both [`compress_model`] (in-memory, whole
//! model at once) and the checkpointed, resumable CLI path. This module
//! keeps the vocabulary: [`Method`], [`Collector`], the tap groups, and
//! the per-linear solve.

use super::cov::CovTriple;
use super::layer::{
    compress_layer_asvd_with, compress_layer_plain_with, compress_layer_with, Factors,
};
use super::objective::Objective;
use super::quant::quantize_factors_inplace;
use super::rank::{Allocation, RankScheme};
use crate::data::TokenBatch;
use crate::model::lowrank::BlockFactors;
use crate::model::{Config, FlatStore};
#[cfg(test)]
use crate::model::BLOCK_LINEARS;
use crate::refine::{RefineOptions, RefineReport};
use crate::runtime::{Engine, Value};
use crate::util::pool::Pool;
use anyhow::Result;

/// A named compression method (one table row). Knobs are private: build
/// one with a named constructor or [`Method::builder`].
#[derive(Clone, Debug)]
pub struct Method {
    pub name: String,
    objective: Objective,
    /// use ASVD-style diagonal scaling instead of the full whitening solve
    asvd_diag: bool,
    scheme: RankScheme,
    quant: bool,
    refine: Option<RefineOptions>,
    /// worker threads for the compression math (0 = auto; see Pool::new)
    threads: usize,
}

/// Fluent constructor for [`Method`]; new knobs get a defaulted builder
/// setter instead of breaking every call site.
#[derive(Clone, Debug)]
pub struct MethodBuilder {
    method: Method,
}

impl MethodBuilder {
    pub fn objective(mut self, objective: Objective) -> Self {
        self.method.objective = objective;
        self
    }

    /// ASVD-style diagonal scaling instead of the full whitening solve.
    pub fn asvd_diag(mut self) -> Self {
        self.method.asvd_diag = true;
        self
    }

    pub fn scheme(mut self, scheme: RankScheme) -> Self {
        self.method.scheme = scheme;
        self
    }

    /// int8-quantize the factors after the solve.
    pub fn quant(mut self) -> Self {
        self.method.quant = true;
        self
    }

    /// block-level local refinement after the layer-wise solves.
    pub fn refine(mut self, options: RefineOptions) -> Self {
        self.method.refine = Some(options);
        self
    }

    /// Worker threads for the compression math. 0 (the default) resolves
    /// at run time: `AA_SVD_THREADS` env, then the `--threads` global
    /// knob, then hardware parallelism. Nonzero pins the count exactly.
    pub fn threads(mut self, n: usize) -> Self {
        self.method.threads = n;
        self
    }

    pub fn build(self) -> Method {
        self.method
    }
}

impl Method {
    /// Start from the input-agnostic / standard-scheme baseline.
    pub fn builder(name: impl Into<String>) -> MethodBuilder {
        MethodBuilder {
            method: Method {
                name: name.into(),
                objective: Objective::InputAgnostic,
                asvd_diag: false,
                scheme: RankScheme::Standard,
                quant: false,
                refine: None,
                threads: 0,
            },
        }
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    pub fn asvd_diag(&self) -> bool {
        self.asvd_diag
    }

    pub fn scheme(&self) -> RankScheme {
        self.scheme
    }

    pub fn quantized(&self) -> bool {
        self.quant
    }

    pub fn refine_options(&self) -> Option<&RefineOptions> {
        self.refine.as_ref()
    }

    /// Requested worker count (0 = auto-resolved at compression time).
    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn naive_svd() -> Method {
        Method::builder("naive_svd").build()
    }

    pub fn asvd() -> Method {
        Method::builder("asvd").objective(Objective::InputAware).asvd_diag().build()
    }

    pub fn svd_llm() -> Method {
        Method::builder("svd_llm").objective(Objective::InputAware).build()
    }

    /// Dobi-SVD-like: shift-aware objective (+remap/quant in `dobi_q`).
    pub fn dobi() -> Method {
        Method::builder("dobi").objective(Objective::ShiftAware).build()
    }

    pub fn dobi_q() -> Method {
        Method::builder("dobi_q")
            .objective(Objective::ShiftAware)
            .scheme(RankScheme::Remap)
            .quant()
            .build()
    }

    /// AA-SVD: input-aware init + block-level refinement (paper §4.3 pairing).
    pub fn aa_svd(refine: RefineOptions) -> Method {
        Method::builder("aa_svd").objective(Objective::InputAware).refine(refine).build()
    }

    /// AA-SVDᵠ: remapped ranks + int8 factors + refinement.
    pub fn aa_svd_q(refine: RefineOptions) -> Method {
        Method::builder("aa_svd_q")
            .objective(Objective::InputAware)
            .scheme(RankScheme::Remap)
            .quant()
            .refine(refine)
            .build()
    }

    /// Ablation constructor: any objective × refinement (Table 5 rows).
    pub fn ablation(objective: Objective, refine: Option<RefineOptions>) -> Method {
        let name = format!(
            "{}{}",
            objective.name(),
            if refine.is_some() { "+refine" } else { "" }
        );
        let builder = Method::builder(name).objective(objective);
        match refine {
            Some(options) => builder.refine(options).build(),
            None => builder.build(),
        }
    }

    /// Does this method ever need the shifted activation stream?
    pub fn needs_shift(&self) -> bool {
        self.objective.needs_shift() || self.refine.is_some() || self.quant
    }
}

/// Result of compressing a model.
pub struct CompressedModel {
    pub blocks: Vec<BlockFactors>,
    pub allocation: Allocation,
    pub report: CompressReport,
}

#[derive(Clone, Debug, Default)]
pub struct CompressReport {
    pub refine: Vec<RefineReport>,
    pub secs_collect: f64,
    pub secs_solve: f64,
    pub secs_refine: f64,
    pub quant_err: f64,
}

/// The tap groups: (tap index into collect outputs, linears fed by it).
/// Collect outputs are (y, a_in, o_in, m_in, d_in).
pub(crate) const GROUPS: [(usize, &[&str]); 4] = [
    (1, &["wq", "wk", "wv"]),
    (2, &["wo"]),
    (3, &["w_gate", "w_up"]),
    (4, &["w_down"]),
];

/// Pack block `i`'s dense params into the bare-name block layout used by
/// the block_fwd/block_collect artifacts.
pub fn pack_block_params(cfg: &Config, params: &FlatStore, i: usize) -> Vec<f32> {
    let lay = crate::model::params::block_param_layout(cfg);
    let mut bp = vec![0f32; lay.total];
    for e in &lay.entries {
        let src = params.view(&format!("blocks.{i}.{}", e.name));
        let size: usize = e.shape.iter().product();
        bp[e.offset..e.offset + size].copy_from_slice(src);
    }
    bp
}

/// Embed calibration tokens (Rust-side gather — step 1 of Algorithm 2).
pub fn embed_batches(cfg: &Config, params: &FlatStore, batches: &[TokenBatch]) -> Vec<Vec<f32>> {
    let d = cfg.d_model;
    let embed = params.view("embed");
    batches
        .iter()
        .map(|tb| {
            let mut x = vec![0f32; tb.tokens.len() * d];
            for (i, &tok) in tb.tokens.iter().enumerate() {
                let tok = tok as usize;
                x[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
            }
            x
        })
        .collect()
}

/// Dense-block taps over all calibration batches.
#[derive(Default)]
pub struct Taps {
    pub y: Vec<Vec<f32>>,
    /// a_in, o_in, m_in, d_in — indexed by tap position
    pub per_tap: [Vec<Vec<f32>>; 4],
}

/// Source of block activations for Algorithm 2 — either the PJRT engine
/// artifacts (the serving/bench hot path) or the pure-Rust reference
/// forward. Implementations are driven from one thread; batch-level
/// parallelism, where available, happens *inside* a method via `pool`.
pub trait Collector {
    /// Dense-block taps of `block` on original inputs, over all batches.
    fn dense_taps(
        &self,
        cfg: &Config,
        params: &FlatStore,
        block: usize,
        xs: &[Vec<f32>],
        pool: &Pool,
    ) -> Result<Taps>;

    /// Shifted tap (0 = a_in, 1 = o_in, 2 = m_in, 3 = d_in) of the current
    /// partial compression state, over all batches.
    fn lr_tap(
        &self,
        cfg: &Config,
        bf: &BlockFactors,
        xs: &[Vec<f32>],
        tap: usize,
        pool: &Pool,
    ) -> Result<Vec<Vec<f32>>>;

    /// Compressed-block output for one batch (advances the shifted stream).
    fn lr_forward(&self, cfg: &Config, bf: &BlockFactors, x: &[f32]) -> Result<Vec<f32>>;

    /// Advance the whole shifted stream (default: sequential per batch).
    fn lr_forward_all(
        &self,
        cfg: &Config,
        bf: &BlockFactors,
        xs: &[Vec<f32>],
        _pool: &Pool,
    ) -> Result<Vec<Vec<f32>>> {
        xs.iter().map(|x| self.lr_forward(cfg, bf, x)).collect()
    }

    /// The PJRT engine behind this collector, if any (block refinement
    /// drives the AOT refine_step artifact and needs it).
    fn engine(&self) -> Option<&Engine> {
        None
    }
}

impl Collector for Engine {
    fn dense_taps(
        &self,
        cfg: &Config,
        params: &FlatStore,
        block: usize,
        xs: &[Vec<f32>],
        _pool: &Pool,
    ) -> Result<Taps> {
        let bp = pack_block_params(cfg, params, block);
        let mut taps = Taps::default();
        for x in xs {
            let out = self.run(
                &cfg.name,
                "block_collect",
                &[Value::F32(&bp), Value::F32(x)],
            )?;
            taps.y.push(out[0].f32.clone());
            for t in 0..4 {
                taps.per_tap[t].push(out[t + 1].f32.clone());
            }
        }
        Ok(taps)
    }

    fn lr_tap(
        &self,
        cfg: &Config,
        bf: &BlockFactors,
        xs: &[Vec<f32>],
        tap: usize,
        _pool: &Pool,
    ) -> Result<Vec<Vec<f32>>> {
        let mut out_taps = Vec::new();
        for x in xs {
            let out = self.run(
                &cfg.name,
                "block_lr_collect",
                &[
                    Value::F32(&bf.factors.data),
                    Value::F32(&bf.masks.data),
                    Value::F32(x),
                ],
            )?;
            out_taps.push(out[tap + 1].f32.clone());
        }
        Ok(out_taps)
    }

    fn lr_forward(&self, cfg: &Config, bf: &BlockFactors, x: &[f32]) -> Result<Vec<f32>> {
        Ok(self
            .run_first(
                &cfg.name,
                "block_lr_fwd",
                &[
                    Value::F32(&bf.factors.data),
                    Value::F32(&bf.masks.data),
                    Value::F32(x),
                ],
            )?
            .f32)
    }

    fn engine(&self) -> Option<&Engine> {
        Some(self)
    }
}

/// Artifact-free [`Collector`] over the pure-Rust reference forward
/// (model::forward / model::lowrank). Batches fan out across the pool;
/// each batch is a pure function of its inputs, so results are bitwise
/// identical for any worker count.
pub struct ReferenceCollector;

impl Collector for ReferenceCollector {
    fn dense_taps(
        &self,
        cfg: &Config,
        params: &FlatStore,
        block: usize,
        xs: &[Vec<f32>],
        pool: &Pool,
    ) -> Result<Taps> {
        let prefix = format!("blocks.{block}.");
        let per_batch = pool.run(
            xs.iter()
                .map(|x| {
                    let prefix = prefix.as_str();
                    move || {
                        crate::model::forward::block_forward(cfg, params, prefix, x, cfg.seq)
                    }
                })
                .collect(),
        );
        let mut taps = Taps::default();
        for t in per_batch {
            taps.y.push(t.y);
            taps.per_tap[0].push(t.a_in);
            taps.per_tap[1].push(t.o_in);
            taps.per_tap[2].push(t.m_in);
            taps.per_tap[3].push(t.d_in);
        }
        Ok(taps)
    }

    fn lr_tap(
        &self,
        cfg: &Config,
        bf: &BlockFactors,
        xs: &[Vec<f32>],
        tap: usize,
        pool: &Pool,
    ) -> Result<Vec<Vec<f32>>> {
        Ok(pool.run(
            xs.iter()
                .map(|x| {
                    move || {
                        let t = crate::model::lowrank::block_lr_forward(cfg, bf, x, cfg.seq);
                        match tap {
                            0 => t.a_in,
                            1 => t.o_in,
                            2 => t.m_in,
                            3 => t.d_in,
                            _ => panic!("tap index {tap} out of range"),
                        }
                    }
                })
                .collect(),
        ))
    }

    fn lr_forward(&self, cfg: &Config, bf: &BlockFactors, x: &[f32]) -> Result<Vec<f32>> {
        Ok(crate::model::lowrank::block_lr_forward(cfg, bf, x, cfg.seq).y)
    }

    fn lr_forward_all(
        &self,
        cfg: &Config,
        bf: &BlockFactors,
        xs: &[Vec<f32>],
        pool: &Pool,
    ) -> Result<Vec<Vec<f32>>> {
        Ok(pool.run(
            xs.iter()
                .map(|x| {
                    move || crate::model::lowrank::block_lr_forward(cfg, bf, x, cfg.seq).y
                })
                .collect(),
        ))
    }
}

/// Solve one linear's closed form. Pure math over shared-read state — a
/// group's solves run concurrently, each with its own share of the worker
/// budget (`pool`) threaded down through the whitening solve, the Gram
/// products and the tridiagonal eigensolver. Returns the unpadded factors
/// and the quantization error (0.0 unless the method quantizes). The only
/// error path is quantizing non-finite factors (a poisoned solve), which
/// surfaces as a typed [`super::quant::QuantError`] instead of silently
/// zeroing NaNs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn solve_one(
    method: &Method,
    cfg: &Config,
    params: &FlatStore,
    block: usize,
    lin: &str,
    cov: &CovTriple,
    k: usize,
    pool: &Pool,
) -> Result<(Factors, f64)> {
    let (m, n) = cfg.linear_dims(lin);
    let w = params.view(&format!("blocks.{block}.{lin}"));
    let mut f = if method.asvd_diag {
        compress_layer_asvd_with(w, m, n, &cov.channel_scales(), 0.5, k, pool)
    } else {
        match method.objective.assemble(cov) {
            None => compress_layer_plain_with(w, m, n, k, pool),
            Some((c, s)) => compress_layer_with(w, m, n, &c, &s, k, pool),
        }
    };
    let mut qerr = 0.0;
    if method.quant {
        let (eu, ev) = quantize_factors_inplace(&mut f.u, m, &mut f.v, n, f.k)
            .map_err(|e| anyhow::anyhow!("block {block} {lin}: {e}"))?;
        qerr = 0.5 * (eu + ev);
    }
    Ok((f, qerr))
}

/// Algorithm 2, whole model in memory: a thin wrapper that drives a
/// [`super::run::CompressRun`] with in-memory options to completion. The
/// streaming session executes the block loop in the exact operation
/// order this function historically used, so outputs are bitwise
/// unchanged. `calib` batches must all be full (`real_rows == batch`).
pub fn compress_model<C: Collector>(
    collector: &C,
    cfg: &Config,
    params: &FlatStore,
    calib: &[TokenBatch],
    method: &Method,
    ratio: f64,
) -> Result<CompressedModel> {
    let mut run = super::run::CompressRun::new(
        collector,
        cfg,
        params,
        calib,
        method,
        ratio,
        super::run::RunOptions::in_memory(),
    )?;
    while run.next_block()?.is_some() {}
    run.into_model()
}

/// Chain dense block_collect across the whole model, accumulating
/// (a_in, m_in, d_in) covariance triples per block (same-input mode).
/// Used by the activation-aware pruning baselines.
pub fn collect_dense_taps_for_pruning<C: Collector>(
    collector: &C,
    cfg: &Config,
    params: &FlatStore,
    mut xs: Vec<Vec<f32>>,
    pool: &Pool,
) -> Result<Vec<(CovTriple, CovTriple, CovTriple)>> {
    let mut out = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let taps = collector.dense_taps(cfg, params, i, &xs, pool)?;
        let mut covs: Vec<CovTriple> = [(0usize, cfg.d_model), (2, cfg.d_model), (3, cfg.d_ff)]
            .into_iter()
            .map(|(tap, dim)| {
                let chunks: Vec<&[f32]> =
                    taps.per_tap[tap].iter().map(|c| c.as_slice()).collect();
                let mut cov = CovTriple::accumulate_same(pool, dim, &chunks);
                cov.mirror_same();
                cov
            })
            .collect();
        let d = covs.pop().unwrap();
        let m = covs.pop().unwrap();
        let a = covs.pop().unwrap();
        out.push((a, m, d));
        xs = taps.y;
    }
    Ok(out)
}

pub(crate) fn concat_batches(batches: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::with_capacity(batches.iter().map(|b| b.len()).sum());
    for b in batches {
        out.extend_from_slice(b);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_presets_are_consistent() {
        assert!(!Method::svd_llm().needs_shift());
        assert!(Method::dobi().needs_shift());
        assert!(Method::aa_svd(RefineOptions::default()).needs_shift());
        assert_eq!(Method::naive_svd().objective(), Objective::InputAgnostic);
        assert_eq!(Method::aa_svd_q(RefineOptions::default()).scheme(), RankScheme::Remap);
        assert!(Method::aa_svd_q(RefineOptions::default()).quantized());
        // presets default to auto thread resolution
        assert_eq!(Method::naive_svd().threads(), 0);
    }

    #[test]
    fn builder_composes_knobs() {
        let m = Method::builder("custom")
            .objective(Objective::Anchored)
            .scheme(RankScheme::Remap)
            .quant()
            .refine(RefineOptions::default())
            .threads(3)
            .build();
        assert_eq!(m.name, "custom");
        assert_eq!(m.objective(), Objective::Anchored);
        assert_eq!(m.scheme(), RankScheme::Remap);
        assert!(m.quantized());
        assert!(m.refine_options().is_some());
        assert!(!m.asvd_diag());
        assert!(m.needs_shift());
        assert_eq!(m.threads(), 3);
        // baseline builder matches the plainest named constructor
        let n = Method::builder("naive_svd").build();
        assert_eq!(n.objective(), Method::naive_svd().objective());
        assert!(!n.needs_shift());
    }

    #[test]
    fn ablation_names() {
        let m = Method::ablation(Objective::Anchored, Some(RefineOptions::default()));
        assert_eq!(m.name, "anchored+refine");
        let m = Method::ablation(Objective::InputAgnostic, None);
        assert_eq!(m.name, "input_agnostic");
    }

    #[test]
    fn groups_cover_all_linears_once() {
        let mut seen: Vec<&str> = GROUPS.iter().flat_map(|(_, l)| l.iter().copied()).collect();
        seen.sort_unstable();
        let mut want = BLOCK_LINEARS.to_vec();
        want.sort_unstable();
        assert_eq!(seen, want);
    }

    #[test]
    fn refinement_requires_an_engine_backed_collector() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = crate::model::init::init_params(
            &cfg,
            &mut crate::util::rng::Rng::new(4),
        );
        let corpus = crate::data::Corpus::generate(crate::data::Domain::Wiki, 10_000, 7);
        let batcher = crate::data::Batcher::new(cfg.batch, cfg.seq);
        let calib: Vec<_> = batcher
            .sequential(&corpus.train, 2)
            .into_iter()
            .filter(|b| b.real_rows == cfg.batch)
            .collect();
        assert!(!calib.is_empty());
        let method = Method::aa_svd(RefineOptions::default());
        let err = match compress_model(&ReferenceCollector, &cfg, &params, &calib, &method, 0.8)
        {
            Err(e) => e,
            Ok(_) => panic!("refinement without an engine must fail"),
        };
        assert!(err.to_string().contains("refine"), "unexpected error: {err}");
    }

    /// End-to-end pipeline on the tiny config (skips without artifacts).
    /// This is the topological-order invariant test: compressing with the
    /// anchored objective must produce finite factors with the allocated
    /// ranks, and the compressed model must stay close to dense at high
    /// ratio.
    #[test]
    fn pipeline_end_to_end_tiny() {
        let Ok(engine) = Engine::new("artifacts") else { return };
        if engine.entry("tiny").is_err() {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let params = crate::model::init::init_params(
            &cfg,
            &mut crate::util::rng::Rng::new(3),
        );
        let corpus = crate::data::Corpus::generate(crate::data::Domain::Wiki, 30_000, 7);
        let batcher = crate::data::Batcher::new(cfg.batch, cfg.seq);
        let calib: Vec<_> = batcher
            .sequential(&corpus.train, 4)
            .into_iter()
            .filter(|b| b.real_rows == cfg.batch)
            .collect();
        assert!(calib.len() >= 2);

        let method = Method::ablation(Objective::Anchored, None);
        let cm = compress_model(&engine, &cfg, &params, &calib, &method, 0.9).unwrap();
        assert_eq!(cm.blocks.len(), cfg.n_layers);
        for bf in &cm.blocks {
            for lin in BLOCK_LINEARS {
                assert_eq!(bf.rank(lin), cm.allocation.rank_of(lin));
            }
            assert!(bf.factors.data.iter().all(|v| v.is_finite()));
        }
    }
}
