//! Serving layer: KV-cached continuous-batching decode behind a
//! streaming, cancellable client API with admission control.
//!
//! # Serving API
//!
//! [`Server::submit`] returns a [`Completion`] handle instead of a bare
//! channel. The handle streams [`Event`]s — `Token` per generated token,
//! then a terminal `Done` (with the full [`GenResponse`]) or `Cancelled`.
//!
//! - **Streaming**: `completion.next_event()` yields tokens as they are
//!   sampled; TTFT is measured at true first-token emission.
//! - **Cancellation**: `completion.cancel()` — or simply dropping the
//!   handle — retires the request's decode slot (and drops its KV cache)
//!   at the next iteration and delivers
//!   `Event::Cancelled { reason: CancelReason::Client }`.
//! - **Deadlines**: `GenParams::deadline` retires a request (queued or
//!   decoding) once the wall-clock budget is exhausted
//!   (`CancelReason::Deadline`).
//! - **Backpressure**: the admission queue is bounded by
//!   [`ServerOptions::max_queue`]; `submit` returns
//!   `Err(SubmitError::Overloaded)` immediately instead of blocking.
//! - **KV-cached batched decode**: admission runs one
//!   [`ModelBackend::prefill`] pass over the prompt, building a
//!   per-request [`Session`]; each decode iteration advances *all* active
//!   sessions with a single [`ModelBackend::decode_batch`] call — one
//!   stacked [B, d] forward per tick at O(len) attention cost per row,
//!   each row **bitwise identical** to its per-session
//!   [`ModelBackend::decode_step`] result, per-row failures retiring only
//!   their own request. The old full-prefix recompute path survives as
//!   [`DecodeMode::Recompute`] (test oracle / bench baseline) and is
//!   guaranteed **bitwise token-identical** to the cached path.
//! - **Backends**: the decode loop is generic over [`ModelBackend`] —
//!   dense ([`DenseBackend`]), low-rank compressed
//!   ([`CompressedBackend`]), int8-quantized low-rank
//!   ([`QuantizedBackend`], fused-dequant kernels over the same KV
//!   machinery; see README "Quantized serving"), or the artifact-free
//!   [`SyntheticBackend`] for tests and load experiments. All decode
//!   through KV-cached pure-Rust reference forwards.
//! - **HTTP front door**: [`http::HttpServer`] exposes the same engine
//!   over a pure-`std::net` HTTP/1.1 endpoint (`POST /v1/completions`,
//!   chunked SSE token streaming, strict request limits, 429/408/499
//!   shed-and-cancel semantics). Multi-threaded submission goes through
//!   the cloneable [`Submitter`] handle. See the [`http`] module docs
//!   and README "HTTP API".
//!
//! ```no_run
//! use aasvd::serve::{Event, GenParams, ServedModel, Server, ServerOptions, SubmitError};
//! # fn demo(cfg: aasvd::model::Config, params: aasvd::model::FlatStore) {
//! let server = Server::start_with(
//!     cfg,
//!     ServedModel::Dense(params),
//!     ServerOptions { max_queue: 32, ..Default::default() },
//! );
//! match server.submit("the cat", GenParams {
//!     max_new_tokens: 16,
//!     temperature: 0.8,
//!     top_k: Some(40),
//!     stop_sequences: vec![".".into()],
//!     deadline: Some(std::time::Duration::from_secs(5)),
//!     ..Default::default()
//! }) {
//!     Err(SubmitError::Overloaded) => { /* shed load */ }
//!     Err(e) => panic!("{e}"),
//!     Ok(completion) => {
//!         while let Some(event) = completion.next_event() {
//!             match event {
//!                 Event::Token(t) => print!("{}", t.ch),
//!                 Event::Done(resp) => println!("  [{} tok]", resp.tokens_generated),
//!                 Event::Cancelled { reason, .. } => println!("  [{reason}]"),
//!             }
//!         }
//!     }
//! }
//! let metrics = server.shutdown();
//! println!("{}", metrics.summary());
//! # }
//! ```
//!
//! The serving hot path must not panic: a worker panic kills every
//! in-flight request at once, where a typed error retires exactly one
//! (`CancelReason::Backend`). `aasvd-lint`'s `serve-unwrap` rule and the
//! clippy lints below enforce this for all non-test code in this tree;
//! test modules opt back in with explicit `#[allow]`s.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod http;
pub mod kv_pool;
pub mod metrics;
pub mod request;

pub use backend::{
    CompressedBackend, DenseBackend, ModelBackend, Prefill, QuantizedBackend, ServedModel,
    Session, SyntheticBackend,
};
pub use engine::{Completion, DecodeMode, Server, ServerOptions, Submitter, WaitError};
pub use http::{HttpOptions, HttpServer};
pub use kv_pool::{KvPoolStats, PagedKvOptions, PagedState, PrefixCache};
pub use metrics::ServeMetrics;
pub use request::{
    CancelReason, Event, GenParams, GenRequest, GenResponse, SubmitError, TokenEvent,
};
