//! KV-cache exactness: cached incremental decode must be **bitwise
//! identical** to full-prefix recompute — at the forward level (dense and
//! low-rank pure-Rust paths), at the backend level (prefill/decode_step vs
//! the oracle for all three backends), and through the engine across
//! multi-request batches with staggered admission and cancellation.
//! Artifact-free: runs everywhere.

use aasvd::model::forward::{model_forward, model_forward_step, KvCache};
use aasvd::model::init::init_params;
use aasvd::model::lowrank::{
    exact_factors, model_lr_forward, model_lr_forward_step, BlockFactors,
};
use aasvd::model::paged_kv::{KvBlockPool, PagedKvCache};
use aasvd::model::{Config, FlatStore};
use aasvd::serve::{
    CancelReason, CompressedBackend, DecodeMode, DenseBackend, GenParams, ModelBackend,
    PagedKvOptions, Prefill, ServeMetrics, ServedModel, Server, ServerOptions,
    SyntheticBackend, WaitError,
};
use aasvd::util::rng::Rng;

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: logit {i} differs: {x} vs {y}"
        );
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0
}

fn tiny() -> Config {
    Config::builtin("tiny").unwrap()
}

fn truncated_blocks(cfg: &Config, params: &FlatStore) -> Vec<BlockFactors> {
    let mut blocks: Vec<BlockFactors> = (0..cfg.n_layers)
        .map(|i| exact_factors(cfg, params, i))
        .collect();
    // truncate some ranks so the masked low-rank path is exercised
    for bf in blocks.iter_mut() {
        bf.set_rank("wk", 6);
        bf.set_rank("w_gate", 9);
    }
    blocks
}

/// Dense forward: every cached step reproduces the last logits row of the
/// full-prefix forward, bit for bit, past the old decode window length.
#[test]
fn dense_cached_steps_match_full_recompute_bitwise() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(21));
    let mut rng = Rng::new(22);
    let n = 2 * cfg.seq + 3;
    let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();
    let mut cache = KvCache::new(cfg.n_layers);
    for (p, &tok) in tokens.iter().enumerate() {
        let step = model_forward_step(&cfg, &params, &mut cache, tok);
        let full = model_forward(&cfg, &params, &tokens[..=p], p + 1);
        assert_bits_eq(&step, &full[p * cfg.vocab..], &format!("dense pos {p}"));
    }
    assert_eq!(cache.len, n);
    assert!(cache.bytes() > 0);
}

/// Low-rank forward with truncated rank masks: same bitwise contract.
#[test]
fn lowrank_cached_steps_match_full_recompute_bitwise() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(23));
    let blocks = truncated_blocks(&cfg, &params);
    let mut rng = Rng::new(24);
    let n = cfg.seq + 5;
    let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();
    let mut cache = KvCache::new(cfg.n_layers);
    for (p, &tok) in tokens.iter().enumerate() {
        let step = model_lr_forward_step(&cfg, &params, &blocks, &mut cache, tok);
        let full = model_lr_forward(&cfg, &params, &blocks, &tokens[..=p], p + 1);
        assert_bits_eq(&step, &full[p * cfg.vocab..], &format!("lowrank pos {p}"));
    }
}

/// Paged forward: walking KV through fixed-size blocks must be bitwise
/// identical to the contiguous dense cache at every step — paging changes
/// where a row lives, never a float operation. Dense and low-rank paths,
/// with a block size that forces mid-sequence block boundaries.
#[test]
fn paged_forward_steps_match_dense_cache_bitwise() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(25));
    let blocks = truncated_blocks(&cfg, &params);
    let mut rng = Rng::new(26);
    let bt = 4usize;
    let n = 2 * cfg.seq + 3;
    let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();
    let pool = KvBlockPool::new(256, bt, cfg.d_model);

    let mut dense = KvCache::new(cfg.n_layers);
    let mut paged = PagedKvCache::new(cfg.n_layers, bt);
    let mut lr_dense = KvCache::new(cfg.n_layers);
    let mut lr_paged = PagedKvCache::new(cfg.n_layers, bt);
    for (p, &tok) in tokens.iter().enumerate() {
        paged.reserve_append(&mut || pool.try_alloc()).unwrap();
        let got = model_forward_step(&cfg, &params, &mut paged, tok);
        let want = model_forward_step(&cfg, &params, &mut dense, tok);
        assert_bits_eq(&got, &want, &format!("paged dense pos {p}"));

        lr_paged.reserve_append(&mut || pool.try_alloc()).unwrap();
        let got = model_lr_forward_step(&cfg, &params, &blocks, &mut lr_paged, tok);
        let want = model_lr_forward_step(&cfg, &params, &blocks, &mut lr_dense, tok);
        assert_bits_eq(&got, &want, &format!("paged lowrank pos {p}"));
    }
    assert_eq!(paged.len, n);
    assert_eq!(paged.blocks_referenced(), cfg.n_layers * n.div_ceil(bt));
    drop(paged);
    drop(lr_paged);
    assert_eq!(pool.in_use(), 0, "paged caches must free every block");
}

/// Shared-prefix decode: a cache that *adopts* another session's full
/// prefix blocks (copy-on-write, zero recompute) must continue bitwise
/// identical to a cold prefill of the whole sequence. This is the
/// hard guarantee the radix prefix cache rests on.
#[test]
fn paged_shared_prefix_is_bitwise_equal_to_cold_prefill() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(27));
    let mut rng = Rng::new(28);
    let bt = 4usize;
    let shared: Vec<u32> = (0..2 * bt).map(|_| rng.below(cfg.vocab) as u32).collect();
    let tail_a: Vec<u32> = (0..5).map(|_| rng.below(cfg.vocab) as u32).collect();
    let tail_b: Vec<u32> = (0..7).map(|_| rng.below(cfg.vocab) as u32).collect();
    let pool = KvBlockPool::new(256, bt, cfg.d_model);

    // session A: cold prefill over shared + tail_a
    let mut a = PagedKvCache::new(cfg.n_layers, bt);
    for &tok in shared.iter().chain(&tail_a) {
        a.reserve_append(&mut || pool.try_alloc()).unwrap();
        model_forward_step(&cfg, &params, &mut a, tok);
    }

    // session B adopts A's two full prefix blocks per layer, then walks
    // only its own tail — the shared span costs zero forward passes
    let mut b = PagedKvCache::new(cfg.n_layers, bt);
    for (l, layer) in b.layers.iter_mut().enumerate() {
        layer.adopt_prefix(&a.layers[l].blocks[..2]);
    }
    b.len = shared.len();
    let mut logits_b = Vec::new();
    for &tok in &tail_b {
        b.reserve_append(&mut || pool.try_alloc()).unwrap();
        logits_b = model_forward_step(&cfg, &params, &mut b, tok);
    }

    // cold oracle: the whole B sequence through a fresh dense cache
    let mut cold = KvCache::new(cfg.n_layers);
    let mut logits_cold = Vec::new();
    for &tok in shared.iter().chain(&tail_b) {
        logits_cold = model_forward_step(&cfg, &params, &mut cold, tok);
    }
    assert_bits_eq(&logits_b, &logits_cold, "adopted prefix vs cold prefill");

    // A's own continuation is undisturbed by the sharing (copy-on-write:
    // B's appends went to fresh blocks, never A's)
    let next = rng.below(cfg.vocab) as u32;
    a.reserve_append(&mut || pool.try_alloc()).unwrap();
    let a_step = model_forward_step(&cfg, &params, &mut a, next);
    let mut cold_a = KvCache::new(cfg.n_layers);
    let mut want_a = Vec::new();
    for &tok in shared.iter().chain(&tail_a).chain(std::iter::once(&next)) {
        want_a = model_forward_step(&cfg, &params, &mut cold_a, tok);
    }
    assert_bits_eq(&a_step, &want_a, "sharer session undisturbed");
}

/// Backend level: a prefill + greedy decode_step chain must agree bitwise
/// with the full-prefix oracle at every position.
fn backend_matches_oracle(mut backend: Box<dyn ModelBackend>) {
    let prompt: Vec<i32> = "the cat sat".bytes().map(|b| b as i32).collect();
    let Prefill {
        mut session,
        mut logits,
        ..
    } = backend.prefill(&prompt).unwrap();
    let mut tokens = prompt.clone();
    for step in 0..12 {
        let want = backend.oracle_logits(&tokens).unwrap();
        assert_bits_eq(
            &logits,
            &want,
            &format!("{} step {step}", backend.artifact()),
        );
        let next = argmax(&logits) as i32;
        tokens.push(next);
        logits = backend.decode_step(&mut session, next).unwrap();
    }
    assert_eq!(session.len(), tokens.len());
}

#[test]
fn all_backends_cached_decode_matches_oracle() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(31));
    let blocks = truncated_blocks(&cfg, &params);
    backend_matches_oracle(Box::new(DenseBackend::new(cfg.clone(), params.clone())));
    backend_matches_oracle(Box::new(
        CompressedBackend::new(cfg.clone(), params, blocks).unwrap(),
    ));
    backend_matches_oracle(Box::new(SyntheticBackend::new(cfg)));
}

/// Backend level, paged: prefill + greedy decode through a paged backend
/// (dense and compressed) is bitwise identical to its unpaged twin, and
/// a second prompt sharing a block-aligned prefix reuses cached blocks
/// without changing a single bit of its logits.
fn paged_backend_matches_unpaged(
    mut plain: Box<dyn ModelBackend>,
    mut paged: Box<dyn ModelBackend>,
) {
    assert!(paged.configure_paged(&PagedKvOptions {
        blocks: 128,
        block_tokens: 4,
        prefix_cache: true,
    }));
    // 24-char shared span (6 full blocks) + distinct tails
    let prompts = ["the shared system prompt tail one", "the shared system prompt tail two"];
    for (i, prompt) in prompts.iter().enumerate() {
        let toks: Vec<i32> = prompt.bytes().map(|b| b as i32).collect();
        let pf = paged.prefill(&toks).unwrap();
        let want = plain.prefill(&toks).unwrap();
        assert_bits_eq(&pf.logits, &want.logits, &format!("paged prefill {i}"));
        if i == 0 {
            assert_eq!(pf.reused, 0, "first prompt is a cold prefill");
        } else {
            assert!(pf.reused >= 24, "second prompt reused {} tokens", pf.reused);
        }
        let (mut s, mut logits) = (pf.session, pf.logits);
        let (mut s2, _) = (want.session, want.logits);
        for step in 0..10 {
            let next = argmax(&logits) as i32;
            logits = paged.decode_step(&mut s, next).unwrap();
            let want = plain.decode_step(&mut s2, next).unwrap();
            assert_bits_eq(&logits, &want, &format!("paged decode {i} step {step}"));
        }
    }
    let stats = paged.kv_pool_stats().unwrap();
    assert!(stats.peak <= stats.capacity);
    paged.kv_reset();
}

#[test]
fn paged_backends_match_unpaged_bitwise_with_prefix_reuse() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(33));
    let blocks = truncated_blocks(&cfg, &params);
    paged_backend_matches_unpaged(
        Box::new(DenseBackend::new(cfg.clone(), params.clone())),
        Box::new(DenseBackend::new(cfg.clone(), params.clone())),
    );
    paged_backend_matches_unpaged(
        Box::new(CompressedBackend::new(cfg.clone(), params.clone(), blocks.clone()).unwrap()),
        Box::new(CompressedBackend::new(cfg, params, blocks).unwrap()),
    );
}

/// Run a staggered multi-request batch (2 decode slots, 5 requests with
/// mixed greedy/seeded sampling, plus one cancelled request) and return
/// the completed texts in submission order.
fn decode_texts(cfg: &Config, model: ServedModel, mode: DecodeMode) -> Vec<String> {
    let server = Server::start_with(
        cfg.clone(),
        model,
        ServerOptions {
            max_batch: 2,
            decode: mode,
            ..Default::default()
        },
    );
    let completions: Vec<_> = (0..5)
        .map(|i| {
            server
                .submit(
                    &format!("request {i} says"),
                    GenParams {
                        max_new_tokens: 6 + i,
                        temperature: if i % 2 == 0 { 0.0 } else { 0.9 },
                        top_k: if i % 2 == 0 { None } else { Some(16) },
                        seed: Some(1000 + i as u64),
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    // a cancelled request must not disturb its neighbors' token streams
    let doomed = server
        .submit(
            "doomed",
            GenParams {
                max_new_tokens: 100_000,
                ..Default::default()
            },
        )
        .unwrap();
    doomed.cancel();
    let texts: Vec<String> = completions
        .into_iter()
        .map(|c| c.wait().expect("request completes").text)
        .collect();
    match doomed.wait() {
        Err(WaitError::Cancelled(CancelReason::Client)) => {}
        other => panic!("doomed request: unexpected outcome {other:?}"),
    }
    server.shutdown();
    texts
}

/// Engine level: cached decode and full-prefix recompute generate
/// identical tokens for every request of a staggered continuous batch —
/// dense and compressed backends, greedy and seeded sampling alike.
#[test]
fn engine_cached_decode_matches_recompute_across_batches() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(41));
    let blocks = truncated_blocks(&cfg, &params);

    let cached = decode_texts(&cfg, ServedModel::Dense(params.clone()), DecodeMode::Cached);
    let recomputed =
        decode_texts(&cfg, ServedModel::Dense(params.clone()), DecodeMode::Recompute);
    assert_eq!(cached, recomputed, "dense cached vs recompute");
    assert_eq!(cached.len(), 5);

    let cached = decode_texts(
        &cfg,
        ServedModel::Compressed(params.clone(), blocks.clone()),
        DecodeMode::Cached,
    );
    let recomputed = decode_texts(
        &cfg,
        ServedModel::Compressed(params, blocks),
        DecodeMode::Recompute,
    );
    assert_eq!(cached, recomputed, "compressed cached vs recompute");
}

/// The staggered batch of `decode_texts`, run through a paged server;
/// returns texts + final metrics.
fn paged_decode_texts(
    cfg: &Config,
    model: ServedModel,
    paged_kv: PagedKvOptions,
) -> (Vec<String>, ServeMetrics) {
    let server = Server::start_with(
        cfg.clone(),
        model,
        ServerOptions {
            max_batch: 2,
            paged_kv: Some(paged_kv),
            ..Default::default()
        },
    );
    let completions: Vec<_> = (0..5)
        .map(|i| {
            server
                .submit(
                    &format!("request {i} says"),
                    GenParams {
                        max_new_tokens: 6 + i,
                        temperature: if i % 2 == 0 { 0.0 } else { 0.9 },
                        top_k: if i % 2 == 0 { None } else { Some(16) },
                        seed: Some(1000 + i as u64),
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    let doomed = server
        .submit(
            "doomed",
            GenParams {
                max_new_tokens: 100_000,
                ..Default::default()
            },
        )
        .unwrap();
    doomed.cancel();
    let texts: Vec<String> = completions
        .into_iter()
        .map(|c| c.wait().expect("request completes").text)
        .collect();
    match doomed.wait() {
        Err(WaitError::Cancelled(CancelReason::Client)) => {}
        other => panic!("doomed request: unexpected outcome {other:?}"),
    }
    (texts, server.shutdown())
}

/// Engine level, paged: the same staggered batch (shared `request N`
/// prefix, mixed sampling, a cancelled hog) generates identical tokens
/// through paged KV — prefix cache on and off — as through plain dense
/// caches, the pool stays within budget, and no block leaks at drain.
#[test]
fn engine_paged_decode_matches_plain_across_batches() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(41));
    let blocks = truncated_blocks(&cfg, &params);
    let pk = |prefix_cache| PagedKvOptions {
        blocks: 256,
        block_tokens: 4,
        prefix_cache,
    };
    for label in ["dense", "compressed"] {
        let make = || match label {
            "dense" => ServedModel::Dense(params.clone()),
            _ => ServedModel::Compressed(params.clone(), blocks.clone()),
        };
        let plain = decode_texts(&cfg, make(), DecodeMode::Cached);
        let (paged_on, m_on) = paged_decode_texts(&cfg, make(), pk(true));
        let (paged_off, m_off) = paged_decode_texts(&cfg, make(), pk(false));
        assert_eq!(plain, paged_on, "{label}: paged (prefix on) vs plain texts");
        assert_eq!(plain, paged_off, "{label}: paged (prefix off) vs plain texts");
        // the five prompts share the 8-byte "request " span (2 blocks)
        assert!(
            m_on.prefix_tokens_reused >= 4 * 8,
            "{label}: reused only {} tokens",
            m_on.prefix_tokens_reused
        );
        assert_eq!(m_off.prefix_tokens_reused, 0, "{label}: cache off must not reuse");
        for (mode, m) in [("on", &m_on), ("off", &m_off)] {
            assert_eq!(m.kv_blocks_leaked, 0, "{label} prefix {mode}: leaked blocks");
            assert!(
                m.kv_peak_blocks <= m.kv_blocks_capacity,
                "{label} prefix {mode}: peak {} over budget {}",
                m.kv_peak_blocks,
                m.kv_blocks_capacity
            );
            assert_eq!(m.kv_blocks_capacity, 256, "{label} prefix {mode}");
        }
    }
}

/// Metrics: prefill/decode token counters and KV residency are recorded on
/// the cached path...
#[test]
fn cached_run_counts_prefill_decode_and_cache_bytes() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(51));
    let server = Server::start(cfg.clone(), ServedModel::Dense(params));
    let prompt = "the cat";
    let resp = server
        .submit(
            prompt,
            GenParams {
                max_new_tokens: 5,
                temperature: 0.0,
                ..Default::default()
            },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.tokens_generated, 5);
    let m = server.shutdown();
    assert_eq!(m.prefill_tokens, prompt.len());
    // prefill seeds the first sample; each of the remaining 4 tokens costs
    // one cached decode step
    assert_eq!(m.decode_tokens, 4);
    assert!(m.peak_cache_bytes() > 0.0);
    assert!(m.summary().contains("prefill_toks=7"), "{}", m.summary());
}

/// ...and the recompute oracle path holds no cache at all.
#[test]
fn recompute_run_holds_no_cache() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(52));
    let server = Server::start_with(
        cfg.clone(),
        ServedModel::Dense(params),
        ServerOptions {
            decode: DecodeMode::Recompute,
            ..Default::default()
        },
    );
    let resp = server
        .submit(
            "the cat",
            GenParams {
                max_new_tokens: 5,
                temperature: 0.0,
                ..Default::default()
            },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.tokens_generated, 5);
    let m = server.shutdown();
    assert_eq!(m.prefill_tokens, 7);
    assert_eq!(m.decode_tokens, 4);
    assert_eq!(m.peak_cache_bytes(), 0.0);
}

/// Cancelling a long cached request frees its slot (and cache); later
/// requests decode exactly as if it never ran.
#[test]
fn cancellation_drops_cache_and_preserves_exactness() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(61));
    let p = GenParams {
        max_new_tokens: 6,
        temperature: 0.0,
        ..Default::default()
    };

    // reference text from a clean server
    let clean = Server::start(cfg.clone(), ServedModel::Dense(params.clone()));
    let want = clean.submit("hello", p.clone()).unwrap().wait().unwrap().text;
    clean.shutdown();

    // same request after a cancelled long-running neighbor on a 1-slot server
    let server = Server::start_with(
        cfg.clone(),
        ServedModel::Dense(params),
        ServerOptions {
            max_batch: 1,
            ..Default::default()
        },
    );
    let hog = server
        .submit(
            "x",
            GenParams {
                max_new_tokens: 100_000,
                ..Default::default()
            },
        )
        .unwrap();
    hog.cancel();
    let got = server.submit("hello", p).unwrap().wait().unwrap().text;
    assert_eq!(got, want);
    let m = server.shutdown();
    assert_eq!(m.cancelled, 1);
}
