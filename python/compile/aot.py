"""AOT lowering: JAX entry points -> HLO text artifacts + manifest.

Interchange format is HLO *text* (not serialized HloModuleProto): jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts --configs tiny,base
    python -m compile.aot --out-dir ../artifacts --all

The manifest (manifest.json) tells the Rust runtime everything it needs:
per-config dims, flat parameter/factor layouts (name, shape, offset), and
per-artifact input/output shape+dtype signatures.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import cov as cov_k
from .kernels import lowrank as lowrank_k
from .kernels import attention as attn_k

# Calibration activations are streamed to the covariance kernels in chunks
# of this many tokens (must divide batch*seq of every config; 4*16=64 is the
# smallest batch*seq across configs and divides all others).
COV_CHUNK = 256


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def layout_json(specs) -> list:
    out, off = [], 0
    for name, shape in specs:
        size = int(np.prod(shape))
        out.append({"name": name, "shape": list(shape), "offset": off})
        off += size
    return out


def kernel_entry_points(cfg: M.Config):
    """Pallas-kernel artifacts, shape-specialized per config."""
    d, ff = cfg.d_model, cfg.d_ff
    f32 = jnp.float32

    def S(*shape, dtype=f32):
        return jax.ShapeDtypeStruct(shape, dtype)

    eps = {}
    for dim, tag in ((d, "d"), (ff, "ff")):
        eps[f"cov_accum_{tag}"] = (
            lambda c, x: (cov_k.cov_accum(c, x),),
            [S(dim, dim), S(COV_CHUNK, dim)],
        )
    # cross-covariance X^T X' (anchored objective) — needed for d and ff
    for dim, tag in ((d, "d"), (ff, "ff")):
        eps[f"cross_cov_accum_{tag}"] = (
            lambda c, a, b: (cov_k.cross_cov_accum(c, a, b),),
            [S(dim, dim), S(COV_CHUNK, dim), S(COV_CHUNK, dim)],
        )
    # fused low-rank apply demo (integration test + bench target)
    kq = d // 4
    eps["lowrank_apply"] = (
        lambda u, v, x: (lowrank_k.lowrank_apply(u, v, x),),
        [S(d, kq), S(d, kq), S(COV_CHUNK, d)],
    )
    hd = cfg.head_dim
    eps["attention_head"] = (
        lambda q, k, v: (attn_k.attention_head(q, k, v, 1.0 / np.sqrt(hd)),),
        [S(cfg.seq, hd), S(cfg.seq, hd), S(cfg.seq, hd)],
    )
    return eps


def lower_config(cfg: M.Config, out_dir: str, verbose: bool = True) -> dict:
    eps = dict(M.entry_points(cfg))
    eps.update(kernel_entry_points(cfg))
    artifacts = {}
    for name, (fn, args) in eps.items():
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}__{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": fname,
            "inputs": [spec_json(a) for a in args],
            "outputs": [spec_json(o) for o in lowered.out_info],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        if verbose:
            print(f"  [{cfg.name}] {name:>20s}: {len(text)/1e3:8.1f} kB "
                  f"({time.time()-t0:.1f}s)", flush=True)
    return {
        "dims": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff, "head_dim": cfg.head_dim,
            "batch": cfg.batch, "seq": cfg.seq,
            "refine_batch": cfg.refine_batch, "train_batch": cfg.train_batch,
            "rope_theta": cfg.rope_theta, "cov_chunk": COV_CHUNK,
        },
        "param_layout": layout_json(M.param_specs(cfg)),
        "block_param_layout": layout_json(M.block_param_specs(cfg, 0)),
        "factor_layout": layout_json(M.factor_specs_one_block(cfg)),
        "mask_layout": layout_json(M.mask_specs_one_block(cfg)),
        "block_linears": [
            {"name": n, "out_dim": M.linear_dims(cfg, n)[0],
             "in_dim": M.linear_dims(cfg, n)[1], "kmax": M.kmax(cfg, n)}
            for n in M.BLOCK_LINEARS
        ],
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,base",
                    help="comma-separated config names")
    ap.add_argument("--all", action="store_true",
                    help="lower every config in model.CONFIGS")
    args = ap.parse_args()

    names = list(M.CONFIGS) if args.all else args.configs.split(",")
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"version": 1, "configs": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    t0 = time.time()
    for name in names:
        cfg = M.CONFIGS[name]
        print(f"lowering config '{name}' "
              f"(d={cfg.d_model}, L={cfg.n_layers}, ff={cfg.d_ff})",
              flush=True)
        manifest["configs"][name] = lower_config(cfg, args.out_dir)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {manifest_path} ({time.time()-t0:.0f}s total)")


if __name__ == "__main__":
    main()
