//! `aasvd-serve` — stand-alone HTTP front door over the synthetic
//! backend.
//!
//! Boots the serving engine behind [`HttpServer`], prints the bound
//! address on stdout (one line, `listening <addr>`), then serves until
//! stdin reaches EOF or a `quit` line arrives — at which point it drains,
//! shuts down, and prints the merged [`ServeMetrics`] summary. Driving
//! stdin rather than signals keeps shutdown portable and scriptable:
//!
//! ```text
//! aasvd-serve --addr 127.0.0.1:8080 --step-delay-ms 20 &
//! ... drive it with aasvd-load --target 127.0.0.1:8080 ...
//! echo quit > /proc/<pid>/fd/0   # or close its stdin
//! ```

use aasvd::model::Config;
use aasvd::serve::{
    DecodeMode, HttpOptions, HttpServer, Server, ServerOptions, SyntheticBackend,
};
use aasvd::util::cli::Args;
use anyhow::{anyhow, Context, Result};
use std::io::BufRead;
use std::time::Duration;

fn main() -> Result<()> {
    let args = Args::parse_env(
        "aasvd-serve: stand-alone HTTP front door (synthetic backend; see README \"HTTP API\")",
    );
    let addr = args.str("addr", "127.0.0.1:0", "bind address (port 0 picks a free port)");
    let model = args.str("model", "small", "builtin config name");
    let step_delay_ms = args.f64("step-delay-ms", 0.0, "synthetic per-decode-tick delay");
    let prefill_delay_ms = args.f64("prefill-delay-ms", 0.0, "synthetic per-prefill delay");
    let max_queue = args.usize("max-queue", 4096, "admission queue bound");
    let max_batch = args.usize("max-batch", 4096, "decode-slot cap");
    let max_connections = args.usize("max-connections", 4096, "HTTP connection cap");
    let default_max_tokens = args.usize("default-max-tokens", 32, "max_tokens when omitted");
    args.finish_or_help();

    let cfg = Config::builtin(&model).ok_or_else(|| anyhow!("unknown builtin config '{model}'"))?;
    let backend_cfg = cfg.clone();
    let prefill_delay = Duration::from_secs_f64(prefill_delay_ms.max(0.0) / 1e3);
    let step_delay = Duration::from_secs_f64(step_delay_ms.max(0.0) / 1e3);
    let server = Server::with_backend(
        cfg,
        ServerOptions {
            max_queue,
            max_batch,
            decode: DecodeMode::Cached,
            prefill_per_tick: 0,
            ..Default::default()
        },
        move || {
            Ok(Box::new(SyntheticBackend::with_delays(
                backend_cfg,
                prefill_delay,
                step_delay,
            )))
        },
    );
    let http = HttpServer::start(
        server,
        HttpOptions {
            addr,
            max_connections,
            default_max_tokens,
            ..Default::default()
        },
    )
    .context("start HTTP front door")?;
    println!("listening {}", http.addr());

    // serve until stdin closes or a `quit` line arrives
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        match line {
            Ok(l) if l.trim() == "quit" => break,
            Ok(_) => continue,
            Err(_) => break,
        }
    }
    let metrics = http.shutdown();
    println!("{}", metrics.summary());
    Ok(())
}
