//! Householder QR — used by the randomized-range helper in benches and by
//! tests that need orthonormal bases with a known distribution.

use super::matrix::Matrix;

/// Thin QR: A [m × n] (m >= n) = Q [m × n] R [n × n], R upper-triangular.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr_thin expects m >= n");
    let mut r = a.clone();
    // Householder vectors stored per column
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for j in 0..n {
        // build reflector for column j below the diagonal
        let mut norm = 0.0;
        for i in j..m {
            norm += r.get(i, j) * r.get(i, j);
        }
        let norm = norm.sqrt();
        let mut v = vec![0.0; m - j];
        if norm == 0.0 {
            vs.push(v);
            continue;
        }
        let alpha = if r.get(j, j) >= 0.0 { -norm } else { norm };
        v[0] = r.get(j, j) - alpha;
        for i in (j + 1)..m {
            v[i - j] = r.get(i, j);
        }
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 > 0.0 {
            // apply (I - 2 v v^T / |v|^2) to R[j.., j..]
            for col in j..n {
                let mut dot = 0.0;
                for i in j..m {
                    dot += v[i - j] * r.get(i, col);
                }
                let coef = 2.0 * dot / vnorm2;
                for i in j..m {
                    let val = r.get(i, col) - coef * v[i - j];
                    r.set(i, col, val);
                }
            }
        }
        vs.push(v);
    }
    // form thin Q by applying reflectors to the first n columns of I
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for j in (0..n).rev() {
        let v = &vs[j];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for col in 0..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i - j] * q.get(i, col);
            }
            let coef = 2.0 * dot / vnorm2;
            for i in j..m {
                let val = q.get(i, col) - coef * v[i - j];
                q.set(i, col, val);
            }
        }
    }
    // zero strictly-lower part of thin R
    let mut r_thin = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_thin.set(i, j, r.get(i, j));
        }
    }
    (q, r_thin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(21);
        for (m, n) in [(5, 5), (12, 4), (30, 30), (9, 1)] {
            let a = Matrix::random(m, n, &mut rng, 1.0);
            let (q, r) = qr_thin(&a);
            let rec = q.matmul(&r);
            let rel = rec.sub(&a).frob_norm() / a.frob_norm();
            assert!(rel < 1e-10, "({m},{n}) rel={rel}");
        }
    }

    #[test]
    fn q_orthonormal() {
        let mut rng = Rng::new(22);
        let a = Matrix::random(20, 7, &mut rng, 1.0);
        let (q, _) = qr_thin(&a);
        let qtq = q.matmul_at(&q);
        assert_close(&qtq.data, &Matrix::identity(7).data, 1e-10);
    }

    #[test]
    fn r_upper_triangular() {
        let mut rng = Rng::new(23);
        let a = Matrix::random(10, 6, &mut rng, 1.0);
        let (_, r) = qr_thin(&a);
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn rank_deficient_input_ok() {
        // two identical columns
        let mut a = Matrix::zeros(6, 2);
        for i in 0..6 {
            a.set(i, 0, (i + 1) as f64);
            a.set(i, 1, (i + 1) as f64);
        }
        let (q, r) = qr_thin(&a);
        let rec = q.matmul(&r);
        assert_close(&rec.data, &a.data, 1e-10);
    }
}
