//! Model backends: the decode loop's view of "a thing that turns a packed
//! token batch into logits".
//!
//! The serving engine is generic over [`ModelBackend`], so dense, low-rank
//! compressed, and future quantized/sharded models all slot in without the
//! decode loop knowing the difference. PJRT-backed backends are constructed
//! *on the serve worker thread* (the PJRT client is not Sync) via the
//! factory passed to `Server::with_backend`; [`ServedModel::into_backend`]
//! is that factory for the two built-in model kinds.
//!
//! [`SyntheticBackend`] is an artifact-free stand-in for tests and load
//! experiments: deterministic logits, optional simulated per-step latency.

use crate::model::lowrank::{concat_factors, BlockFactors};
use crate::model::{Config, FlatStore};
use crate::runtime::{Engine, Value};
use anyhow::Result;
use std::time::Duration;

/// A forward-pass provider for the continuous-batching decode loop.
pub trait ModelBackend {
    /// Name of the compiled artifact (or pseudo-artifact) this backend
    /// decodes through; used for logs and metrics labels.
    fn artifact(&self) -> &'static str;

    /// Forward a packed `[batch, seq]` i32 token batch; returns flat
    /// logits of length `batch * seq * vocab`.
    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;
}

/// What the server is serving (the two built-in backend kinds).
pub enum ServedModel {
    Dense(FlatStore),
    Compressed(FlatStore, Vec<BlockFactors>),
}

impl ServedModel {
    /// Artifact the model decodes through.
    pub fn artifact(&self) -> &'static str {
        match self {
            ServedModel::Dense(_) => "model_fwd",
            ServedModel::Compressed(..) => "model_lr_fwd",
        }
    }

    /// Build the PJRT-backed backend for this model. Must run on the serve
    /// worker thread: compiling artifacts creates the PJRT client, which is
    /// not Sync.
    pub fn into_backend(
        self,
        artifact_dir: &str,
        cfg: &Config,
    ) -> Result<Box<dyn ModelBackend>> {
        Ok(match self {
            ServedModel::Dense(params) => {
                Box::new(DenseBackend::new(artifact_dir, cfg.clone(), params)?)
            }
            ServedModel::Compressed(params, blocks) => Box::new(CompressedBackend::new(
                artifact_dir,
                cfg.clone(),
                params,
                &blocks,
            )?),
        })
    }
}

/// Dense model through the `model_fwd` artifact.
pub struct DenseBackend {
    engine: Engine,
    cfg: Config,
    params: FlatStore,
}

impl DenseBackend {
    pub fn new(artifact_dir: &str, cfg: Config, params: FlatStore) -> Result<DenseBackend> {
        let engine = Engine::new(artifact_dir)?;
        engine.warmup(&cfg.name, &["model_fwd"])?;
        Ok(DenseBackend { engine, cfg, params })
    }
}

impl ModelBackend for DenseBackend {
    fn artifact(&self) -> &'static str {
        "model_fwd"
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out = self.engine.run_first(
            &self.cfg.name,
            "model_fwd",
            &[Value::F32(&self.params.data), Value::I32(tokens)],
        )?;
        Ok(out.f32)
    }
}

/// Low-rank compressed model through the `model_lr_fwd` artifact; the
/// per-block factors are concatenated once at construction.
pub struct CompressedBackend {
    engine: Engine,
    cfg: Config,
    params: FlatStore,
    factors: Vec<f32>,
    masks: Vec<f32>,
}

impl CompressedBackend {
    pub fn new(
        artifact_dir: &str,
        cfg: Config,
        params: FlatStore,
        blocks: &[BlockFactors],
    ) -> Result<CompressedBackend> {
        let engine = Engine::new(artifact_dir)?;
        engine.warmup(&cfg.name, &["model_lr_fwd"])?;
        let (factors, masks) = concat_factors(blocks);
        Ok(CompressedBackend {
            engine,
            cfg,
            params,
            factors,
            masks,
        })
    }
}

impl ModelBackend for CompressedBackend {
    fn artifact(&self) -> &'static str {
        "model_lr_fwd"
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out = self.engine.run_first(
            &self.cfg.name,
            "model_lr_fwd",
            &[
                Value::F32(&self.params.data),
                Value::F32(&self.factors),
                Value::F32(&self.masks),
                Value::I32(tokens),
            ],
        )?;
        Ok(out.f32)
    }
}

/// Artifact-free backend for tests and load experiments: at every position
/// the logits deterministically favor `(prev_token + 1) % vocab`, so greedy
/// decoding of prompt "a" yields "bcde…". `step_delay` emulates model
/// latency per forward call.
pub struct SyntheticBackend {
    cfg: Config,
    step_delay: Duration,
}

impl SyntheticBackend {
    pub fn new(cfg: Config) -> SyntheticBackend {
        SyntheticBackend {
            cfg,
            step_delay: Duration::ZERO,
        }
    }

    pub fn with_delay(cfg: Config, step_delay: Duration) -> SyntheticBackend {
        SyntheticBackend { cfg, step_delay }
    }
}

impl ModelBackend for SyntheticBackend {
    fn artifact(&self) -> &'static str {
        "synthetic"
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let (b, t, v) = (self.cfg.batch, self.cfg.seq, self.cfg.vocab);
        anyhow::ensure!(tokens.len() == b * t, "synthetic backend: bad batch shape");
        let mut logits = vec![0f32; b * t * v];
        for pos in 0..b * t {
            let prev = tokens[pos].rem_euclid(v as i32) as usize;
            logits[pos * v + (prev + 1) % v] = 8.0;
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_favors_successor_byte() {
        let cfg = Config::builtin("tiny").unwrap();
        let (b, t, v) = (cfg.batch, cfg.seq, cfg.vocab);
        let mut be = SyntheticBackend::new(cfg);
        let mut tokens = vec![b' ' as i32; b * t];
        tokens[0] = b'a' as i32;
        let logits = be.forward(&tokens).unwrap();
        let row = &logits[..v];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, b'b' as usize);
    }

    #[test]
    fn served_model_artifact_names() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = crate::model::init::init_params(&cfg, &mut crate::util::rng::Rng::new(1));
        assert_eq!(ServedModel::Dense(params.clone()).artifact(), "model_fwd");
        assert_eq!(
            ServedModel::Compressed(params, Vec::new()).artifact(),
            "model_lr_fwd"
        );
    }
}
