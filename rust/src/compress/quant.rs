//! Int8 factor quantization — the storage format behind Dobi-style
//! remapping (paper §B.4, the AA-SVDᵠ rows).
//!
//! We implement the *actual* precision reduction, not just the accounting:
//! factor matrices are quantized per-column (symmetric int8 with f32
//! scales) and dequantized into the padded factor buffers at load time, so
//! the quality effect of remapping is measured, not assumed.

/// A per-column symmetric int8 quantized matrix [rows, cols].
#[derive(Clone, Debug)]
pub struct QuantMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
    pub scales: Vec<f32>, // one per column
}

impl QuantMatrix {
    pub fn quantize(x: &[f32], rows: usize, cols: usize) -> QuantMatrix {
        assert_eq!(x.len(), rows * cols);
        let mut scales = vec![0f32; cols];
        for j in 0..cols {
            let mut mx = 0f32;
            for i in 0..rows {
                mx = mx.max(x[i * cols + j].abs());
            }
            scales[j] = if mx > 0.0 { mx / 127.0 } else { 1.0 };
        }
        let data = (0..rows * cols)
            .map(|idx| {
                let j = idx % cols;
                (x[idx] / scales[j]).round().clamp(-127.0, 127.0) as i8
            })
            .collect();
        QuantMatrix {
            rows,
            cols,
            data,
            scales,
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        self.data
            .iter()
            .enumerate()
            .map(|(idx, &q)| q as f32 * self.scales[idx % self.cols])
            .collect()
    }

    /// Storage in bytes: 1 byte/entry + 4 bytes/column scale.
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len()
    }
}

/// Balance per-component column norms between U and V in place:
/// (u_p, v_p) <- (u_p·s, v_p/s) with s = sqrt(‖v_p‖/‖u_p‖), leaving the
/// product U Vᵀ unchanged. The whitening solve (V = R⁻ᵀ V_k) can give tail
/// components tiny u_p but enormous v_p; int8 quantization error is
/// relative *per column*, so an unbalanced pair converts small relative
/// error into large absolute error in W'. This is the √Σ split Dobi-style
/// remapping stores.
pub fn balance_factor_columns(u: &mut [f32], m: usize, v: &mut [f32], n: usize, k: usize) {
    for p in 0..k {
        // aasvd-lint: allow(float-reduce): sequential column-norm in fixed index order; single-threaded, identical on every run
        let nu: f64 = (0..m).map(|i| (u[i * k + p] as f64).powi(2)).sum::<f64>().sqrt();
        // aasvd-lint: allow(float-reduce): sequential column-norm in fixed index order; single-threaded, identical on every run
        let nv: f64 = (0..n).map(|i| (v[i * k + p] as f64).powi(2)).sum::<f64>().sqrt();
        if nu <= 1e-30 || nv <= 1e-30 {
            continue;
        }
        let s = (nv / nu).sqrt() as f32;
        for i in 0..m {
            u[i * k + p] *= s;
        }
        for i in 0..n {
            v[i * k + p] /= s;
        }
    }
}

/// Quantize+dequantize a factor pair in place (simulating int8 storage),
/// returning the round-trip relative error of each factor.
/// Columns are norm-balanced first (see `balance_factor_columns`).
pub fn quantize_factors_inplace(
    u: &mut [f32],
    m: usize,
    v: &mut [f32],
    n: usize,
    k: usize,
) -> (f64, f64) {
    balance_factor_columns(u, m, v, n, k);
    let qu = QuantMatrix::quantize(u, m, k);
    let qv = QuantMatrix::quantize(v, n, k);
    let du = qu.dequantize();
    let dv = qv.dequantize();
    let eu = rel(u, &du);
    let ev = rel(v, &dv);
    u.copy_from_slice(&du);
    v.copy_from_slice(&dv);
    (eu, ev)
}

fn rel(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0f64;
    let mut den = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (x as f64).powi(2);
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_within_8bit_bound() {
        let mut rng = Rng::new(1);
        let (rows, cols) = (64, 16);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let q = QuantMatrix::quantize(&x, rows, cols);
        let d = q.dequantize();
        // max error per entry <= scale/2
        for j in 0..cols {
            for i in 0..rows {
                let err = (x[i * cols + j] - d[i * cols + j]).abs();
                assert!(err <= q.scales[j] * 0.5 + 1e-7);
            }
        }
        assert!(rel(&x, &d) < 0.01, "rel {}", rel(&x, &d));
    }

    #[test]
    fn zero_matrix_safe() {
        let x = vec![0f32; 12];
        let q = QuantMatrix::quantize(&x, 3, 4);
        assert_eq!(q.dequantize(), x);
    }

    #[test]
    fn per_column_scales_adapt() {
        // column 1 is 100x column 0: per-column scaling keeps both accurate
        let x = vec![0.01f32, 1.0, -0.02, 2.0, 0.015, -1.5];
        let q = QuantMatrix::quantize(&x, 3, 2);
        let d = q.dequantize();
        assert!(rel(&x, &d) < 0.01);
    }

    #[test]
    fn bytes_accounting() {
        let q = QuantMatrix::quantize(&[1.0; 50], 10, 5);
        assert_eq!(q.bytes(), 50 + 20);
    }

    #[test]
    fn balancing_preserves_product_and_fixes_quant_damage() {
        let mut rng = Rng::new(3);
        let (m, n, k) = (16, 16, 6);
        // adversarial imbalance: column p has u ~ 1e-3, v ~ 1e3
        let mut u: Vec<f32> = (0..m * k).map(|_| rng.normal() * 1e-3).collect();
        let mut v: Vec<f32> = (0..n * k).map(|_| rng.normal() * 1e3).collect();
        let dense = |u: &[f32], v: &[f32]| -> Vec<f32> {
            let mut w = vec![0f32; m * n];
            for i in 0..m {
                for p in 0..k {
                    for j in 0..n {
                        w[i * n + j] += u[i * k + p] * v[j * k + p];
                    }
                }
            }
            w
        };
        let before = dense(&u, &v);
        balance_factor_columns(&mut u, m, &mut v, n, k);
        let after = dense(&u, &v);
        assert!(rel(&before, &after) < 1e-5, "balance changed the product");
        // per-column norms now equal
        for p in 0..k {
            let nu: f32 = (0..m).map(|i| u[i * k + p] * u[i * k + p]).sum::<f32>().sqrt();
            let nv: f32 = (0..n).map(|i| v[i * k + p] * v[i * k + p]).sum::<f32>().sqrt();
            assert!((nu / nv - 1.0).abs() < 1e-3);
        }
        // quantization after balancing keeps the product accurate
        let (eu, ev) = quantize_factors_inplace(&mut u, m, &mut v, n, k);
        assert!(eu < 0.02 && ev < 0.02);
        let quantized = dense(&u, &v);
        assert!(rel(&before, &quantized) < 0.05, "rel {}", rel(&before, &quantized));
    }

    #[test]
    fn inplace_returns_errors() {
        let mut rng = Rng::new(2);
        let (m, n, k) = (20, 30, 8);
        let mut u: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let mut v: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let orig_u = u.clone();
        let (eu, ev) = quantize_factors_inplace(&mut u, m, &mut v, n, k);
        assert!(eu > 0.0 && eu < 0.02);
        assert!(ev > 0.0 && ev < 0.02);
        assert_ne!(u, orig_u); // actually changed
    }
}
