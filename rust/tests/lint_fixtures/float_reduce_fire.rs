// aasvd-lint: path=src/refine/fixture.rs

pub fn energy(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x * x).sum::<f32>()
}
