//! Model backends: the decode loop's view of "a thing that turns tokens
//! into logits", redesigned around per-request sessions with KV caches.
//!
//! [`ModelBackend::prefill`] absorbs a whole prompt into a fresh
//! [`Session`] (one O(T²)-attention pass) and returns the logits at its
//! last position; [`ModelBackend::decode_step`] then appends one token per
//! call at O(T) attention cost, reading and extending the session's KV
//! cache. [`ModelBackend::decode_batch`] advances B sessions in one
//! stacked [B, d] forward — the engine's production tick — with every row
//! bitwise identical to its `decode_step` result and per-row failures
//! isolated to their own session (a default implementation loops
//! `decode_step`, so third-party backends keep working).
//! [`ModelBackend::oracle_logits`] keeps the pre-cache decode path
//! — a full-prefix recompute per token — as the bitwise test oracle and
//! bench baseline (driven by `DecodeMode::Recompute`).
//!
//! All built-in backends are artifact-free: the dense, low-rank, and
//! int8-quantized paths decode through the pure-Rust reference forwards
//! (`model::forward`, `model::lowrank`, `model::quant_lowrank`), which
//! the AOT artifacts are validated against, so cached and recomputed
//! logits can be compared bit for bit. The PJRT artifacts stay on the batch-shaped paths
//! (calibration, refinement, eval), where round-tripping a KV cache
//! through host literals per step would dominate the win (see DESIGN.md).
//!
//! [`SyntheticBackend`] is a deterministic stand-in for tests and load
//! experiments: logits favor `(prev_token + 1) % vocab`, with optional
//! simulated per-step latency.

use super::kv_pool::{KvPoolStats, PagedKvOptions, PagedState};
use crate::model::forward::{
    model_forward, model_forward_prefill, model_forward_step, model_forward_step_batch,
    KvCache,
};
use crate::model::lowrank::{
    model_lr_forward, model_lr_forward_prefill, model_lr_forward_step,
    model_lr_forward_step_batch, BlockFactors,
};
use crate::model::paged_kv::PagedKvCache;
use crate::model::quant_lowrank::{
    model_q_forward, model_q_forward_prefill, model_q_forward_step,
    model_q_forward_step_batch, QuantBlockFactors,
};
use crate::model::{Config, FlatStore};
use crate::util::pool::Pool;
use anyhow::Result;
use std::time::Duration;

/// Per-request decode state: created by [`ModelBackend::prefill`],
/// advanced one token at a time by [`ModelBackend::decode_step`], freed by
/// dropping it (the engine drops the slot when a request retires).
pub struct Session {
    state: SessionState,
    /// artifact label of the backend that created this session; checked
    /// by `decode_step` so a session is never advanced by a different
    /// backend kind (which would silently corrupt its cache)
    backend: &'static str,
}

enum SessionState {
    Kv(KvCache),
    /// KV rows on pool blocks, possibly sharing full prefix blocks with
    /// other sessions and the backend's prefix trie (copy-on-write:
    /// shared blocks are never written).
    Paged(PagedKvCache),
    Synthetic { last: i32, len: usize },
}

impl Session {
    /// Tokens absorbed so far (prompt + generated) — derived from the
    /// backend state, so it can never drift out of sync with the cache.
    pub fn len(&self) -> usize {
        match &self.state {
            SessionState::Kv(c) => c.len,
            SessionState::Paged(c) => c.len,
            SessionState::Synthetic { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Label of the backend that created this session.
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Cache-resident bytes held by this session's KV cache.
    pub fn kv_bytes(&self) -> usize {
        match &self.state {
            SessionState::Kv(c) => c.bytes(),
            SessionState::Paged(c) => c.bytes(),
            SessionState::Synthetic { .. } => 0,
        }
    }

    /// Pool blocks this session references across all layers (0 for
    /// non-paged sessions; shared prefix blocks count once per session).
    pub fn kv_blocks(&self) -> usize {
        match &self.state {
            SessionState::Paged(c) => c.blocks_referenced(),
            _ => 0,
        }
    }
}

/// Result of absorbing a prompt: the session plus the logits row
/// ([vocab]) at the prompt's last position — the distribution the first
/// generated token is sampled from.
pub struct Prefill {
    pub session: Session,
    pub logits: Vec<f32>,
    /// Prompt positions whose KV rows came from the prefix cache instead
    /// of being computed (0 without paged KV / on a prefix miss). Always
    /// < prompt length: at least the final token is computed so the
    /// returned logits are real.
    pub reused: usize,
}

/// A forward-pass provider for the continuous-batching decode loop.
///
/// Contract: `prefill(p).logits`, and every subsequent `decode_step`
/// logits row, must be **bitwise identical** to `oracle_logits` over the
/// same token prefix (enforced by tests/kv_cache.rs and the serving
/// bench's pre-timing assert).
pub trait ModelBackend {
    /// Name of the decode path; used for logs and metrics labels.
    fn artifact(&self) -> &'static str;

    /// Absorb `tokens` (a full prompt, never empty) into a fresh session
    /// and return the logits row at its last position.
    fn prefill(&mut self, tokens: &[i32]) -> Result<Prefill>;

    /// Append one token to the session; returns the logits row [vocab]
    /// at the new last position, at O(len) attention cost.
    fn decode_step(&mut self, session: &mut Session, token: i32) -> Result<Vec<f32>>;

    /// Advance B sessions by one token each in a single call — the
    /// engine's production tick. `sessions[i]` absorbs `tokens[i]`;
    /// result row i carries its logits, or the error that retired it.
    ///
    /// Contract:
    /// - **row equality**: every `Ok` row is bitwise identical to the
    ///   `decode_step` (and therefore `oracle_logits`) result over the
    ///   same prefix, for any batch size, composition, or worker count;
    /// - **per-row isolation**: a failing row leaves its own session
    ///   unadvanced and must not disturb any other row;
    /// - lengths must match (`sessions.len() == tokens.len()`), and the
    ///   result has exactly one entry per session, in order.
    ///
    /// The default implementation loops `decode_step`, so third-party
    /// backends keep working unchanged; the built-in backends override it
    /// with one stacked [B, d] forward per call.
    fn decode_batch(
        &mut self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Vec<Result<Vec<f32>>> {
        assert_eq!(sessions.len(), tokens.len(), "one token per session");
        sessions
            .iter_mut()
            .zip(tokens)
            .map(|(session, &token)| self.decode_step(session, token))
            .collect()
    }

    /// Full-prefix recompute oracle (the pre-KV-cache decode path):
    /// logits row [vocab] at the last position of `tokens`.
    fn oracle_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Switch this backend to paged KV allocation (bounded block pool +
    /// optional prefix cache). Returns whether paged KV is supported;
    /// the default `false` keeps dense per-session caches and tells the
    /// engine to skip block-projection admission.
    fn configure_paged(&mut self, _opts: &PagedKvOptions) -> bool {
        false
    }

    /// Pool/prefix counters, when paged KV is configured and supported.
    fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        None
    }

    /// Drop cached prefixes (engine drain/shutdown). With no live
    /// sessions, pool residency after this call must be zero — anything
    /// else is a block leak.
    fn kv_reset(&mut self) {}
}

/// Prefill `toks` on paged storage: adopt the longest cached prefix,
/// compute the remaining positions through `step` (the same
/// single-position kernel decode uses, so prefill is bitwise identical
/// to a cold dense prefill by construction), and publish the prompt's
/// full chunks for future reuse. Block reservation happens here, outside
/// the banded kernels.
fn paged_prefill(
    ps: &mut PagedState,
    n_layers: usize,
    toks: &[u32],
    step: &mut dyn FnMut(&mut PagedKvCache, u32) -> Vec<f32>,
) -> Result<(PagedKvCache, usize, Vec<f32>)> {
    let (mut cache, reused) = ps.start_session(n_layers, toks);
    let mut logits = Vec::new();
    for &tok in &toks[reused..] {
        cache.reserve_append(&mut || ps.alloc_evicting())?;
        logits = step(&mut cache, tok);
    }
    ps.register(toks, &cache);
    Ok((cache, reused, logits))
}

/// A session may only be advanced by the backend kind that created it —
/// advancing e.g. a dense session with the low-rank step would silently
/// corrupt the cache and break the bitwise-oracle contract.
fn ensure_owner(session: &Session, artifact: &'static str) -> Result<()> {
    anyhow::ensure!(
        session.backend == artifact,
        "session belongs to backend '{}', not '{artifact}'",
        session.backend
    );
    Ok(())
}

/// A `decode_batch` split into the rows a KV-cached backend can advance
/// (stacked caches + wrapped tokens) and the rows already resolved to
/// per-row errors (foreign owner, non-KV state).
struct KvBatch<'a> {
    /// per-row slots; `None` rows are filled from the stacked forwards
    out: Vec<Option<Result<Vec<f32>>>>,
    /// original row index of each stacked dense cache
    rows: Vec<usize>,
    caches: Vec<&'a mut KvCache>,
    toks: Vec<u32>,
    /// original row index of each stacked paged cache
    paged_rows: Vec<usize>,
    paged_caches: Vec<&'a mut PagedKvCache>,
    paged_toks: Vec<u32>,
}

/// Validate a batch row by row — owner tag and KV state, the same checks
/// `decode_step` runs — resolving bad rows to errors without touching
/// their sessions, so one foreign or corrupt session never poisons the
/// stacked pass for the rest (the per-row isolation half of the
/// `decode_batch` contract).
fn partition_kv_batch<'a>(
    artifact: &'static str,
    vocab: usize,
    sessions: &'a mut [&mut Session],
    tokens: &[i32],
) -> KvBatch<'a> {
    assert_eq!(sessions.len(), tokens.len(), "one token per session");
    let mut batch = KvBatch {
        out: (0..sessions.len()).map(|_| None).collect(),
        rows: Vec::with_capacity(sessions.len()),
        caches: Vec::with_capacity(sessions.len()),
        toks: Vec::with_capacity(sessions.len()),
        paged_rows: Vec::new(),
        paged_caches: Vec::new(),
        paged_toks: Vec::new(),
    };
    for (i, session) in sessions.iter_mut().enumerate() {
        if let Err(e) = ensure_owner(session, artifact) {
            batch.out[i] = Some(Err(e));
            continue;
        }
        match &mut session.state {
            SessionState::Kv(cache) => {
                batch.rows.push(i);
                batch.toks.push(tokens[i].rem_euclid(vocab as i32) as u32);
                batch.caches.push(cache);
            }
            SessionState::Paged(cache) => {
                batch.paged_rows.push(i);
                batch.paged_toks.push(tokens[i].rem_euclid(vocab as i32) as u32);
                batch.paged_caches.push(cache);
            }
            _ => {
                batch.out[i] = Some(Err(anyhow::anyhow!(
                    "session does not belong to a KV-cached backend"
                )));
            }
        }
    }
    batch
}

/// Reserve tail blocks for every paged row in the batch, splitting it
/// into the rows the stacked pass can advance and the rows resolved to a
/// per-row error right here (pool pressure with nothing evictable, or a
/// paged session reaching a backend with no pool — per-row isolation:
/// the failed session is left unadvanced, the rest stack normally).
/// Allocation stays outside the banded kernels, on the engine thread.
#[allow(clippy::type_complexity)]
fn reserve_paged_rows<'a>(
    paged: &mut Option<PagedState>,
    out: &mut [Option<Result<Vec<f32>>>],
    rows: Vec<usize>,
    caches: Vec<&'a mut PagedKvCache>,
    toks: Vec<u32>,
) -> (Vec<usize>, Vec<&'a mut PagedKvCache>, Vec<u32>) {
    let mut ready_rows = Vec::with_capacity(rows.len());
    let mut ready_caches = Vec::with_capacity(rows.len());
    let mut ready_toks = Vec::with_capacity(rows.len());
    for ((i, cache), tok) in rows.into_iter().zip(caches).zip(toks) {
        match paged {
            Some(ps) => match cache.reserve_append(&mut || ps.alloc_evicting()) {
                Ok(()) => {
                    ready_rows.push(i);
                    ready_caches.push(cache);
                    ready_toks.push(tok);
                }
                Err(pressure) => out[i] = Some(Err(anyhow::Error::new(pressure))),
            },
            None => {
                out[i] = Some(Err(anyhow::anyhow!(
                    "paged session on a backend without a configured pool"
                )));
            }
        }
    }
    (ready_rows, ready_caches, ready_toks)
}

/// Byte tokens arrive as i32 from the client surface; wrap defensively
/// into the model's vocab (mirrors the synthetic backend's behavior, and
/// keeps cached and oracle paths consistent by construction).
fn as_vocab_tokens(vocab: usize, tokens: &[i32]) -> Vec<u32> {
    tokens
        .iter()
        .map(|&t| t.rem_euclid(vocab as i32) as u32)
        .collect()
}

/// What the server is serving (the built-in backend kinds).
pub enum ServedModel {
    Dense(FlatStore),
    Compressed(FlatStore, Vec<BlockFactors>),
    Quantized(FlatStore, Vec<QuantBlockFactors>),
}

impl ServedModel {
    /// Decode-path label of the backend this model builds.
    pub fn artifact(&self) -> &'static str {
        match self {
            ServedModel::Dense(_) => "dense_kv",
            ServedModel::Compressed(..) => "lowrank_kv",
            ServedModel::Quantized(..) => "quant_kv",
        }
    }

    /// Build the KV-cached backend for this model.
    pub fn into_backend(self, cfg: &Config) -> Result<Box<dyn ModelBackend>> {
        Ok(match self {
            ServedModel::Dense(params) => {
                Box::new(DenseBackend::new(cfg.clone(), params))
            }
            ServedModel::Compressed(params, blocks) => {
                Box::new(CompressedBackend::new(cfg.clone(), params, blocks)?)
            }
            ServedModel::Quantized(params, blocks) => {
                Box::new(QuantizedBackend::new(cfg.clone(), params, blocks)?)
            }
        })
    }
}

/// Dense model through the KV-cached pure-Rust forward.
pub struct DenseBackend {
    cfg: Config,
    params: FlatStore,
    /// `Some` after `configure_paged`: sessions live on pool blocks and
    /// share prompt prefixes through the trie.
    paged: Option<PagedState>,
}

impl DenseBackend {
    pub fn new(cfg: Config, params: FlatStore) -> DenseBackend {
        DenseBackend {
            cfg,
            params,
            paged: None,
        }
    }
}

impl ModelBackend for DenseBackend {
    fn artifact(&self) -> &'static str {
        "dense_kv"
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Prefill> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let artifact = self.artifact();
        let DenseBackend { cfg, params, paged } = self;
        let toks = as_vocab_tokens(cfg.vocab, tokens);
        if let Some(ps) = paged {
            let (cache, reused, logits) =
                paged_prefill(ps, cfg.n_layers, &toks, &mut |cache, tok| {
                    model_forward_step(cfg, params, cache, tok)
                })?;
            return Ok(Prefill {
                session: Session {
                    state: SessionState::Paged(cache),
                    backend: artifact,
                },
                logits,
                reused,
            });
        }
        let mut cache = KvCache::new(cfg.n_layers);
        let logits = model_forward_prefill(cfg, params, &mut cache, &toks);
        Ok(Prefill {
            session: Session {
                state: SessionState::Kv(cache),
                backend: artifact,
            },
            logits,
            reused: 0,
        })
    }

    fn decode_step(&mut self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        ensure_owner(session, self.artifact())?;
        let DenseBackend { cfg, params, paged } = self;
        let tok = token.rem_euclid(cfg.vocab as i32) as u32;
        match &mut session.state {
            SessionState::Kv(cache) => Ok(model_forward_step(cfg, params, cache, tok)),
            SessionState::Paged(cache) => {
                let Some(ps) = paged else {
                    anyhow::bail!("paged session on a backend without a configured pool");
                };
                cache.reserve_append(&mut || ps.alloc_evicting())?;
                Ok(model_forward_step(cfg, params, cache, tok))
            }
            _ => anyhow::bail!("session does not belong to a KV-cached backend"),
        }
    }

    fn decode_batch(
        &mut self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Vec<Result<Vec<f32>>> {
        let artifact = self.artifact();
        let DenseBackend { cfg, params, paged } = self;
        let KvBatch {
            mut out,
            rows,
            mut caches,
            toks,
            paged_rows,
            paged_caches,
            paged_toks,
        } = partition_kv_batch(artifact, cfg.vocab, sessions, tokens);
        let logits = model_forward_step_batch(cfg, params, &mut caches, &toks, &Pool::auto());
        for (i, row) in rows.into_iter().zip(logits) {
            out[i] = Some(Ok(row));
        }
        let (ready_rows, mut ready_caches, ready_toks) =
            reserve_paged_rows(paged, &mut out, paged_rows, paged_caches, paged_toks);
        let logits =
            model_forward_step_batch(cfg, params, &mut ready_caches, &ready_toks, &Pool::auto());
        for (i, row) in ready_rows.into_iter().zip(logits) {
            out[i] = Some(Ok(row));
        }
        resolve_rows(out)
    }

    fn oracle_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "oracle needs at least one token");
        let toks = as_vocab_tokens(self.cfg.vocab, tokens);
        let all = model_forward(&self.cfg, &self.params, &toks, toks.len());
        Ok(all[(toks.len() - 1) * self.cfg.vocab..].to_vec())
    }

    fn configure_paged(&mut self, opts: &PagedKvOptions) -> bool {
        self.paged = Some(PagedState::new(opts, self.cfg.d_model));
        true
    }

    fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        self.paged.as_ref().map(PagedState::stats)
    }

    fn kv_reset(&mut self) {
        if let Some(ps) = &mut self.paged {
            ps.reset();
        }
    }
}

/// Collapse the partition's `Option` layer: every row is resolved by
/// either the partition pre-pass (foreign/invalid sessions) or the
/// stacked forward. A still-unresolved row is an internal accounting bug;
/// surface it as a per-row error — the engine retires that request
/// through `CancelReason::Backend` — rather than panicking the worker.
fn resolve_rows(out: Vec<Option<Result<Vec<f32>>>>) -> Vec<Result<Vec<f32>>> {
    out.into_iter()
        .map(|r| {
            r.unwrap_or_else(|| {
                Err(anyhow::anyhow!(
                    "decode_batch row missing from the stacked pass"
                ))
            })
        })
        .collect()
}

/// Low-rank compressed model through the KV-cached pure-Rust forward;
/// shares the cached attention kernel with the dense path.
pub struct CompressedBackend {
    cfg: Config,
    params: FlatStore,
    blocks: Vec<BlockFactors>,
    /// `Some` after `configure_paged` (see [`DenseBackend::paged`]).
    paged: Option<PagedState>,
}

impl CompressedBackend {
    pub fn new(
        cfg: Config,
        params: FlatStore,
        blocks: Vec<BlockFactors>,
    ) -> Result<CompressedBackend> {
        anyhow::ensure!(
            blocks.len() == cfg.n_layers,
            "expected {} compressed blocks, got {}",
            cfg.n_layers,
            blocks.len()
        );
        Ok(CompressedBackend {
            cfg,
            params,
            blocks,
            paged: None,
        })
    }
}

impl ModelBackend for CompressedBackend {
    fn artifact(&self) -> &'static str {
        "lowrank_kv"
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Prefill> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let artifact = self.artifact();
        let CompressedBackend {
            cfg,
            params,
            blocks,
            paged,
        } = self;
        let toks = as_vocab_tokens(cfg.vocab, tokens);
        if let Some(ps) = paged {
            let (cache, reused, logits) =
                paged_prefill(ps, cfg.n_layers, &toks, &mut |cache, tok| {
                    model_lr_forward_step(cfg, params, blocks, cache, tok)
                })?;
            return Ok(Prefill {
                session: Session {
                    state: SessionState::Paged(cache),
                    backend: artifact,
                },
                logits,
                reused,
            });
        }
        let mut cache = KvCache::new(cfg.n_layers);
        let logits = model_lr_forward_prefill(cfg, params, blocks, &mut cache, &toks);
        Ok(Prefill {
            session: Session {
                state: SessionState::Kv(cache),
                backend: artifact,
            },
            logits,
            reused: 0,
        })
    }

    fn decode_step(&mut self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        ensure_owner(session, self.artifact())?;
        let CompressedBackend {
            cfg,
            params,
            blocks,
            paged,
        } = self;
        let tok = token.rem_euclid(cfg.vocab as i32) as u32;
        match &mut session.state {
            SessionState::Kv(cache) => {
                Ok(model_lr_forward_step(cfg, params, blocks, cache, tok))
            }
            SessionState::Paged(cache) => {
                let Some(ps) = paged else {
                    anyhow::bail!("paged session on a backend without a configured pool");
                };
                cache.reserve_append(&mut || ps.alloc_evicting())?;
                Ok(model_lr_forward_step(cfg, params, blocks, cache, tok))
            }
            _ => anyhow::bail!("session does not belong to a KV-cached backend"),
        }
    }

    fn decode_batch(
        &mut self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Vec<Result<Vec<f32>>> {
        let artifact = self.artifact();
        let CompressedBackend {
            cfg,
            params,
            blocks,
            paged,
        } = self;
        let KvBatch {
            mut out,
            rows,
            mut caches,
            toks,
            paged_rows,
            paged_caches,
            paged_toks,
        } = partition_kv_batch(artifact, cfg.vocab, sessions, tokens);
        let logits =
            model_lr_forward_step_batch(cfg, params, blocks, &mut caches, &toks, &Pool::auto());
        for (i, row) in rows.into_iter().zip(logits) {
            out[i] = Some(Ok(row));
        }
        let (ready_rows, mut ready_caches, ready_toks) =
            reserve_paged_rows(paged, &mut out, paged_rows, paged_caches, paged_toks);
        let logits = model_lr_forward_step_batch(
            cfg,
            params,
            blocks,
            &mut ready_caches,
            &ready_toks,
            &Pool::auto(),
        );
        for (i, row) in ready_rows.into_iter().zip(logits) {
            out[i] = Some(Ok(row));
        }
        resolve_rows(out)
    }

    fn oracle_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "oracle needs at least one token");
        let toks = as_vocab_tokens(self.cfg.vocab, tokens);
        let all =
            model_lr_forward(&self.cfg, &self.params, &self.blocks, &toks, toks.len());
        Ok(all[(toks.len() - 1) * self.cfg.vocab..].to_vec())
    }

    fn configure_paged(&mut self, opts: &PagedKvOptions) -> bool {
        self.paged = Some(PagedState::new(opts, self.cfg.d_model));
        true
    }

    fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        self.paged.as_ref().map(PagedState::stats)
    }

    fn kv_reset(&mut self) {
        if let Some(ps) = &mut self.paged {
            ps.reset();
        }
    }
}

/// Int8-quantized low-rank model through the KV-cached pure-Rust forward.
/// Factors stay int8 end-to-end — dequantization is fused into the banded
/// kernels (`model::quant_lowrank`) — while the KV cache, paged pool, and
/// prefix trie are the same f32 machinery the other backends use, so
/// paged sessions and prefix reuse work unchanged.
pub struct QuantizedBackend {
    cfg: Config,
    params: FlatStore,
    blocks: Vec<QuantBlockFactors>,
    /// `Some` after `configure_paged` (see [`DenseBackend::paged`]).
    paged: Option<PagedState>,
}

impl QuantizedBackend {
    pub fn new(
        cfg: Config,
        params: FlatStore,
        blocks: Vec<QuantBlockFactors>,
    ) -> Result<QuantizedBackend> {
        anyhow::ensure!(
            blocks.len() == cfg.n_layers,
            "expected {} quantized blocks, got {}",
            cfg.n_layers,
            blocks.len()
        );
        Ok(QuantizedBackend {
            cfg,
            params,
            blocks,
            paged: None,
        })
    }
}

impl ModelBackend for QuantizedBackend {
    fn artifact(&self) -> &'static str {
        "quant_kv"
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Prefill> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let artifact = self.artifact();
        let QuantizedBackend {
            cfg,
            params,
            blocks,
            paged,
        } = self;
        let toks = as_vocab_tokens(cfg.vocab, tokens);
        if let Some(ps) = paged {
            let (cache, reused, logits) =
                paged_prefill(ps, cfg.n_layers, &toks, &mut |cache, tok| {
                    model_q_forward_step(cfg, params, blocks, cache, tok)
                })?;
            return Ok(Prefill {
                session: Session {
                    state: SessionState::Paged(cache),
                    backend: artifact,
                },
                logits,
                reused,
            });
        }
        let mut cache = KvCache::new(cfg.n_layers);
        let logits = model_q_forward_prefill(cfg, params, blocks, &mut cache, &toks);
        Ok(Prefill {
            session: Session {
                state: SessionState::Kv(cache),
                backend: artifact,
            },
            logits,
            reused: 0,
        })
    }

    fn decode_step(&mut self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        ensure_owner(session, self.artifact())?;
        let QuantizedBackend {
            cfg,
            params,
            blocks,
            paged,
        } = self;
        let tok = token.rem_euclid(cfg.vocab as i32) as u32;
        match &mut session.state {
            SessionState::Kv(cache) => {
                Ok(model_q_forward_step(cfg, params, blocks, cache, tok))
            }
            SessionState::Paged(cache) => {
                let Some(ps) = paged else {
                    anyhow::bail!("paged session on a backend without a configured pool");
                };
                cache.reserve_append(&mut || ps.alloc_evicting())?;
                Ok(model_q_forward_step(cfg, params, blocks, cache, tok))
            }
            _ => anyhow::bail!("session does not belong to a KV-cached backend"),
        }
    }

    fn decode_batch(
        &mut self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Vec<Result<Vec<f32>>> {
        let artifact = self.artifact();
        let QuantizedBackend {
            cfg,
            params,
            blocks,
            paged,
        } = self;
        let KvBatch {
            mut out,
            rows,
            mut caches,
            toks,
            paged_rows,
            paged_caches,
            paged_toks,
        } = partition_kv_batch(artifact, cfg.vocab, sessions, tokens);
        let logits =
            model_q_forward_step_batch(cfg, params, blocks, &mut caches, &toks, &Pool::auto());
        for (i, row) in rows.into_iter().zip(logits) {
            out[i] = Some(Ok(row));
        }
        let (ready_rows, mut ready_caches, ready_toks) =
            reserve_paged_rows(paged, &mut out, paged_rows, paged_caches, paged_toks);
        let logits = model_q_forward_step_batch(
            cfg,
            params,
            blocks,
            &mut ready_caches,
            &ready_toks,
            &Pool::auto(),
        );
        for (i, row) in ready_rows.into_iter().zip(logits) {
            out[i] = Some(Ok(row));
        }
        resolve_rows(out)
    }

    fn oracle_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "oracle needs at least one token");
        let toks = as_vocab_tokens(self.cfg.vocab, tokens);
        let all =
            model_q_forward(&self.cfg, &self.params, &self.blocks, &toks, toks.len());
        Ok(all[(toks.len() - 1) * self.cfg.vocab..].to_vec())
    }

    fn configure_paged(&mut self, opts: &PagedKvOptions) -> bool {
        self.paged = Some(PagedState::new(opts, self.cfg.d_model));
        true
    }

    fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        self.paged.as_ref().map(PagedState::stats)
    }

    fn kv_reset(&mut self) {
        if let Some(ps) = &mut self.paged {
            ps.reset();
        }
    }
}

/// Artifact-free backend for tests and load experiments: the logits after
/// any prefix deterministically favor `(last_token + 1) % vocab`, so
/// greedy decoding of prompt "a" yields "bcde…". `step_delay` emulates
/// model latency per decode/oracle call and `prefill_delay` per prefill
/// pass (the single-knob [`SyntheticBackend::with_delay`] sets both).
pub struct SyntheticBackend {
    cfg: Config,
    step_delay: Duration,
    prefill_delay: Duration,
}

impl SyntheticBackend {
    pub fn new(cfg: Config) -> SyntheticBackend {
        SyntheticBackend {
            cfg,
            step_delay: Duration::ZERO,
            prefill_delay: Duration::ZERO,
        }
    }

    pub fn with_delay(cfg: Config, step_delay: Duration) -> SyntheticBackend {
        // historical semantics: one knob paces prefill and decode alike
        SyntheticBackend {
            cfg,
            step_delay,
            prefill_delay: step_delay,
        }
    }

    /// Split pacing: `prefill_delay` per prefill pass, `step_delay` per
    /// decode/oracle call (paid once per batch on the batched path). The
    /// HTTP load harness uses a free prefill with a real step delay so
    /// admission rate and token pacing can be tuned independently.
    pub fn with_delays(
        cfg: Config,
        prefill_delay: Duration,
        step_delay: Duration,
    ) -> SyntheticBackend {
        SyntheticBackend {
            cfg,
            step_delay,
            prefill_delay,
        }
    }

    fn logits_after(&self, last: i32) -> Vec<f32> {
        let v = self.cfg.vocab;
        let mut logits = vec![0f32; v];
        let prev = last.rem_euclid(v as i32) as usize;
        logits[(prev + 1) % v] = 8.0;
        logits
    }

    fn simulate_latency(&self) {
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
    }

    fn simulate_prefill_latency(&self) {
        if !self.prefill_delay.is_zero() {
            std::thread::sleep(self.prefill_delay);
        }
    }

    /// Advance one session without the simulated latency (shared by
    /// `decode_step`, which pays the delay per call, and `decode_batch`,
    /// which pays it once per batch).
    fn advance(&self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        ensure_owner(session, self.artifact())?;
        let SessionState::Synthetic { last, len } = &mut session.state else {
            anyhow::bail!("session does not belong to the synthetic backend");
        };
        *last = token;
        *len += 1;
        Ok(self.logits_after(token))
    }
}

impl ModelBackend for SyntheticBackend {
    fn artifact(&self) -> &'static str {
        "synthetic"
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Prefill> {
        let Some(&last) = tokens.last() else {
            anyhow::bail!("prefill needs at least one token");
        };
        self.simulate_prefill_latency();
        Ok(Prefill {
            session: Session {
                state: SessionState::Synthetic {
                    last,
                    len: tokens.len(),
                },
                backend: self.artifact(),
            },
            logits: self.logits_after(last),
            reused: 0,
        })
    }

    fn decode_step(&mut self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        // validate before sleeping: a foreign session must fail
        // immediately, not after a simulated model latency
        ensure_owner(session, self.artifact())?;
        anyhow::ensure!(
            matches!(session.state, SessionState::Synthetic { .. }),
            "session does not belong to the synthetic backend"
        );
        self.simulate_latency();
        self.advance(session, token)
    }

    /// The whole batch shares one simulated model latency — the synthetic
    /// stand-in for a stacked forward amortizing per-call cost over B
    /// rows — while each row advances exactly as `decode_step` would.
    fn decode_batch(
        &mut self,
        sessions: &mut [&mut Session],
        tokens: &[i32],
    ) -> Vec<Result<Vec<f32>>> {
        assert_eq!(sessions.len(), tokens.len(), "one token per session");
        if !sessions.is_empty() {
            self.simulate_latency();
        }
        sessions
            .iter_mut()
            .zip(tokens)
            .map(|(session, &token)| self.advance(session, token))
            .collect()
    }

    fn oracle_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let Some(&last) = tokens.last() else {
            anyhow::bail!("oracle needs at least one token");
        };
        self.simulate_latency();
        Ok(self.logits_after(last))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::util::rng::Rng;

    fn argmax(xs: &[f32]) -> usize {
        xs.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0
    }

    #[test]
    fn synthetic_favors_successor_byte() {
        let cfg = Config::builtin("tiny").unwrap();
        let mut be = SyntheticBackend::new(cfg);
        let prompt = [b' ' as i32, b'a' as i32];
        let pf = be.prefill(&prompt).unwrap();
        assert_eq!(pf.session.len(), 2);
        assert!(!pf.session.is_empty());
        assert_eq!(pf.session.kv_bytes(), 0);
        assert_eq!(argmax(&pf.logits), b'b' as usize);
    }

    #[test]
    fn synthetic_split_delays_preserve_the_logit_contract() {
        let cfg = Config::builtin("tiny").unwrap();
        let mut be =
            SyntheticBackend::with_delays(cfg, Duration::ZERO, Duration::from_millis(1));
        let pf = be.prefill(&[b'a' as i32]).unwrap();
        assert_eq!(argmax(&pf.logits), b'b' as usize);
        let mut session = pf.session;
        let logits = be.decode_step(&mut session, b'b' as i32).unwrap();
        assert_eq!(argmax(&logits), b'c' as usize);
    }

    #[test]
    fn synthetic_decode_step_tracks_last_token() {
        let cfg = Config::builtin("tiny").unwrap();
        let mut be = SyntheticBackend::new(cfg);
        let Prefill { mut session, .. } = be.prefill(&[b'a' as i32]).unwrap();
        let logits = be.decode_step(&mut session, b'b' as i32).unwrap();
        assert_eq!(argmax(&logits), b'c' as usize);
        assert_eq!(session.len(), 2);
        // the oracle over the same prefix agrees bitwise
        let want = be.oracle_logits(&[b'a' as i32, b'b' as i32]).unwrap();
        assert_eq!(logits, want);
    }

    #[test]
    fn dense_session_holds_cache_bytes() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(1));
        let mut be = DenseBackend::new(cfg.clone(), params);
        let prompt: Vec<i32> = "abc".bytes().map(|b| b as i32).collect();
        let Prefill { mut session, .. } = be.prefill(&prompt).unwrap();
        let bytes_after_prefill = session.kv_bytes();
        assert_eq!(
            bytes_after_prefill,
            3 * cfg.n_layers * 2 * cfg.d_model * 4
        );
        be.decode_step(&mut session, b'd' as i32).unwrap();
        assert_eq!(session.len(), 4);
        assert!(session.kv_bytes() > bytes_after_prefill);
    }

    #[test]
    fn foreign_session_is_rejected() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(2));
        let blocks = vec![crate::model::lowrank::BlockFactors::zeros(&cfg); cfg.n_layers];
        let mut synth = SyntheticBackend::new(cfg.clone());
        let mut dense = DenseBackend::new(cfg.clone(), params.clone());
        let mut compressed = CompressedBackend::new(cfg, params, blocks).unwrap();

        // synthetic session into a KV backend
        let Prefill { mut session, .. } = synth.prefill(&[b'a' as i32]).unwrap();
        assert!(dense.decode_step(&mut session, b'b' as i32).is_err());

        // dense session into the low-rank backend (both are Kv-state, so
        // only the owner tag catches the mix)
        let Prefill { mut session, .. } = dense.prefill(&[b'a' as i32]).unwrap();
        assert_eq!(session.backend(), "dense_kv");
        assert!(compressed.decode_step(&mut session, b'b' as i32).is_err());
        // and the rightful owner still advances it fine afterwards
        assert!(dense.decode_step(&mut session, b'b' as i32).is_ok());
    }

    #[test]
    fn decode_batch_rows_match_decode_step_bitwise() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(7));
        let mut be = DenseBackend::new(cfg.clone(), params.clone());
        let mut twin = DenseBackend::new(cfg, params);
        let prompts = ["one", "two", "three"];
        let mut batched: Vec<Session> = prompts
            .iter()
            .map(|p| {
                let toks: Vec<i32> = p.bytes().map(|b| b as i32).collect();
                be.prefill(&toks).unwrap().session
            })
            .collect();
        let mut solo: Vec<Session> = prompts
            .iter()
            .map(|p| {
                let toks: Vec<i32> = p.bytes().map(|b| b as i32).collect();
                twin.prefill(&toks).unwrap().session
            })
            .collect();
        for step in 0..3i32 {
            let toks: Vec<i32> = (0..3).map(|r| r * 11 + step * 5 + 97).collect();
            let mut refs: Vec<&mut Session> = batched.iter_mut().collect();
            let rows = be.decode_batch(&mut refs, &toks);
            assert_eq!(rows.len(), 3);
            for (r, row) in rows.into_iter().enumerate() {
                let row = row.expect("batched row succeeds");
                let want = twin.decode_step(&mut solo[r], toks[r]).unwrap();
                assert!(
                    row.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "row {r} diverged at step {step}"
                );
            }
        }
        for (a, b) in batched.iter().zip(&solo) {
            assert_eq!(a.len(), b.len());
            assert_eq!(a.kv_bytes(), b.kv_bytes());
        }
    }

    #[test]
    fn decode_batch_isolates_foreign_rows() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(8));
        let mut dense = DenseBackend::new(cfg.clone(), params.clone());
        let mut twin = DenseBackend::new(cfg.clone(), params);
        let mut synth = SyntheticBackend::new(cfg);
        let mut good0 = dense.prefill(&[b'a' as i32]).unwrap().session;
        let mut bad = synth.prefill(&[b'a' as i32]).unwrap().session;
        let mut good1 = dense.prefill(&[b'b' as i32]).unwrap().session;
        let toks = [b'x' as i32, b'y' as i32, b'z' as i32];
        let mut refs: Vec<&mut Session> = vec![&mut good0, &mut bad, &mut good1];
        let rows = dense.decode_batch(&mut refs, &toks);
        assert!(rows[0].is_ok());
        assert!(rows[1].is_err(), "foreign row must fail");
        assert!(rows[2].is_ok());
        // the foreign session was not advanced; the good rows match their
        // sequential twins bitwise
        assert_eq!(bad.len(), 1);
        let mut t0 = twin.prefill(&[b'a' as i32]).unwrap().session;
        let want = twin.decode_step(&mut t0, toks[0]).unwrap();
        let got = rows[0].as_ref().unwrap();
        assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(good0.len(), 2);
        assert_eq!(good1.len(), 2);
    }

    #[test]
    fn decode_batch_empty_is_a_no_op() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(9));
        let blocks =
            vec![crate::model::lowrank::BlockFactors::zeros(&cfg); cfg.n_layers];
        let mut dense = DenseBackend::new(cfg.clone(), params.clone());
        let mut lowr = CompressedBackend::new(cfg.clone(), params, blocks).unwrap();
        let mut synth = SyntheticBackend::new(cfg);
        assert!(dense.decode_batch(&mut [], &[]).is_empty());
        assert!(lowr.decode_batch(&mut [], &[]).is_empty());
        assert!(synth.decode_batch(&mut [], &[]).is_empty());
    }

    #[test]
    fn synthetic_decode_batch_tracks_each_row() {
        let cfg = Config::builtin("tiny").unwrap();
        let mut be = SyntheticBackend::new(cfg);
        let mut s0 = be.prefill(&[b'a' as i32]).unwrap().session;
        let mut s1 = be.prefill(&[b'p' as i32]).unwrap().session;
        let mut refs: Vec<&mut Session> = vec![&mut s0, &mut s1];
        let rows = be.decode_batch(&mut refs, &[b'b' as i32, b'q' as i32]);
        assert_eq!(argmax(rows[0].as_ref().unwrap()), b'c' as usize);
        assert_eq!(argmax(rows[1].as_ref().unwrap()), b'r' as usize);
        assert_eq!(s0.len(), 2);
        assert_eq!(s1.len(), 2);
    }

    #[test]
    fn served_model_artifact_names() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(1));
        assert_eq!(ServedModel::Dense(params.clone()).artifact(), "dense_kv");
        assert_eq!(
            ServedModel::Compressed(params.clone(), Vec::new()).artifact(),
            "lowrank_kv"
        );
        assert_eq!(
            ServedModel::Quantized(params, Vec::new()).artifact(),
            "quant_kv"
        );
    }

    #[test]
    fn compressed_backend_rejects_wrong_block_count() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(3));
        assert!(CompressedBackend::new(cfg.clone(), params.clone(), Vec::new()).is_err());
        assert!(QuantizedBackend::new(cfg, params, Vec::new()).is_err());
    }

    #[test]
    fn quantized_sessions_enforce_ownership() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(21));
        let blocks: Vec<QuantBlockFactors> = (0..cfg.n_layers)
            .map(|i| {
                let bf = crate::model::lowrank::exact_factors(&cfg, &params, i);
                QuantBlockFactors::from_block(&cfg, &bf).unwrap()
            })
            .collect();
        let mut quant = QuantizedBackend::new(cfg.clone(), params.clone(), blocks).unwrap();
        let mut dense = DenseBackend::new(cfg, params);
        let Prefill { mut session, .. } = quant.prefill(&[b'a' as i32]).unwrap();
        assert_eq!(session.backend(), "quant_kv");
        // a dense backend must refuse the quantized session, and vice versa
        assert!(dense.decode_step(&mut session, b'b' as i32).is_err());
        assert!(quant.decode_step(&mut session, b'b' as i32).is_ok());
        let Prefill { mut session, .. } = dense.prefill(&[b'a' as i32]).unwrap();
        assert!(quant.decode_step(&mut session, b'b' as i32).is_err());
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn paged_backend_matches_dense_bitwise_and_reuses_prefixes() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(11));
        let mut plain = DenseBackend::new(cfg.clone(), params.clone());
        let mut paged = DenseBackend::new(cfg, params);
        assert!(paged.configure_paged(&PagedKvOptions {
            blocks: 64,
            block_tokens: 4,
            prefix_cache: true,
        }));
        let prompt: Vec<i32> = "shared system prompt!".bytes().map(|b| b as i32).collect();
        let cold = plain.prefill(&prompt).unwrap();
        let first = paged.prefill(&prompt).unwrap();
        assert_eq!(first.reused, 0, "cold trie cannot reuse");
        assert!(bits_eq(&first.logits, &cold.logits), "paged prefill diverged");
        let second = paged.prefill(&prompt).unwrap();
        assert_eq!(second.reused, 20, "all full chunks of the 21-token prompt reused");
        assert!(bits_eq(&second.logits, &cold.logits), "shared-prefix prefill diverged");
        // decode stays bitwise equal to the dense path
        let mut s_plain = cold.session;
        let mut s_paged = second.session;
        for t in [b'a' as i32, b'b' as i32, b'c' as i32, b'd' as i32, b'e' as i32] {
            let want = plain.decode_step(&mut s_plain, t).unwrap();
            let got = paged.decode_step(&mut s_paged, t).unwrap();
            assert!(bits_eq(&got, &want), "paged decode diverged on token {t}");
        }
        assert_eq!(s_paged.len(), s_plain.len());
        assert!(s_paged.kv_blocks() > 0);
        let stats = paged.kv_pool_stats().unwrap();
        assert!(stats.in_use > 0 && stats.peak <= stats.capacity);
        drop(s_paged);
        drop(first.session);
        paged.kv_reset();
        assert_eq!(paged.kv_pool_stats().unwrap().in_use, 0, "blocks leaked after drain");
    }

    #[test]
    fn paged_decode_batch_rows_match_decode_step_bitwise() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(12));
        let mut be = DenseBackend::new(cfg.clone(), params.clone());
        let mut twin = DenseBackend::new(cfg, params);
        assert!(be.configure_paged(&PagedKvOptions {
            blocks: 64,
            block_tokens: 2,
            prefix_cache: true,
        }));
        assert!(twin.configure_paged(&PagedKvOptions {
            blocks: 64,
            block_tokens: 2,
            prefix_cache: true,
        }));
        let prompts = ["common lead-in, tail A", "common lead-in, tail B", "zzz"];
        let mut batched: Vec<Session> = prompts
            .iter()
            .map(|p| {
                let toks: Vec<i32> = p.bytes().map(|b| b as i32).collect();
                be.prefill(&toks).unwrap().session
            })
            .collect();
        let mut solo: Vec<Session> = prompts
            .iter()
            .map(|p| {
                let toks: Vec<i32> = p.bytes().map(|b| b as i32).collect();
                twin.prefill(&toks).unwrap().session
            })
            .collect();
        for step in 0..5i32 {
            let toks: Vec<i32> = (0..3).map(|r| r * 13 + step * 3 + 65).collect();
            let mut refs: Vec<&mut Session> = batched.iter_mut().collect();
            let rows = be.decode_batch(&mut refs, &toks);
            for (r, row) in rows.into_iter().enumerate() {
                let row = row.expect("paged batched row succeeds");
                let want = twin.decode_step(&mut solo[r], toks[r]).unwrap();
                assert!(bits_eq(&row, &want), "paged row {r} diverged at step {step}");
            }
        }
        drop(batched);
        drop(solo);
        be.kv_reset();
        twin.kv_reset();
        assert_eq!(be.kv_pool_stats().unwrap().in_use, 0);
        assert_eq!(twin.kv_pool_stats().unwrap().in_use, 0);
    }

    #[test]
    fn synthetic_backend_declines_paged_kv() {
        let cfg = Config::builtin("tiny").unwrap();
        let mut be = SyntheticBackend::new(cfg);
        assert!(!be.configure_paged(&PagedKvOptions::default()));
        assert!(be.kv_pool_stats().is_none());
        be.kv_reset(); // default no-op
    }
}
