//! Runtime: PJRT client wrapper that loads and executes the AOT artifacts.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, HostTensor, Value};
pub use manifest::{
    ArtifactSpec, BlockEntry, BlockStatus, ConfigEntry, DType, Manifest, RunManifest,
    TensorSpec, RUN_MANIFEST_VERSION,
};
