//! Load-generation helpers for serving benches: closed-loop and open-loop
//! arrival processes.

use crate::util::rng::Rng;

/// Poisson arrival schedule: returns cumulative arrival times (seconds) for
/// `n` requests at `rate` req/s.
pub fn poisson_arrivals(n: usize, rate: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            // exponential inter-arrival
            let u = 1.0 - rng.f64();
            t += -u.ln() / rate.max(1e-9);
            t
        })
        .collect()
}

/// Deterministic prompt set drawn from the synthetic language.
pub fn bench_prompts(n: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            crate::data::corpus::sentence(&mut rng, crate::data::Domain::Wiki)
                .split('.')
                .next()
                .unwrap_or("the cat")
                .to_string()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_sorted_and_rate_scaled() {
        let a = poisson_arrivals(2000, 10.0, 1);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        let mean_gap = a.last().unwrap() / 2000.0;
        assert!((mean_gap - 0.1).abs() < 0.02, "gap {mean_gap}");
    }

    #[test]
    fn prompts_nonempty_and_deterministic() {
        let a = bench_prompts(5, 3);
        let b = bench_prompts(5, 3);
        assert_eq!(a, b);
        assert!(a.iter().all(|p| !p.is_empty()));
    }
}
