//! Model substrate: configs, parameter stores, reference forwards,
//! low-rank representation, init and tokenizers.

pub mod config;
pub mod forward;
pub mod init;
pub mod lowrank;
pub mod paged_kv;
pub mod params;
pub mod quant_lowrank;
pub mod tokenizer;

pub use config::{Config, BLOCK_LINEARS};
pub use lowrank::BlockFactors;
pub use quant_lowrank::{QuantBlockFactors, QuantLinear};
pub use params::{factor_layout, mask_layout, param_layout, FlatStore, Layout};
