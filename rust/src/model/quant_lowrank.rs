//! Int8-quantized low-rank model: fused-dequant forwards + AAT2 artifacts.
//!
//! The quantized twin of [`super::lowrank`]: each linear stores exact-rank
//! factors U[m,k] / V[n,k] as [`QuantMatrix`] (int8 data + per-column,
//! per-row-group f32 scales) instead of kmax-padded f32 factors with a
//! rank mask. Dequantization is fused into the matmuls — every product
//! reads `q as f32 * scale` in-register, so the fused path is bitwise
//! identical to dequantize-then-f32-kernel (the test oracle), and the
//! banded batch steps inherit the repo-wide thread-count-invariance
//! contract from [`super::forward::qlinear_batch`].
//!
//! Artifacts are AAT2 tensor archives (see `util::io`): int8 factor data
//! rides as i8 records, scales and norm gains as f32, plus a
//! `quant.group_rows` meta scalar recording the group cap the writer
//! quantized under (the cap is policy, not derivable from shapes).

use super::config::{Config, BLOCK_LINEARS};
use super::forward::{
    attention, attention_step, linear, linear_batch, qlinear, rmsnorm, silu, KvSeq,
    KvSeqStore,
};
use super::lowrank::BlockFactors;
use super::params::FlatStore;
use crate::compress::quant::{balance_factor_columns, QuantError, QuantMatrix, QUANT_GROUP_ROWS};
use crate::util::pool::Pool;

/// One quantized linear: exact-rank int8 factors (no mask — the rank is
/// the stored width `k`).
#[derive(Clone, Debug)]
pub struct QuantLinear {
    /// U [m, k] int8 + grouped scales
    pub u: QuantMatrix,
    /// V [n, k] int8 + grouped scales
    pub v: QuantMatrix,
}

impl QuantLinear {
    /// Output dim m, input dim n, rank k.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.u.rows, self.v.rows, self.u.cols)
    }

    /// Stored bytes: int8 payloads + f32 scales of both factors.
    pub fn bytes(&self) -> usize {
        self.u.bytes() + self.v.bytes()
    }

    /// y = U (V^T x) with dequantization fused into both products;
    /// x: [rows, n] -> out: [rows, m]. Bitwise identical to dequantizing
    /// U and V and running the f32 low-rank apply (same index order,
    /// same zero-skip).
    pub fn apply(&self, x: &[f32], out: &mut [f32]) {
        let (m, n, k) = self.dims();
        let rows = x.len() / n;
        assert_eq!(x.len(), rows * n);
        assert_eq!(out.len(), rows * m);
        if k == 0 {
            out.fill(0.0);
            return;
        }
        // z = x V (V stored [n, k] => z_j = sum_i x_i V[i, j]), dequant
        // fused per element: V[i, j] = q * scale, never materialized
        let mut z = vec![0.0f32; rows * k];
        for (xr, zr) in x.chunks_exact(n).zip(z.chunks_exact_mut(k)) {
            for (i, &xv) in xr.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let qrow = &self.v.data[i * k..(i + 1) * k];
                let srow = self.v.scale_row(i);
                for ((zv, &qv), &sv) in zr.iter_mut().zip(qrow).zip(srow) {
                    *zv += xv * (qv as f32 * sv);
                }
            }
        }
        // y = z U^T, dequant fused in the banded int8 kernel
        qlinear(&z, &self.u, out);
    }
}

/// One quantized block: f32 norm gains + int8 factors per linear, in
/// [`BLOCK_LINEARS`] order.
#[derive(Clone, Debug)]
pub struct QuantBlockFactors {
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub linears: Vec<QuantLinear>,
}

impl QuantBlockFactors {
    fn lin(&self, name: &str) -> &QuantLinear {
        match BLOCK_LINEARS.iter().position(|l| *l == name) {
            Some(i) => &self.linears[i],
            None => panic!("unknown linear '{name}'"),
        }
    }

    /// Quantize a solved f32 block at its active ranks: active factor
    /// columns are copied out of the kmax-padded store, norm-balanced
    /// (int8 error is relative per column), then quantized with the
    /// default group policy. Non-finite factors surface as [`QuantError`].
    pub fn from_block(cfg: &Config, bf: &BlockFactors) -> Result<QuantBlockFactors, QuantError> {
        let mut linears = Vec::with_capacity(BLOCK_LINEARS.len());
        for lin in BLOCK_LINEARS {
            let (m, n) = cfg.linear_dims(lin);
            let kmax = cfg.kmax(lin);
            let k = bf.rank(lin);
            let u_full = bf.factors.view(&format!("{lin}.u"));
            let v_full = bf.factors.view(&format!("{lin}.v"));
            let mut u = vec![0.0f32; m * k];
            let mut v = vec![0.0f32; n * k];
            for i in 0..m {
                u[i * k..(i + 1) * k].copy_from_slice(&u_full[i * kmax..i * kmax + k]);
            }
            for i in 0..n {
                v[i * k..(i + 1) * k].copy_from_slice(&v_full[i * kmax..i * kmax + k]);
            }
            balance_factor_columns(&mut u, m, &mut v, n, k);
            linears.push(QuantLinear {
                u: QuantMatrix::quantize(&u, m, k)?,
                v: QuantMatrix::quantize(&v, n, k)?,
            });
        }
        Ok(QuantBlockFactors {
            attn_norm: bf.factors.view("attn_norm").to_vec(),
            mlp_norm: bf.factors.view("mlp_norm").to_vec(),
            linears,
        })
    }

    /// Dequantize back into a kmax-padded [`BlockFactors`] (rank masks
    /// set to the stored widths) — the f32 interop path for eval and
    /// backend-equality tests.
    pub fn to_block(&self, cfg: &Config) -> BlockFactors {
        let mut bf = BlockFactors::zeros(cfg);
        bf.factors
            .view_mut("attn_norm")
            .copy_from_slice(&self.attn_norm);
        bf.factors
            .view_mut("mlp_norm")
            .copy_from_slice(&self.mlp_norm);
        for (lin, ql) in BLOCK_LINEARS.iter().zip(&self.linears) {
            let (m, n, k) = ql.dims();
            let kmax = cfg.kmax(lin);
            let du = ql.u.dequantize();
            let dv = ql.v.dequantize();
            {
                let u = bf.factors.view_mut(&format!("{lin}.u"));
                for i in 0..m {
                    u[i * kmax..i * kmax + k].copy_from_slice(&du[i * k..(i + 1) * k]);
                }
            }
            {
                let v = bf.factors.view_mut(&format!("{lin}.v"));
                for i in 0..n {
                    v[i * kmax..i * kmax + k].copy_from_slice(&dv[i * k..(i + 1) * k]);
                }
            }
            bf.set_rank(lin, k);
        }
        bf
    }

    /// Stored bytes: norm gains (f32) + both quantized factors per linear.
    pub fn bytes(&self) -> usize {
        let mut total = 4 * (self.attn_norm.len() + self.mlp_norm.len());
        for ql in &self.linears {
            total += ql.bytes();
        }
        total
    }
}

/// Quantized block forward (full sequence, no cache) — the quantized twin
/// of [`super::lowrank::block_lr_forward`], minus taps (quantized blocks
/// are a serving format, never a calibration target).
pub fn block_q_forward(cfg: &Config, qb: &QuantBlockFactors, x: &[f32], t: usize) -> Vec<f32> {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let rows = x.len() / d;

    let mut a_in = vec![0.0; x.len()];
    rmsnorm(x, &qb.attn_norm, d, &mut a_in);

    let mut q = vec![0.0; rows * d];
    let mut k = vec![0.0; rows * d];
    let mut v = vec![0.0; rows * d];
    qb.lin("wq").apply(&a_in, &mut q);
    qb.lin("wk").apply(&a_in, &mut k);
    qb.lin("wv").apply(&a_in, &mut v);
    let o_in = attention(cfg, &mut q, &mut k, &v, t);

    let mut attn_out = vec![0.0; rows * d];
    qb.lin("wo").apply(&o_in, &mut attn_out);
    let h: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

    let mut m_in = vec![0.0; h.len()];
    rmsnorm(&h, &qb.mlp_norm, d, &mut m_in);
    let mut gate = vec![0.0; rows * f];
    let mut up = vec![0.0; rows * f];
    qb.lin("w_gate").apply(&m_in, &mut gate);
    qb.lin("w_up").apply(&m_in, &mut up);
    let d_in: Vec<f32> = gate
        .iter()
        .zip(&up)
        .map(|(&gv, &uv)| silu(gv) * uv)
        .collect();
    let mut down = vec![0.0; rows * d];
    qb.lin("w_down").apply(&d_in, &mut down);
    h.iter().zip(&down).map(|(a, b)| a + b).collect()
}

/// One-position quantized block step against the layer's KV cache — the
/// quantized twin of [`super::lowrank::block_lr_forward_step`], sharing
/// the same cached attention kernel.
pub fn block_q_forward_step<K: KvSeq>(
    cfg: &Config,
    qb: &QuantBlockFactors,
    layer: &mut K,
    x: &[f32],
) -> Vec<f32> {
    let (d, f) = (cfg.d_model, cfg.d_ff);

    let mut a_in = vec![0.0; d];
    rmsnorm(x, &qb.attn_norm, d, &mut a_in);

    let mut q = vec![0.0; d];
    let mut k = vec![0.0; d];
    let mut v = vec![0.0; d];
    qb.lin("wq").apply(&a_in, &mut q);
    qb.lin("wk").apply(&a_in, &mut k);
    qb.lin("wv").apply(&a_in, &mut v);
    let o_in = attention_step(cfg, layer, &mut q, &mut k, &v);

    let mut attn_out = vec![0.0; d];
    qb.lin("wo").apply(&o_in, &mut attn_out);
    let h: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

    let mut m_in = vec![0.0; d];
    rmsnorm(&h, &qb.mlp_norm, d, &mut m_in);
    let mut gate = vec![0.0; f];
    let mut up = vec![0.0; f];
    qb.lin("w_gate").apply(&m_in, &mut gate);
    qb.lin("w_up").apply(&m_in, &mut up);
    let d_in: Vec<f32> = gate
        .iter()
        .zip(&up)
        .map(|(&gv, &uv)| silu(gv) * uv)
        .collect();
    let mut down = vec![0.0; d];
    qb.lin("w_down").apply(&d_in, &mut down);
    h.iter().zip(&down).map(|(a, b)| a + b).collect()
}

/// Batched one-position quantized block step — the quantized twin of
/// [`super::lowrank::block_lr_forward_step_batch`]: the batch is cut into
/// row bands on `pool`, stacked fused-dequant projections run through the
/// multi-row [`QuantLinear::apply`] kernel, attention stays a per-session
/// [`attention_step`]. Rows never mix, so each output row is bitwise
/// identical to [`block_q_forward_step`] at any worker count.
pub fn block_q_forward_step_batch<K: KvSeq + Send>(
    cfg: &Config,
    qb: &QuantBlockFactors,
    layers: &mut [&mut K],
    x: &[f32],
    pool: &Pool,
) -> Vec<f32> {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let b = layers.len();
    assert_eq!(x.len(), b * d);
    if b == 0 {
        return Vec::new();
    }

    let mut y = vec![0.0f32; b * d];
    let bands = if pool.threads() <= 1 {
        1
    } else {
        pool.threads().min(b)
    };
    let rows_per = b.div_ceil(bands);
    let jobs: Vec<_> = x
        .chunks(rows_per * d)
        .zip(y.chunks_mut(rows_per * d))
        .zip(layers.chunks_mut(rows_per))
        .map(|((xb, yb), lb)| {
            move || {
                let rb = lb.len();
                let mut a_in = vec![0.0; rb * d];
                rmsnorm(xb, &qb.attn_norm, d, &mut a_in);

                let mut q = vec![0.0; rb * d];
                let mut k = vec![0.0; rb * d];
                let mut v = vec![0.0; rb * d];
                qb.lin("wq").apply(&a_in, &mut q);
                qb.lin("wk").apply(&a_in, &mut k);
                qb.lin("wv").apply(&a_in, &mut v);

                let mut o_in = vec![0.0; rb * d];
                for (r, layer) in lb.iter_mut().enumerate() {
                    let row = attention_step(
                        cfg,
                        layer,
                        &mut q[r * d..(r + 1) * d],
                        &mut k[r * d..(r + 1) * d],
                        &v[r * d..(r + 1) * d],
                    );
                    o_in[r * d..(r + 1) * d].copy_from_slice(&row);
                }

                let mut attn_out = vec![0.0; rb * d];
                qb.lin("wo").apply(&o_in, &mut attn_out);
                let h: Vec<f32> = xb.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

                let mut m_in = vec![0.0; rb * d];
                rmsnorm(&h, &qb.mlp_norm, d, &mut m_in);
                let mut gate = vec![0.0; rb * f];
                let mut up = vec![0.0; rb * f];
                qb.lin("w_gate").apply(&m_in, &mut gate);
                qb.lin("w_up").apply(&m_in, &mut up);
                let d_in: Vec<f32> = gate
                    .iter()
                    .zip(&up)
                    .map(|(&gv, &uv)| silu(gv) * uv)
                    .collect();
                let mut down = vec![0.0; rb * d];
                qb.lin("w_down").apply(&d_in, &mut down);
                for (yv, (hv, dv)) in yb.iter_mut().zip(h.iter().zip(&down)) {
                    *yv = hv + dv;
                }
            }
        })
        .collect();
    pool.run(jobs);
    y
}

/// One KV-cached decode step through the quantized model. Bitwise
/// identical to the last row of [`model_q_forward`] over the same prefix
/// (the cache-exactness contract).
pub fn model_q_forward_step<S: KvSeqStore>(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[QuantBlockFactors],
    cache: &mut S,
    token: u32,
) -> Vec<f32> {
    assert_eq!(blocks.len(), cfg.n_layers);
    assert_eq!(cache.n_layers(), cfg.n_layers);
    let d = cfg.d_model;
    let tok = token as usize;
    assert!(tok < cfg.vocab, "token {tok} out of range");
    let embed = params.view("embed");
    let mut x = embed[tok * d..(tok + 1) * d].to_vec();
    for (blk, qb) in blocks.iter().enumerate() {
        x = block_q_forward_step(cfg, qb, cache.layer_mut(blk), &x);
    }
    cache.advance();
    let mut hn = vec![0.0; d];
    rmsnorm(&x, params.view("final_norm"), d, &mut hn);
    let mut logits = vec![0.0; cfg.vocab];
    linear(&hn, params.view("lm_head"), d, cfg.vocab, &mut logits);
    logits
}

/// Batched KV-cached decode through the quantized model: one stacked
/// [B, d] pass per layer, one logits row per session. Row i is bitwise
/// identical to [`model_q_forward_step`] on cache i with token i, at any
/// pool width.
pub fn model_q_forward_step_batch<S: KvSeqStore>(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[QuantBlockFactors],
    caches: &mut [&mut S],
    tokens: &[u32],
    pool: &Pool,
) -> Vec<Vec<f32>> {
    assert_eq!(blocks.len(), cfg.n_layers);
    assert_eq!(caches.len(), tokens.len());
    let b = tokens.len();
    if b == 0 {
        return Vec::new();
    }
    for c in caches.iter() {
        assert_eq!(c.n_layers(), cfg.n_layers);
    }
    let d = cfg.d_model;
    let embed = params.view("embed");
    let mut x = vec![0.0f32; b * d];
    for (i, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < cfg.vocab, "token {tok} out of range");
        x[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    for (blk, qb) in blocks.iter().enumerate() {
        let mut layers: Vec<&mut S::Layer> =
            caches.iter_mut().map(|c| c.layer_mut(blk)).collect();
        x = block_q_forward_step_batch(cfg, qb, &mut layers, &x, pool);
    }
    for c in caches.iter_mut() {
        c.advance();
    }
    let mut hn = vec![0.0; b * d];
    rmsnorm(&x, params.view("final_norm"), d, &mut hn);
    let mut logits = vec![0.0f32; b * cfg.vocab];
    linear_batch(&hn, params.view("lm_head"), d, cfg.vocab, pool, &mut logits);
    logits.chunks_exact(cfg.vocab).map(|r| r.to_vec()).collect()
}

/// Prefill the quantized model: absorb a whole prompt into `cache`,
/// returning the logits row at its last position.
pub fn model_q_forward_prefill<S: KvSeqStore>(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[QuantBlockFactors],
    cache: &mut S,
    tokens: &[u32],
) -> Vec<f32> {
    assert!(!tokens.is_empty(), "prefill needs at least one token");
    let mut logits = Vec::new();
    for &tok in tokens {
        logits = model_q_forward_step(cfg, params, blocks, cache, tok);
    }
    logits
}

/// Quantized full-model forward (dense embed/head + quantized blocks).
pub fn model_q_forward(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[QuantBlockFactors],
    tokens: &[u32],
    t: usize,
) -> Vec<f32> {
    assert_eq!(blocks.len(), cfg.n_layers);
    let d = cfg.d_model;
    let b = tokens.len() / t;
    let embed = params.view("embed");
    let mut x = vec![0.0f32; b * t * d];
    for (i, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        x[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    for qb in blocks {
        x = block_q_forward(cfg, qb, &x, t);
    }
    let mut hn = vec![0.0; x.len()];
    rmsnorm(&x, params.view("final_norm"), d, &mut hn);
    let mut logits = vec![0.0; b * t * cfg.vocab];
    linear(&hn, params.view("lm_head"), d, cfg.vocab, &mut logits);
    logits
}

/// Save quantized blocks to an AAT2 tensor archive: int8 factor payloads
/// (`{lin}.u_q` / `{lin}.v_q`), f32 scales (`{lin}.u_s` / `{lin}.v_s`),
/// f32 norm gains, and a `quant.group_rows` meta scalar pinning the
/// group cap the writer quantized under.
pub fn save_quant_blocks(
    blocks: &[QuantBlockFactors],
    path: impl AsRef<std::path::Path>,
) -> anyhow::Result<()> {
    use crate::util::io::{Tensor, TensorArchive, TensorI8};
    let mut arch = TensorArchive::new();
    arch.insert(
        "quant.group_rows",
        Tensor::new(vec![1], vec![QUANT_GROUP_ROWS as f32]),
    );
    for (i, b) in blocks.iter().enumerate() {
        arch.insert(
            &format!("blocks.{i}.attn_norm"),
            Tensor::new(vec![b.attn_norm.len()], b.attn_norm.clone()),
        );
        arch.insert(
            &format!("blocks.{i}.mlp_norm"),
            Tensor::new(vec![b.mlp_norm.len()], b.mlp_norm.clone()),
        );
        for (lin, ql) in BLOCK_LINEARS.iter().zip(&b.linears) {
            for (tag, q) in [("u", &ql.u), ("v", &ql.v)] {
                arch.insert_i8(
                    &format!("blocks.{i}.{lin}.{tag}_q"),
                    TensorI8::new(vec![q.rows, q.cols], q.data.clone()),
                );
                arch.insert(
                    &format!("blocks.{i}.{lin}.{tag}_s"),
                    Tensor::new(vec![q.n_groups(), q.cols], q.scales.clone()),
                );
            }
        }
    }
    arch.save(path)
}

/// Load quantized blocks saved by [`save_quant_blocks`], validating
/// shapes against `cfg` and scale layouts against the recorded group cap.
pub fn load_quant_blocks(
    cfg: &Config,
    path: impl AsRef<std::path::Path>,
) -> anyhow::Result<Vec<QuantBlockFactors>> {
    use crate::util::io::TensorArchive;
    use anyhow::{anyhow, bail, ensure};
    let arch = TensorArchive::load(path)?;
    let cap = match arch.get("quant.group_rows").and_then(|t| t.data.first()) {
        Some(&c) if c >= 1.0 && c.fract() == 0.0 => c as usize,
        Some(&c) => bail!("bad quant.group_rows {c}"),
        None => bail!("missing quant.group_rows meta tensor"),
    };
    let d = cfg.d_model;
    let mut out = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let norm = |name: &str| -> anyhow::Result<Vec<f32>> {
            let t = arch
                .get(&format!("blocks.{i}.{name}"))
                .ok_or_else(|| anyhow!("missing block {i} {name}"))?;
            ensure!(t.data.len() == d, "block {i} {name}: {} != d_model", t.data.len());
            Ok(t.data.clone())
        };
        let mut linears = Vec::with_capacity(BLOCK_LINEARS.len());
        for lin in BLOCK_LINEARS {
            let (m, n) = cfg.linear_dims(lin);
            let kmax = cfg.kmax(lin);
            let load_half = |tag: &str, rows: usize| -> anyhow::Result<QuantMatrix> {
                let qn = format!("blocks.{i}.{lin}.{tag}_q");
                let sn = format!("blocks.{i}.{lin}.{tag}_s");
                let q = arch.get_i8(&qn).ok_or_else(|| anyhow!("missing tensor {qn}"))?;
                let s = arch.get(&sn).ok_or_else(|| anyhow!("missing tensor {sn}"))?;
                ensure!(q.dims.len() == 2 && q.dims[0] == rows, "{qn}: bad dims {:?}", q.dims);
                let k = q.dims[1];
                ensure!(k <= kmax, "{qn}: rank {k} exceeds kmax {kmax}");
                let group_rows = rows.min(cap).max(1);
                let n_groups = rows.div_ceil(group_rows);
                ensure!(
                    s.dims == [n_groups, k],
                    "{sn}: dims {:?} != [{n_groups}, {k}] under group cap {cap}",
                    s.dims
                );
                Ok(QuantMatrix {
                    rows,
                    cols: k,
                    group_rows,
                    data: q.data.clone(),
                    scales: s.data.clone(),
                })
            };
            let u = load_half("u", m)?;
            let v = load_half("v", n)?;
            ensure!(
                u.cols == v.cols,
                "block {i} {lin}: u rank {} != v rank {}",
                u.cols,
                v.cols
            );
            linears.push(QuantLinear { u, v });
        }
        out.push(QuantBlockFactors {
            attn_norm: norm("attn_norm")?,
            mlp_norm: norm("mlp_norm")?,
            linears,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::KvCache;
    use crate::model::init::init_params;
    use crate::model::lowrank::{exact_factors, model_lr_forward};
    use crate::testkit::approx::assert_close_f32;
    use crate::util::rng::Rng;

    fn setup() -> (Config, FlatStore, Vec<QuantBlockFactors>) {
        let cfg = Config::builtin("tiny").unwrap();
        let p = init_params(&cfg, &mut Rng::new(11));
        let blocks: Vec<QuantBlockFactors> = (0..cfg.n_layers)
            .map(|i| {
                let mut bf = exact_factors(&cfg, &p, i);
                bf.set_rank("wq", 5);
                bf.set_rank("w_up", 7);
                QuantBlockFactors::from_block(&cfg, &bf).unwrap()
            })
            .collect();
        (cfg, p, blocks)
    }

    #[test]
    fn fused_apply_is_bitwise_equal_to_dequant_oracle() {
        let (_cfg, _p, blocks) = setup();
        let qb = &blocks[0];
        let mut rng = Rng::new(21);
        for lin in BLOCK_LINEARS {
            let ql = qb.lin(lin);
            let (m, n, k) = ql.dims();
            let rows = 3;
            let x: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
            let mut fused = vec![0.0f32; rows * m];
            ql.apply(&x, &mut fused);
            // oracle: dequantize both factors, run the identical f32 loops
            let du = ql.u.dequantize();
            let dv = ql.v.dequantize();
            let mut z = vec![0.0f32; rows * k];
            for (xr, zr) in x.chunks_exact(n).zip(z.chunks_exact_mut(k)) {
                for (i, &xv) in xr.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    for (zv, &vv) in zr.iter_mut().zip(&dv[i * k..(i + 1) * k]) {
                        *zv += xv * vv;
                    }
                }
            }
            let mut oracle = vec![0.0f32; rows * m];
            linear(&z, &du, k, m, &mut oracle);
            for (i, (a, b)) in fused.iter().zip(&oracle).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{lin} out {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn from_block_roundtrips_through_to_block() {
        let (cfg, p, blocks) = setup();
        let bf0 = {
            let mut bf = exact_factors(&cfg, &p, 0);
            bf.set_rank("wq", 5);
            bf.set_rank("w_up", 7);
            bf
        };
        let back = blocks[0].to_block(&cfg);
        for lin in BLOCK_LINEARS {
            assert_eq!(back.rank(lin), bf0.rank(lin), "{lin} rank");
            let w0 = bf0.dense_weight(&cfg, lin);
            let w1 = back.dense_weight(&cfg, lin);
            let num: f64 = w0
                .iter()
                .zip(&w1)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
            let den: f64 = w0.iter().map(|&a| (a as f64).powi(2)).sum::<f64>();
            assert!(
                (num / den.max(1e-300)).sqrt() < 0.05,
                "{lin} quant error too large"
            );
        }
        assert_close_f32(&back.factors.view("attn_norm").to_vec(), &blocks[0].attn_norm, 0.0);
    }

    #[test]
    fn q_cached_step_matches_full_forward_bitwise() {
        let (cfg, p, blocks) = setup();
        let mut rng = Rng::new(18);
        let n = cfg.seq + 2;
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut cache = KvCache::new(cfg.n_layers);
        for (pos, &tok) in tokens.iter().enumerate() {
            let step = model_q_forward_step(&cfg, &p, &blocks, &mut cache, tok);
            let full = model_q_forward(&cfg, &p, &blocks, &tokens[..=pos], pos + 1);
            let want = &full[pos * cfg.vocab..];
            for (i, (a, b)) in step.iter().zip(want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "pos {pos} logit {i}: {a} vs {b}");
            }
        }
        assert_eq!(cache.len, n);
    }

    #[test]
    fn q_batched_step_rows_match_single_steps_bitwise() {
        let (cfg, p, blocks) = setup();
        let b = 3;
        let prompts: Vec<Vec<u32>> = (0..b)
            .map(|r| (0..2 + r).map(|i| ((i * 23 + r * 5) % cfg.vocab) as u32).collect())
            .collect();
        let mut batched: Vec<KvCache> = prompts
            .iter()
            .map(|pr| {
                let mut c = KvCache::new(cfg.n_layers);
                model_q_forward_prefill(&cfg, &p, &blocks, &mut c, pr);
                c
            })
            .collect();
        let mut solo = batched.clone();
        for threads in [1usize, 2, 4] {
            let pool = Pool::exact(threads);
            let toks: Vec<u32> =
                (0..b).map(|r| ((r * 31 + threads * 17) % cfg.vocab) as u32).collect();
            let mut refs: Vec<&mut KvCache> = batched.iter_mut().collect();
            let rows = model_q_forward_step_batch(&cfg, &p, &blocks, &mut refs, &toks, &pool);
            for (r, row) in rows.iter().enumerate() {
                let want = model_q_forward_step(&cfg, &p, &blocks, &mut solo[r], toks[r]);
                for (i, (a, b_)) in row.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b_.to_bits(),
                        "row {r} threads {threads} logit {i}: {a} vs {b_}"
                    );
                }
            }
        }
    }

    #[test]
    fn quant_forward_tracks_f32_lowrank_closely() {
        let (cfg, p, blocks) = setup();
        let f32_blocks: Vec<_> = blocks.iter().map(|qb| qb.to_block(&cfg)).collect();
        let t = cfg.seq;
        let tokens: Vec<u32> = (0..t).map(|i| (i * 7 % cfg.vocab) as u32).collect();
        let ql = model_q_forward(&cfg, &p, &blocks, &tokens, t);
        let fl = model_lr_forward(&cfg, &p, &f32_blocks, &tokens, t);
        // the dequantized f32 model is the same math modulo kmax zero
        // padding, which only ever adds exact zeros
        assert_close_f32(&ql, &fl, 1e-5);
    }

    #[test]
    fn quant_artifact_roundtrips_exactly() {
        let (cfg, _, blocks) = setup();
        let dir = std::env::temp_dir().join("aasvd-quant-lowrank-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("quant.aat");
        save_quant_blocks(&blocks, &path).unwrap();
        let loaded = load_quant_blocks(&cfg, &path).unwrap();
        assert_eq!(loaded.len(), blocks.len());
        for (a, b) in blocks.iter().zip(&loaded) {
            assert_eq!(a.attn_norm, b.attn_norm);
            assert_eq!(a.mlp_norm, b.mlp_norm);
            for (qa, qb) in a.linears.iter().zip(&b.linears) {
                for (ma, mb) in [(&qa.u, &qb.u), (&qa.v, &qb.v)] {
                    assert_eq!(ma.rows, mb.rows);
                    assert_eq!(ma.cols, mb.cols);
                    assert_eq!(ma.group_rows, mb.group_rows);
                    assert_eq!(ma.data, mb.data);
                    assert_eq!(
                        ma.scales.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                        mb.scales.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
                    );
                }
            }
        }
        assert_eq!(blocks[0].bytes(), loaded[0].bytes());
    }

    #[test]
    fn load_rejects_missing_meta() {
        let (cfg, _, blocks) = setup();
        let dir = std::env::temp_dir().join("aasvd-quant-lowrank-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.aat");
        // strip the meta tensor by re-saving a doctored archive
        use crate::util::io::TensorArchive;
        save_quant_blocks(&blocks, &path).unwrap();
        let mut arch = TensorArchive::load(&path).unwrap();
        arch.tensors.remove("quant.group_rows");
        arch.save(&path).unwrap();
        let err = load_quant_blocks(&cfg, &path).unwrap_err();
        assert!(err.to_string().contains("quant.group_rows"), "{err}");
    }
}
