//! Figures 1 & 4: layer-wise error evolution across depth.
//!
//! Paper: LLaMA-7B @ ratio 0.8, MSE + cosine distance between original and
//! compressed outputs for O-proj / down-proj / block outputs, evaluated on
//! held-out WikiText2, for naive SVD vs SVD-LLM vs AA-SVD. Figure 1 is the
//! cosine-distance view with each method's final-layer distortion linked to
//! its perplexity — emitted here as the same series plus the PPL column.

use aasvd::compress::{error::depth_profile, CompressRun, Method, RunOptions};
use aasvd::data::Domain;
use aasvd::eval::{compressed_ppl, display_ppl, Table};
use aasvd::experiments::{setup, Knobs};
use aasvd::util::cli::Args;
use aasvd::util::json::Json;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env("Figures 1+4: depth-wise error profiles");
    let mut knobs = Knobs::parse(&args, "small");
    knobs.ratios = vec![args.f64("ratio", 0.8, "compression ratio")];
    args.finish_or_help();
    let ctx = setup(&knobs)?;
    let ratio = knobs.ratios[0];

    let methods = vec![
        Method::naive_svd(),
        Method::svd_llm(),
        Method::aa_svd(knobs.refine()),
    ];
    // held-out eval data (wiki test)
    let eval = &ctx.eval.iter().find(|(d, _)| *d == Domain::Wiki).unwrap().1;
    let eval: Vec<_> = eval
        .iter()
        .filter(|b| b.real_rows == ctx.cfg.batch)
        .take(4)
        .cloned()
        .collect();

    let mut series = Vec::new();
    let mut table = Table::new(
        &format!("Fig 4 — per-layer errors @ ratio {ratio} (final layer shown)"),
        &["method", "oproj_mse[L]", "oproj_cos[L]", "down_cos[L]", "block_mse[L]", "wiki_ppl"],
    );
    for method in &methods {
        // drive the streaming session directly: the profile needs every
        // block in memory, but the loop still paces and reports per block
        let mut run = CompressRun::new(
            &ctx.engine,
            &ctx.cfg,
            &ctx.params,
            &ctx.calib,
            method,
            ratio,
            RunOptions::in_memory(),
        )?;
        while let Some(o) = run.next_block()? {
            eprintln!(
                "[fig4] {} @ {ratio}: block {}/{} ({:.1}s)",
                method.name,
                o.index + 1,
                o.total,
                o.secs
            );
        }
        let cm = run.into_model()?;
        let prof = depth_profile(&ctx.engine, &ctx.cfg, &ctx.params, &cm.blocks, &eval)?;
        let ppl = compressed_ppl(&ctx.engine, &ctx.cfg, &ctx.params, &cm.blocks, eval.as_slice())?;
        let last = prof.block_mse.len() - 1;
        table.row(vec![
            method.name.clone(),
            format!("{:.2e}", prof.o_proj_mse[last]),
            format!("{:.3}", prof.o_proj_cos[last]),
            format!("{:.3}", prof.down_cos[last]),
            format!("{:.2e}", prof.block_mse[last]),
            display_ppl(ppl),
        ]);
        // full per-layer series to results/
        let j = Json::obj()
            .set("method", method.name.as_str())
            .set("ratio", ratio)
            .set("wiki_ppl", ppl)
            .set("o_proj_mse", prof.o_proj_mse.clone())
            .set("o_proj_cos", prof.o_proj_cos.clone())
            .set("down_mse", prof.down_mse.clone())
            .set("down_cos", prof.down_cos.clone())
            .set("block_mse", prof.block_mse.clone())
            .set("block_cos", prof.block_cos.clone());
        series.push(j);

        // ascii sparkline of block-output cosine distance across depth
        println!(
            "{:>12} block cos across depth: {}",
            method.name,
            sparkline(&prof.block_cos)
        );
    }
    table.emit("fig4_summary")?;
    aasvd::util::io::write_text(
        "results/fig1_fig4_series.json",
        &Json::Arr(series).to_string_pretty(),
    )?;
    Ok(())
}

fn sparkline(xs: &[f64]) -> String {
    const TICKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = xs.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    xs.iter()
        .map(|&x| TICKS[((x / max) * 7.0).round().clamp(0.0, 7.0) as usize])
        .collect()
}
