//! L3 perf: the pure-Rust linalg kernels on compression-realistic shapes
//! (d_model=256, d_ff=704 from `base`; plus the 1k-class sizes), including
//! the banded-parallel kernels at pinned worker counts — the 1-vs-4-thread
//! rows are the scaling record CI's bench-smoke job archives per PR.

use aasvd::bench::Bench;
use aasvd::linalg::{cholesky, eigh, svd_k, Matrix};
use aasvd::util::pool::Pool;
use aasvd::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(1);

    for n in [256usize, 512, 704] {
        let a = Matrix::random(n, n, &mut rng, 1.0);
        let c = Matrix::random(n, n, &mut rng, 1.0);
        let flops = 2.0 * (n as f64).powi(3);
        b.run(&format!("matmul {n}x{n}"), Some(flops), || {
            std::hint::black_box(a.matmul(&c));
        });
    }

    // banded-parallel kernels at pinned widths (ignores AA_SVD_THREADS):
    // same results bitwise, different wall clock
    {
        let n = 512usize;
        let a = Matrix::random(n, n, &mut rng, 1.0);
        let c = Matrix::random(n, n, &mut rng, 1.0);
        let flops = 2.0 * (n as f64).powi(3);
        for threads in [1usize, 2, 4] {
            let pool = Pool::exact(threads);
            b.run(
                &format!("matmul {n}x{n} threads={threads}"),
                Some(flops),
                || {
                    std::hint::black_box(a.matmul_with(&c, &pool));
                },
            );
        }
        for threads in [1usize, 4] {
            let pool = Pool::exact(threads);
            b.run(
                &format!("gram A^T*A {n}x{n} threads={threads}"),
                Some(flops),
                || {
                    std::hint::black_box(a.matmul_at_with(&a, &pool));
                },
            );
            b.run(
                &format!("transpose {n}x{n} threads={threads}"),
                None,
                || {
                    std::hint::black_box(a.transpose_with(&pool));
                },
            );
        }
    }

    for n in [256usize, 704] {
        let s = Matrix::random_spd(n, &mut rng);
        b.run(&format!("cholesky {n}"), Some((n as f64).powi(3) / 3.0), || {
            std::hint::black_box(cholesky(&s).unwrap());
        });
    }

    for n in [128usize, 256] {
        let s = Matrix::random_spd(n, &mut rng);
        b.run(&format!("eigh(jacobi) {n}"), None, || {
            std::hint::black_box(eigh(&s));
        });
    }

    // the actual CompressLayer SVD shapes: M is [m, n] with min side = d
    for (m, n, k) in [(256usize, 256usize, 85usize), (704, 256, 128), (256, 704, 85)] {
        let a = Matrix::random(m, n, &mut rng, 1.0);
        b.run(&format!("svd_k {m}x{n} k={k}"), None, || {
            std::hint::black_box(svd_k(&a, k));
        });
    }
    b.save("linalg");
}
