// aasvd-lint: path=src/model/fixture.rs

pub fn hidden_knob() -> usize {
    // aasvd-lint: allow(env-var): fixture justification — imagine this only tunes logging
    std::env::var("AASVD_FIXTURE_KNOB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}
