//! Deterministic PRNG (PCG64-XSL-RR) + distribution helpers.
//!
//! The offline build has no `rand` crate; this is the single source of
//! randomness for model init, synthetic corpora, task generation and the
//! property-testing kit, so every experiment is reproducible from a seed.

/// PCG64 XSL-RR generator (O'Neill 2014), 128-bit state / 64-bit output.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// Seeded generator; `stream` selects an independent sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive a child RNG (for parallel/streamed generation).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::with_stream(self.next_u64(), tag)
    }

    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
    }

    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // bias is < 2^-40 for the n used in this repo.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (uses one cached value).
    pub fn normal(&mut self) -> f32 {
        // no caching to keep the generator state trivially forkable
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of iid N(0, scale^2) samples.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from softmax(logits / temp) — used by the serving sampler.
    pub fn sample_logits(&mut self, logits: &[f32], temp: f32) -> usize {
        if temp <= 0.0 {
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let ws: Vec<f64> = logits
            .iter()
            .map(|&l| (((l - mx) / temp) as f64).exp())
            .collect();
        self.categorical(&ws)
    }

    /// `sample_logits` restricted to the `k` largest logits
    /// (None or k >= len = unrestricted; greedy when temp <= 0).
    /// O(len) partition, not a full sort — this runs per token on the
    /// serving decode path.
    pub fn sample_logits_topk(&mut self, logits: &[f32], temp: f32, k: Option<usize>) -> usize {
        match k {
            Some(k) if k > 0 && k < logits.len() && temp > 0.0 => {
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.select_nth_unstable_by(k - 1, |&a, &b| logits[b].total_cmp(&logits[a]));
                idx.truncate(k);
                let top: Vec<f32> = idx.iter().map(|&i| logits[i]).collect();
                idx[self.sample_logits(&top, temp)]
            }
            _ => self.sample_logits(logits, temp),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn greedy_sampling_picks_argmax() {
        let mut r = Rng::new(8);
        assert_eq!(r.sample_logits(&[0.1, 2.0, -1.0], 0.0), 1);
    }

    #[test]
    fn topk_sampling_stays_in_top_set() {
        let mut r = Rng::new(9);
        // indices 1 and 3 carry all the mass once k=2 keeps only them
        let logits = [0.0f32, 5.0, 1.0, 6.0, -2.0];
        for _ in 0..500 {
            let i = r.sample_logits_topk(&logits, 1.0, Some(2));
            assert!(i == 1 || i == 3, "sampled outside top-2: {i}");
        }
        // k = None and oversized k fall back to the full distribution
        assert_eq!(r.sample_logits_topk(&logits, 0.0, None), 3);
        assert_eq!(r.sample_logits_topk(&logits, 0.0, Some(100)), 3);
    }
}
