//! Shared substrates: CLI parsing, JSON, RNG, logging, stats, threads, IO.
//!
//! The offline environment vendors only the `xla` crate's dependency tree,
//! so the conveniences normally pulled from clap/serde/rand/rayon live here.

pub mod cli;
pub mod hash;
pub mod io;
pub mod json;
pub mod logging;
pub mod mem;
pub mod pool;
pub mod rng;
pub mod stats;
