//! Seven synthetic multiple-choice tasks standing in for the paper's
//! zero-shot commonsense benchmarks (OpenbookQA, ARC-e, ARC-c, WinoGrande,
//! PIQA, MathQA, HellaSwag).
//!
//! Each task probes one regularity of the shared language with the same
//! scoring protocol as lm-eval-harness: length-normalized LM likelihood of
//! each choice continuation given the context; argmin NLL wins.

use super::lang::*;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TaskInstance {
    pub context: String,
    pub choices: Vec<String>,
    pub answer: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    Openb,  // color fact recall (4-way)
    ArcE,   // weekday continuation (4-way)
    ArcC,   // addition (5-way)
    Winog,  // size-order consistency (2-way)
    Piqa,   // subject plausibility (2-way)
    MathQa, // subtraction (5-way)
    HellaS, // sentence completion vs corrupted continuations (4-way)
}

pub const ALL_TASKS: [Task; 7] = [
    Task::Openb,
    Task::ArcE,
    Task::ArcC,
    Task::Winog,
    Task::Piqa,
    Task::MathQa,
    Task::HellaS,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Openb => "openb",
            Task::ArcE => "arc_e",
            Task::ArcC => "arc_c",
            Task::Winog => "winog",
            Task::Piqa => "piqa",
            Task::MathQa => "mathqa",
            Task::HellaS => "hellas",
        }
    }

    pub fn n_choices(&self) -> usize {
        match self {
            Task::Openb | Task::ArcE | Task::HellaS => 4,
            Task::ArcC | Task::MathQa => 5,
            Task::Winog | Task::Piqa => 2,
        }
    }

    pub fn chance(&self) -> f64 {
        1.0 / self.n_choices() as f64
    }

    /// Generate one instance.
    pub fn instance(&self, rng: &mut Rng) -> TaskInstance {
        match self {
            Task::Openb => {
                let a = rng.below(ANIMALS.len());
                let correct = color_of(a);
                let mut choices = vec![correct.to_string()];
                let mut pool: Vec<&str> =
                    COLORS.iter().filter(|&&c| c != correct).cloned().collect();
                rng.shuffle(&mut pool);
                choices.extend(pool[..3].iter().map(|s| s.to_string()));
                let answer = shuffle_with_answer(rng, &mut choices);
                TaskInstance {
                    context: format!("the {} is", ANIMALS[a]),
                    choices,
                    answer,
                }
            }
            Task::ArcE => {
                let i = rng.below(7);
                let correct = next_day(i).to_string();
                let mut choices = vec![correct.clone()];
                let mut pool: Vec<&str> = DAYS
                    .iter()
                    .filter(|&&d| d != correct && d != DAYS[i])
                    .cloned()
                    .collect();
                rng.shuffle(&mut pool);
                choices.extend(pool[..3].iter().map(|s| s.to_string()));
                let answer = shuffle_with_answer(rng, &mut choices);
                TaskInstance {
                    context: format!("after {} comes", DAYS[i]),
                    choices,
                    answer,
                }
            }
            Task::ArcC => {
                let a = rng.below(10);
                let b = rng.below(10);
                let correct = plus(a, b);
                let mut choices = vec![correct.to_string()];
                let mut pool: Vec<&str> =
                    DIGITS.iter().filter(|&&d| d != correct).cloned().collect();
                rng.shuffle(&mut pool);
                choices.extend(pool[..4].iter().map(|s| s.to_string()));
                let answer = shuffle_with_answer(rng, &mut choices);
                TaskInstance {
                    context: format!("{} plus {} is", DIGITS[a], DIGITS[b]),
                    choices,
                    answer,
                }
            }
            Task::Winog => {
                // "the X is bigger than the ___": animal smaller than X is
                // corpus-consistent, larger contradicts the total order.
                let x = 1 + rng.below(ANIMALS.len() - 2); // not extremes
                let smaller = rng.below(x);
                let larger = x + 1 + rng.below(ANIMALS.len() - x - 1);
                let mut choices =
                    vec![ANIMALS[smaller].to_string(), ANIMALS[larger].to_string()];
                let answer = shuffle_with_answer(rng, &mut choices);
                TaskInstance {
                    context: format!("the {} is bigger than the", ANIMALS[x]),
                    choices,
                    answer,
                }
            }
            Task::Piqa => {
                // plausible subject for an animate verb: animal vs object
                let v = rng.below(ANIMATE_VERBS.len());
                let o = rng.below(ANIMALS.len());
                let animal = ANIMALS[rng.below(ANIMALS.len())];
                let object = OBJECTS[rng.below(OBJECTS.len())];
                let mut choices = vec![
                    format!("{animal} {} the {}", ANIMATE_VERBS[v], ANIMALS[o]),
                    format!("{object} {} the {}", ANIMATE_VERBS[v], ANIMALS[o]),
                ];
                let answer = shuffle_with_answer(rng, &mut choices);
                TaskInstance {
                    context: "the".to_string(),
                    choices,
                    answer,
                }
            }
            Task::MathQa => {
                let a = rng.below(10);
                let b = rng.below(10);
                let correct = minus(a, b);
                let mut choices = vec![correct.to_string()];
                let mut pool: Vec<&str> =
                    DIGITS.iter().filter(|&&d| d != correct).cloned().collect();
                rng.shuffle(&mut pool);
                choices.extend(pool[..4].iter().map(|s| s.to_string()));
                let answer = shuffle_with_answer(rng, &mut choices);
                TaskInstance {
                    context: format!("{} minus {} is", DIGITS[a], DIGITS[b]),
                    choices,
                    answer,
                }
            }
            Task::HellaS => {
                // complete a canonical sentence; distractors shuffle word
                // order or swap in an implausible noun
                let s = rng.below(ANIMALS.len());
                let v = rng.below(ANIMATE_VERBS.len());
                let o = rng.below(ANIMALS.len());
                let verb = ANIMATE_VERBS[v];
                let obj = ANIMALS[o];
                let correct = format!("{verb} the {obj} ."); // canonical
                let mut choices = vec![
                    correct,
                    format!("the {obj} {verb} ."),               // scrambled
                    format!("{verb} {obj} the ."),               // scrambled
                    format!("{verb} the {} .", OBJECTS[rng.below(OBJECTS.len())]),
                ];
                let answer = shuffle_with_answer(rng, &mut choices);
                TaskInstance {
                    context: format!("the {}", ANIMALS[s]),
                    choices,
                    answer,
                }
            }
        }
    }

    /// A deterministic evaluation set for this task.
    pub fn dataset(&self, n: usize, seed: u64) -> Vec<TaskInstance> {
        let mut rng = Rng::with_stream(seed, 0x7a5c + self.name().len() as u64);
        (0..n).map(|_| self.instance(&mut rng)).collect()
    }
}

/// Shuffle `choices` (currently correct-first) and return the new index of
/// the correct answer.
fn shuffle_with_answer(rng: &mut Rng, choices: &mut [String]) -> usize {
    let correct = choices[0].clone();
    rng.shuffle(choices);
    choices.iter().position(|c| *c == correct).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instances_have_declared_arity() {
        let mut rng = Rng::new(1);
        for task in ALL_TASKS {
            for _ in 0..20 {
                let inst = task.instance(&mut rng);
                assert_eq!(inst.choices.len(), task.n_choices(), "{}", task.name());
                assert!(inst.answer < inst.choices.len());
                // choices distinct
                let mut c = inst.choices.clone();
                c.sort();
                c.dedup();
                assert_eq!(c.len(), inst.choices.len(), "{}", task.name());
            }
        }
    }

    #[test]
    fn datasets_deterministic() {
        for task in ALL_TASKS {
            let a = task.dataset(10, 42);
            let b = task.dataset(10, 42);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.choices, y.choices);
                assert_eq!(x.answer, y.answer);
            }
        }
    }

    #[test]
    fn answers_not_always_first() {
        // shuffle must distribute the correct answer across positions
        let insts = Task::Openb.dataset(200, 7);
        let first = insts.iter().filter(|i| i.answer == 0).count();
        assert!(first < 120, "answer position biased: {first}/200");
    }

    #[test]
    fn openb_answer_is_the_fact() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let inst = Task::Openb.instance(&mut rng);
            // context names the animal; the correct choice is its color
            let animal = inst.context.split_whitespace().nth(1).unwrap();
            let idx = ANIMALS.iter().position(|&a| a == animal).unwrap();
            assert_eq!(inst.choices[inst.answer], color_of(idx));
        }
    }

    #[test]
    fn winog_answer_is_smaller_animal() {
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let inst = Task::Winog.instance(&mut rng);
            let subject = inst.context.split_whitespace().nth(1).unwrap();
            let si = ANIMALS.iter().position(|&a| a == subject).unwrap();
            let ans = &inst.choices[inst.answer];
            let ai = ANIMALS.iter().position(|a| a == ans).unwrap();
            assert!(bigger(si, ai), "{subject} must be bigger than {ans}");
        }
    }

    #[test]
    fn chance_levels() {
        assert_eq!(Task::Winog.chance(), 0.5);
        assert_eq!(Task::ArcC.chance(), 0.2);
        assert_eq!(Task::Openb.chance(), 0.25);
    }
}
