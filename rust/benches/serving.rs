//! Serving perf, artifact-free (the serving layer decodes through the
//! KV-cached pure-Rust forward):
//!
//! - closed-loop throughput + batch occupancy of the continuous-batching
//!   engine, dense vs compressed-with-exact-factors (isolates the
//!   low-rank kernel cost);
//! - the decode rows CI gates: KV-cached incremental decode vs the
//!   full-prefix recompute oracle for a 256-token completion on the
//!   synthetic (builtin tiny) config. Before timing, the two modes'
//!   greedy outputs are asserted identical — speed means nothing if the
//!   cache diverges from the oracle.

use aasvd::bench::Bench;
use aasvd::model::init::init_params;
use aasvd::model::lowrank::exact_factors;
use aasvd::model::Config;
use aasvd::serve::batcher::bench_prompts;
use aasvd::serve::{DecodeMode, GenParams, ServedModel, Server, ServerOptions};
use aasvd::util::rng::Rng;

const DECODE_TOKENS: usize = 256;

/// One single-request completion through a fresh server; returns its text.
fn decode_one(cfg: &Config, model: ServedModel, mode: DecodeMode, max_new: usize) -> String {
    let server = Server::start_with(
        cfg.clone(),
        model,
        ServerOptions {
            decode: mode,
            ..Default::default()
        },
    );
    let resp = server
        .submit(
            "the cat",
            GenParams {
                max_new_tokens: max_new,
                temperature: 0.0,
                ..Default::default()
            },
        )
        .expect("queue has room")
        .wait()
        .expect("request completes");
    server.shutdown();
    resp.text
}

fn main() {
    let cfg = Config::builtin("tiny").unwrap();
    let params = init_params(&cfg, &mut Rng::new(1));
    let blocks: Vec<_> = (0..cfg.n_layers)
        .map(|i| exact_factors(&cfg, &params, i))
        .collect();
    let prompts = bench_prompts(16, 5);

    // cache-exactness smoke: cached and recompute greedy decodes must
    // agree exactly before their speeds are compared
    let cached = decode_one(&cfg, ServedModel::Dense(params.clone()), DecodeMode::Cached, 64);
    let recomputed = decode_one(
        &cfg,
        ServedModel::Dense(params.clone()),
        DecodeMode::Recompute,
        64,
    );
    assert_eq!(
        cached, recomputed,
        "cached decode diverged from the full-prefix recompute oracle"
    );

    let mut b = Bench::new();
    b.min_iters = 3;
    b.max_iters = 6;
    type ModelFactory = Box<dyn Fn() -> ServedModel>;
    let variants: Vec<(&str, ModelFactory)> = vec![
        (
            "dense",
            Box::new({
                let p = params.clone();
                move || ServedModel::Dense(p.clone())
            }),
        ),
        (
            "lowrank",
            Box::new({
                let p = params.clone();
                let bl = blocks.clone();
                move || ServedModel::Compressed(p.clone(), bl.clone())
            }),
        ),
    ];
    for (label, make_model) in variants {
        b.run(
            &format!("serve[{label}] 16 reqs x 8 toks (closed loop)"),
            Some(16.0 * 8.0),
            || {
                let server = Server::start(cfg.clone(), make_model());
                let completions: Vec<_> = prompts
                    .iter()
                    .map(|p| {
                        server
                            .submit(
                                p,
                                GenParams {
                                    max_new_tokens: 8,
                                    temperature: 0.0,
                                    ..Default::default()
                                },
                            )
                            .expect("closed loop stays under max_queue")
                    })
                    .collect();
                for c in completions {
                    c.wait().unwrap();
                }
                let m = server.shutdown();
                std::hint::black_box(m);
            },
        );
    }

    // decode-throughput rows (the CI gate): one request, 256 new tokens.
    // Recompute re-runs the whole prefix per token — the pre-KV-cache
    // path — so it pays O(len²) attention per step where cached pays
    // O(len); CI gates cached at >= 3x recompute throughput.
    b.min_iters = 2;
    b.max_iters = 3;
    b.warmup = 1;
    for (label, mode) in [
        ("cached", DecodeMode::Cached),
        ("recompute", DecodeMode::Recompute),
    ] {
        let p = params.clone();
        b.run(
            &format!("decode[dense {label}] 1 req x {DECODE_TOKENS} toks"),
            Some(DECODE_TOKENS as f64),
            || {
                let text = decode_one(&cfg, ServedModel::Dense(p.clone()), mode, DECODE_TOKENS);
                std::hint::black_box(text);
            },
        );
    }
    b.save("serving");
}
