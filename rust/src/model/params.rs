//! Flat parameter stores with named views.
//!
//! Parameters live in one contiguous f32 vector in the canonical layout the
//! AOT artifacts expect (see model.param_specs); `Layout` maps tensor names
//! to (shape, offset). The same machinery backs dense params, per-block
//! low-rank factors, rank masks, and optimizer state.

use crate::util::io::{Tensor, TensorArchive};
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Layout {
    pub entries: Vec<Entry>,
    index: BTreeMap<String, usize>,
    pub total: usize,
}

impl Layout {
    pub fn new(entries: Vec<(String, Vec<usize>)>) -> Layout {
        let mut out = Vec::with_capacity(entries.len());
        let mut index = BTreeMap::new();
        let mut off = 0usize;
        for (name, shape) in entries {
            let size: usize = shape.iter().product();
            index.insert(name.clone(), out.len());
            out.push(Entry {
                name,
                shape,
                offset: off,
            });
            off += size;
        }
        Layout {
            entries: out,
            index,
            total: off,
        }
    }

    pub fn from_manifest(j: &Json) -> Layout {
        let entries = j
            .as_arr()
            .expect("layout must be an array")
            .iter()
            .map(|e| {
                let name = e.req("name").as_str().unwrap().to_string();
                let shape: Vec<usize> = e
                    .req("shape")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|d| d.as_usize().unwrap())
                    .collect();
                (name, shape)
            })
            .collect();
        let lay = Layout::new(entries);
        // cross-check offsets against the manifest (both sides must agree)
        for (ent, j_ent) in lay.entries.iter().zip(j.as_arr().unwrap()) {
            assert_eq!(
                ent.offset,
                j_ent.req("offset").as_usize().unwrap(),
                "manifest/layout offset mismatch for '{}'",
                ent.name
            );
        }
        lay
    }

    pub fn entry(&self, name: &str) -> &Entry {
        &self.entries[*self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("no tensor '{name}' in layout"))]
    }

    pub fn has(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.name.as_str())
    }
}

/// Flat f32 parameter vector + its layout.
#[derive(Clone, Debug)]
pub struct FlatStore {
    pub layout: Layout,
    pub data: Vec<f32>,
}

impl FlatStore {
    pub fn zeros(layout: Layout) -> FlatStore {
        let n = layout.total;
        FlatStore {
            layout,
            data: vec![0.0; n],
        }
    }

    pub fn from_data(layout: Layout, data: Vec<f32>) -> FlatStore {
        assert_eq!(layout.total, data.len(), "flat data length mismatch");
        FlatStore { layout, data }
    }

    pub fn view(&self, name: &str) -> &[f32] {
        let e = self.layout.entry(name);
        let size: usize = e.shape.iter().product();
        &self.data[e.offset..e.offset + size]
    }

    pub fn view_mut(&mut self, name: &str) -> &mut [f32] {
        let e = self.layout.entry(name).clone();
        let size: usize = e.shape.iter().product();
        &mut self.data[e.offset..e.offset + size]
    }

    pub fn shape(&self, name: &str) -> &[usize] {
        &self.layout.entry(name).shape
    }

    /// Save as a named-tensor archive (reshaped per layout).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut arch = TensorArchive::new();
        for e in &self.layout.entries {
            let size: usize = e.shape.iter().product();
            arch.insert(
                &e.name,
                Tensor::new(
                    e.shape.clone(),
                    self.data[e.offset..e.offset + size].to_vec(),
                ),
            );
        }
        arch.save(path)
    }

    /// Load from an archive; every layout entry must be present with the
    /// right shape (extra archive tensors are ignored).
    pub fn load(layout: Layout, path: impl AsRef<std::path::Path>) -> Result<FlatStore> {
        let arch = TensorArchive::load(path)?;
        let mut store = FlatStore::zeros(layout);
        for e in store.layout.entries.clone() {
            match arch.get(&e.name) {
                Some(t) if t.dims == e.shape => {
                    let size: usize = e.shape.iter().product();
                    store.data[e.offset..e.offset + size].copy_from_slice(&t.data);
                }
                Some(t) => bail!(
                    "tensor '{}' shape {:?} != layout {:?}",
                    e.name,
                    t.dims,
                    e.shape
                ),
                None => bail!("archive missing tensor '{}'", e.name),
            }
        }
        Ok(store)
    }
}

/// Build the dense-parameter layout for a config
/// (must match python model.param_specs exactly).
pub fn param_layout(cfg: &super::config::Config) -> Layout {
    let mut entries = vec![("embed".to_string(), vec![cfg.vocab, cfg.d_model])];
    for i in 0..cfg.n_layers {
        entries.extend(block_param_entries(cfg, i));
    }
    entries.push(("final_norm".to_string(), vec![cfg.d_model]));
    entries.push(("lm_head".to_string(), vec![cfg.vocab, cfg.d_model]));
    Layout::new(entries)
}

fn block_param_entries(
    cfg: &super::config::Config,
    i: usize,
) -> Vec<(String, Vec<usize>)> {
    let d = cfg.d_model;
    let mut v = vec![(format!("blocks.{i}.attn_norm"), vec![d])];
    for name in ["wq", "wk", "wv", "wo"] {
        let (m, n) = cfg.linear_dims(name);
        v.push((format!("blocks.{i}.{name}"), vec![m, n]));
    }
    v.push((format!("blocks.{i}.mlp_norm"), vec![d]));
    for name in ["w_gate", "w_up", "w_down"] {
        let (m, n) = cfg.linear_dims(name);
        v.push((format!("blocks.{i}.{name}"), vec![m, n]));
    }
    v
}

/// Layout of one block's dense params with bare names (block_fwd artifact).
pub fn block_param_layout(cfg: &super::config::Config) -> Layout {
    Layout::new(
        block_param_entries(cfg, 0)
            .into_iter()
            .map(|(n, s)| (n.split('.').skip(2).collect::<Vec<_>>().join("."), s))
            .collect(),
    )
}

/// Layout of one compressed block's trainables
/// (must match model.factor_specs_one_block).
pub fn factor_layout(cfg: &super::config::Config) -> Layout {
    let d = cfg.d_model;
    let mut entries = vec![
        ("attn_norm".to_string(), vec![d]),
        ("mlp_norm".to_string(), vec![d]),
    ];
    for name in super::config::BLOCK_LINEARS {
        let (m, n) = cfg.linear_dims(name);
        let k = cfg.kmax(name);
        entries.push((format!("{name}.u"), vec![m, k]));
        entries.push((format!("{name}.v"), vec![n, k]));
    }
    Layout::new(entries)
}

/// Layout of one block's rank masks (must match model.mask_specs_one_block).
pub fn mask_layout(cfg: &super::config::Config) -> Layout {
    Layout::new(
        super::config::BLOCK_LINEARS
            .iter()
            .map(|name| (format!("{name}.mask"), vec![cfg.kmax(name)]))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Config;

    #[test]
    fn layout_offsets_contiguous() {
        let cfg = Config::builtin("tiny").unwrap();
        let lay = param_layout(&cfg);
        let mut off = 0;
        for e in &lay.entries {
            assert_eq!(e.offset, off);
            off += e.shape.iter().product::<usize>();
        }
        assert_eq!(lay.total, off);
    }

    #[test]
    fn expected_param_count() {
        let cfg = Config::builtin("tiny").unwrap();
        let lay = param_layout(&cfg);
        let expect = cfg.vocab * cfg.d_model * 2
            + cfg.n_layers * (2 * cfg.d_model + cfg.block_linear_params())
            + cfg.d_model;
        assert_eq!(lay.total, expect);
    }

    #[test]
    fn views_are_disjoint_and_named() {
        let cfg = Config::builtin("tiny").unwrap();
        let mut s = FlatStore::zeros(param_layout(&cfg));
        s.view_mut("embed")[0] = 1.0;
        s.view_mut("blocks.0.wq")[0] = 2.0;
        assert_eq!(s.view("embed")[0], 1.0);
        assert_eq!(s.view("blocks.0.wq")[0], 2.0);
        assert_eq!(s.view("blocks.1.wq")[0], 0.0);
        assert_eq!(s.shape("blocks.0.wq"), &[cfg.d_model, cfg.d_model]);
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = Config::builtin("tiny").unwrap();
        let mut s = FlatStore::zeros(param_layout(&cfg));
        for (i, x) in s.data.iter_mut().enumerate() {
            *x = (i % 97) as f32 * 0.1;
        }
        let dir = std::env::temp_dir().join("aasvd-params-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tiny.aat");
        s.save(&p).unwrap();
        let t = FlatStore::load(param_layout(&cfg), &p).unwrap();
        assert_eq!(s.data, t.data);
    }

    #[test]
    fn factor_and_mask_layouts() {
        let cfg = Config::builtin("tiny").unwrap();
        let fl = factor_layout(&cfg);
        let ml = mask_layout(&cfg);
        assert!(fl.has("wq.u") && fl.has("w_down.v") && fl.has("attn_norm"));
        assert_eq!(
            ml.total,
            super::super::config::BLOCK_LINEARS
                .iter()
                .map(|l| cfg.kmax(l))
                .sum::<usize>()
        );
        // factor count: 2 norms + 2 mats per linear
        assert_eq!(fl.entries.len(), 2 + 14);
    }

    #[test]
    fn block_layout_has_bare_names() {
        let cfg = Config::builtin("tiny").unwrap();
        let bl = block_param_layout(&cfg);
        assert!(bl.has("attn_norm") && bl.has("wq") && bl.has("w_down"));
        assert_eq!(
            bl.total,
            2 * cfg.d_model + cfg.block_linear_params()
        );
    }

    #[test]
    fn load_rejects_shape_mismatch() {
        let cfg = Config::builtin("tiny").unwrap();
        let dir = std::env::temp_dir().join("aasvd-params-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.aat");
        let mut arch = TensorArchive::new();
        arch.insert("embed", Tensor::zeros(vec![1, 1]));
        arch.save(&p).unwrap();
        assert!(FlatStore::load(param_layout(&cfg), &p).is_err());
    }
}
