//! Table 3: AA-SVD vs structured-pruning baselines (zero-shot accuracy).
//!
//! Paper: LLaMA-2-7B vs LLM-Pruner / SliceGPT / Bonsai / Wanda-sp at
//! ratios 0.6 and 0.4(0.5). Here: in-repo pruning mechanism classes
//! (magnitude / wanda-sp / slicegpt / blockdrop) vs AA-SVD(±q) on the same
//! parameter budget and task battery.

use aasvd::compress::{prune_model, BlockOutcome, Method, ALL_PRUNERS};
use aasvd::eval::{all_tasks_accuracy, ModelRef, Table};
use aasvd::experiments::{eval_compressed_method_observed, eval_dense, setup, Knobs};
use aasvd::util::cli::Args;
use anyhow::Result;

/// Paper Table 3 average accuracies at (ratio, method).
const PAPER: [(f64, &str, f64); 12] = [
    (0.6, "llm_pruner", 0.48),
    (0.6, "slicegpt", 0.51),
    (0.6, "wanda_sp", 0.50),
    (0.6, "svd_llm", 0.40),
    (0.6, "aa_svd", 0.52),
    (0.6, "aa_svd_q", 0.60),
    (0.4, "llm_pruner", 0.45),
    (0.4, "slicegpt", 0.45),
    (0.4, "wanda_sp", 0.42),
    (0.4, "svd_llm", 0.36),
    (0.4, "aa_svd", 0.43),
    (0.4, "aa_svd_q", 0.51),
];

fn main() -> Result<()> {
    let args = Args::parse_env("Table 3: vs structured pruning");
    let mut knobs = Knobs::parse(&args, "small");
    knobs.ratios = args
        .list("ratios", "0.6,0.4", "ratios")
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    args.finish_or_help();
    let ctx = setup(&knobs)?;

    let mut table = Table::new(
        "Table 3 — vs structured pruning (avg zero-shot accuracy)",
        &["ratio", "method", "acc", "drop%", "paper:acc"],
    );
    let dense = eval_dense(&ctx)?;
    table.row(vec![
        "1.0".into(),
        "dense".into(),
        format!("{:.3}", dense.avg_acc),
        "-".into(),
        "0.65".into(),
    ]);

    for &ratio in &knobs.ratios {
        // pruning baselines
        for pruner in ALL_PRUNERS {
            let pm = prune_model(&ctx.engine, &ctx.cfg, &ctx.params, &ctx.calib, pruner, ratio)?;
            let (_, acc) = all_tasks_accuracy(
                &ctx.engine,
                &ctx.cfg,
                &ModelRef::Dense(&pm.params),
                ctx.n_task_instances,
                ctx.task_seed,
            )?;
            let paper = PAPER
                .iter()
                .find(|(r, m, _)| *r == ratio && *m == pruner.name())
                .map(|&(_, _, a)| format!("{a:.2}"))
                .unwrap_or("-".into());
            table.row(vec![
                format!("{ratio}"),
                pruner.name().into(),
                format!("{acc:.3}"),
                format!("{:.1}%", 100.0 * (dense.avg_acc - acc) / dense.avg_acc),
                paper,
            ]);
        }
        // SVD methods
        for method in [
            Method::svd_llm(),
            Method::aa_svd(knobs.refine()),
            Method::aa_svd_q(knobs.refine()),
        ] {
            let (ev, _) =
                eval_compressed_method_observed(&ctx, &method, ratio, &mut |o: &BlockOutcome| {
                    eprintln!(
                        "[table3] {} @ {ratio}: block {}/{} ({:.1}s)",
                        method.name,
                        o.index + 1,
                        o.total,
                        o.secs
                    );
                })?;
            let paper = PAPER
                .iter()
                .find(|(r, m, _)| *r == ratio && *m == method.name)
                .map(|&(_, _, a)| format!("{a:.2}"))
                .unwrap_or("-".into());
            table.row(vec![
                format!("{ratio}"),
                ev.method.clone(),
                format!("{:.3}", ev.avg_acc),
                format!("{:.1}%", 100.0 * (dense.avg_acc - ev.avg_acc) / dense.avg_acc),
                paper,
            ]);
        }
    }
    table.emit("table3")?;
    Ok(())
}
