//! Randomized engine-schedule fuzzing (seeded, deterministic generation):
//! drive the synthetic backend — wrapped in a deterministic fault
//! injector that exercises the trait's *default* `decode_batch` — through
//! ~200 random admit/cancel/deadline/backend-error schedules and assert
//! the engine's lifecycle invariants:
//!
//! - every accepted request terminates with **exactly one** terminal
//!   event, and no token arrives after it;
//! - every streamed token sequence is a prefix of the synthetic oracle's
//!   stream for that prompt (whatever mix of completion, cancellation,
//!   deadline expiry, context capping, or injected backend failure ends
//!   the request);
//! - the `ServeMetrics` counters balance: submissions =
//!   completed + cancelled + rejected, token totals agree with what the
//!   clients saw, and every batched decode call is accounted for.
//!
//! Outcome *classes* may vary with timing (a cancel can land before or
//! after completion); the invariants hold either way, which is exactly
//! what makes them fuzzable.

use aasvd::model::init::init_params;
use aasvd::model::Config;
use aasvd::serve::{
    CancelReason, DecodeMode, DenseBackend, Event, GenParams, GenResponse, ModelBackend,
    PagedKvOptions, Prefill, Server, ServerOptions, Session, SubmitError, SyntheticBackend,
};
use aasvd::util::rng::Rng;
use std::time::Duration;

/// Deterministic fault injector: every `fail_every`-th backend call
/// (prefill, decode step, or oracle recompute) fails. Implements only the
/// session API, so the engine reaches it through the trait's default
/// `decode_batch` — the third-party-backend compatibility path.
struct FaultyBackend {
    inner: SyntheticBackend,
    fail_every: u64,
    calls: u64,
}

impl FaultyBackend {
    fn tick(&mut self) -> anyhow::Result<()> {
        self.calls += 1;
        if self.fail_every != 0 && self.calls % self.fail_every == 0 {
            anyhow::bail!("injected backend failure (call {})", self.calls);
        }
        Ok(())
    }
}

impl ModelBackend for FaultyBackend {
    fn artifact(&self) -> &'static str {
        "faulty-synthetic"
    }
    fn prefill(&mut self, tokens: &[i32]) -> anyhow::Result<Prefill> {
        self.tick()?;
        self.inner.prefill(tokens)
    }
    fn decode_step(&mut self, session: &mut Session, token: i32) -> anyhow::Result<Vec<f32>> {
        self.tick()?;
        self.inner.decode_step(session, token)
    }
    fn oracle_logits(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        self.tick()?;
        self.inner.oracle_logits(tokens)
    }
}

#[test]
fn randomized_schedules_preserve_engine_invariants() {
    let mut rng = Rng::new(0xF0_22_5EED);
    for schedule in 0..200u32 {
        let cfg = Config::builtin("tiny").unwrap();
        // fault injection on ~1/4 of schedules
        let fail_every = if rng.below(4) == 0 {
            3 + rng.below(6) as u64
        } else {
            0
        };
        // a sprinkle of simulated model latency so cancels and deadlines
        // can land mid-decode, not only between requests
        let step_delay = if rng.below(8) == 0 {
            Duration::from_micros(200)
        } else {
            Duration::ZERO
        };
        let mode = if rng.below(4) == 0 {
            DecodeMode::Recompute
        } else {
            DecodeMode::Cached
        };
        let options = ServerOptions {
            max_batch: 1 + rng.below(4),
            max_queue: 1 + rng.below(6),
            poll_interval: Duration::from_millis(1),
            decode: mode,
            max_context: [0, 0, 0, 4, 16][rng.below(5)],
            ..Default::default()
        };
        let backend_cfg = cfg.clone();
        let server = Server::with_backend(cfg, options, move || {
            Ok(Box::new(FaultyBackend {
                inner: SyntheticBackend::with_delay(backend_cfg, step_delay),
                fail_every,
                calls: 0,
            }) as Box<dyn ModelBackend>)
        });

        let n_requests = 1 + rng.below(7);
        let mut accepted: Vec<(aasvd::serve::Completion, u8, usize)> = Vec::new();
        let mut rejected = 0usize;
        for _ in 0..n_requests {
            let prompt_bytes: Vec<u8> = (0..rng.below(6))
                .map(|_| b'a' + rng.below(24) as u8)
                .collect();
            let prompt = String::from_utf8(prompt_bytes.clone()).unwrap();
            let params = GenParams {
                max_new_tokens: rng.below(13),
                temperature: 0.0,
                deadline: if rng.below(6) == 0 {
                    Some(Duration::ZERO)
                } else {
                    None
                },
                ..Default::default()
            };
            match server.submit(&prompt, params.clone()) {
                Ok(completion) => {
                    if rng.below(5) == 0 {
                        completion.cancel();
                    }
                    // an empty prompt is seated as a single space token
                    let last = prompt_bytes.last().copied().unwrap_or(b' ');
                    accepted.push((completion, last, params.max_new_tokens));
                }
                Err(SubmitError::Overloaded) => rejected += 1,
                Err(e) => panic!("schedule {schedule}: unexpected submit error: {e}"),
            }
        }

        let mut completed = 0usize;
        let mut cancelled = 0usize;
        let mut done_tokens = 0usize;
        for (completion, last, max_new) in accepted {
            let mut streamed = String::new();
            let mut terminals = 0usize;
            let mut done: Option<GenResponse> = None;
            while let Some(event) = completion.next_event() {
                match event {
                    Event::Token(t) => {
                        assert_eq!(
                            terminals, 0,
                            "schedule {schedule}: token after a terminal event"
                        );
                        assert_eq!(
                            t.index,
                            streamed.chars().count(),
                            "schedule {schedule}: token indices must be contiguous"
                        );
                        streamed.push(t.ch);
                    }
                    Event::Done(resp) => {
                        terminals += 1;
                        done = Some(resp);
                    }
                    Event::Cancelled { .. } => terminals += 1,
                }
            }
            assert_eq!(
                terminals, 1,
                "schedule {schedule}: exactly one terminal event per request"
            );
            // prefix consistency: the synthetic oracle's stream after a
            // prompt ending in byte `b` is (b+1), (b+2), ... mod 256
            let expect: String = (1..=streamed.chars().count())
                .map(|i| last.wrapping_add(i as u8) as char)
                .collect();
            assert_eq!(
                streamed, expect,
                "schedule {schedule}: stream diverged from the oracle prefix"
            );
            match done {
                Some(resp) => {
                    completed += 1;
                    done_tokens += resp.tokens_generated;
                    assert!(resp.tokens_generated <= max_new);
                    assert_eq!(
                        resp.text, streamed,
                        "schedule {schedule}: final text vs streamed tokens"
                    );
                    assert!(resp.latency >= resp.ttft || resp.tokens_generated == 0);
                }
                None => cancelled += 1,
            }
        }

        let metrics = server.shutdown();
        assert_eq!(metrics.rejected, rejected, "schedule {schedule}: rejected");
        assert_eq!(
            metrics.latencies.len(),
            completed,
            "schedule {schedule}: completed"
        );
        assert_eq!(metrics.cancelled, cancelled, "schedule {schedule}: cancelled");
        assert_eq!(
            n_requests,
            completed + cancelled + metrics.rejected,
            "schedule {schedule}: every submission has exactly one outcome"
        );
        assert_eq!(metrics.tokens, done_tokens, "schedule {schedule}: tokens");
        // batched-call accounting: one occupancy sample per batched call,
        // and no batched calls at all on the recompute path
        assert_eq!(
            metrics.decode_batches,
            metrics.decode_batch_rows.len(),
            "schedule {schedule}: occupancy samples"
        );
        if mode == DecodeMode::Recompute {
            assert_eq!(metrics.decode_batches, 0, "schedule {schedule}");
        }
    }
}

/// Paged-KV storm: random schedules against a real dense backend over tiny
/// block pools (some deliberately too small for the largest requests, so
/// the never-fits path fires and clients see `CancelReason::KvPressure`).
/// Per schedule, assert the lifecycle and memory invariants the paged
/// engine must keep under churn:
///
/// - every accepted request gets **exactly one** terminal event — a
///   KvPressure rejection included — and no token precedes a rejection;
/// - every engine-side KvPressure retirement reached exactly one client;
/// - the pool is hard-bounded (`kv_peak_blocks <= capacity`) and fully
///   drained at shutdown (`kv_blocks_leaked == 0`: residency returned to
///   zero after the last request retired);
/// - submission counts balance: n = completed + cancelled + rejected.
#[test]
fn paged_schedules_bound_the_pool_and_leak_no_blocks() {
    let mut rng = Rng::new(0x9A6E_D5EE);
    for schedule in 0..40u32 {
        let cfg = Config::builtin("tiny").unwrap();
        // tiny pools; with block_tokens = 4 and 2 layers a request needs
        // 2 * ceil((prompt + max_new) / 4) blocks, so the 4-block pool
        // rejects anything past 8 total tokens while 24 admits everything
        let blocks = [4, 6, 8, 12, 24][rng.below(5)];
        let paged = PagedKvOptions {
            blocks,
            block_tokens: 4,
            prefix_cache: rng.below(2) == 0,
        };
        let options = ServerOptions {
            max_batch: 1 + rng.below(4),
            max_queue: 32,
            poll_interval: Duration::from_millis(1),
            decode: DecodeMode::Cached,
            paged_kv: Some(paged),
            ..Default::default()
        };
        let backend_cfg = cfg.clone();
        let server = Server::with_backend(cfg, options, move || {
            let params = init_params(&backend_cfg, &mut Rng::new(0xA5_5EED));
            Ok(Box::new(DenseBackend::new(backend_cfg, params)) as Box<dyn ModelBackend>)
        });

        let n_requests = 4 + rng.below(8);
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for _ in 0..n_requests {
            // half the prompts share an 8-char prefix (two full blocks),
            // so the radix cache sees real reuse whenever it is enabled
            let tail: String = (0..1 + rng.below(8))
                .map(|_| char::from(b'a' + rng.below(24) as u8))
                .collect();
            let prompt = if rng.below(2) == 0 {
                format!("sharedpf{tail}")
            } else {
                tail
            };
            let params = GenParams {
                max_new_tokens: 1 + rng.below(12),
                temperature: 0.0,
                ..Default::default()
            };
            match server.submit(&prompt, params) {
                Ok(completion) => accepted.push(completion),
                Err(SubmitError::Overloaded) => rejected += 1,
                Err(e) => panic!("schedule {schedule}: unexpected submit error: {e}"),
            }
        }

        let mut completed = 0usize;
        let mut cancelled = 0usize;
        let mut pressure_seen = 0usize;
        for completion in accepted {
            let mut terminals = 0usize;
            let mut streamed = String::new();
            let mut done: Option<GenResponse> = None;
            while let Some(event) = completion.next_event() {
                match event {
                    Event::Token(t) => {
                        assert_eq!(
                            terminals, 0,
                            "schedule {schedule}: token after a terminal event"
                        );
                        assert_eq!(
                            t.index,
                            streamed.chars().count(),
                            "schedule {schedule}: token indices must be contiguous"
                        );
                        streamed.push(t.ch);
                    }
                    Event::Done(resp) => {
                        terminals += 1;
                        done = Some(resp);
                    }
                    Event::Cancelled { reason, .. } => {
                        terminals += 1;
                        if reason == CancelReason::KvPressure {
                            assert!(
                                streamed.is_empty(),
                                "schedule {schedule}: KvPressure must reject before any token"
                            );
                            pressure_seen += 1;
                        }
                    }
                }
            }
            assert_eq!(
                terminals, 1,
                "schedule {schedule}: exactly one terminal event per request"
            );
            match done {
                Some(resp) => {
                    completed += 1;
                    assert_eq!(
                        resp.text, streamed,
                        "schedule {schedule}: final text vs streamed tokens"
                    );
                }
                None => cancelled += 1,
            }
        }

        let metrics = server.shutdown();
        assert_eq!(metrics.rejected, rejected, "schedule {schedule}: rejected");
        assert_eq!(
            metrics.latencies.len(),
            completed,
            "schedule {schedule}: completed"
        );
        assert_eq!(metrics.cancelled, cancelled, "schedule {schedule}: cancelled");
        assert_eq!(
            n_requests,
            completed + cancelled + metrics.rejected,
            "schedule {schedule}: every submission has exactly one outcome"
        );
        assert_eq!(
            metrics.kv_pressure_rejected, pressure_seen,
            "schedule {schedule}: every KvPressure retirement reached exactly one client"
        );
        // the pool is hard-bounded and fully drained
        assert_eq!(
            metrics.kv_blocks_capacity, blocks,
            "schedule {schedule}: pool capacity"
        );
        assert!(
            metrics.kv_peak_blocks <= blocks,
            "schedule {schedule}: peak residency {} exceeded the {blocks}-block budget",
            metrics.kv_peak_blocks
        );
        assert_eq!(
            metrics.kv_blocks_leaked, 0,
            "schedule {schedule}: blocks still resident after drain"
        );
    }
}
