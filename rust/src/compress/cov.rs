//! Streaming covariance accumulation (Algorithm 1, step 2).
//!
//! The closed form needs only fixed-size covariance matrices, never the raw
//! activation matrices (paper §B.1): for every tap position we accumulate
//!   S_orig  = Σ x xᵀ     (original inputs X)
//!   S_shift = Σ x' x'ᵀ   (shifted inputs X' from the partially-compressed net)
//!   C_cross = Σ x x'ᵀ    (the anchored cross term)
//! over token chunks. Accumulation is f64 (condition numbers grow with
//! calibration size); the Pallas cov_accum artifact provides an f32
//! MXU-shaped alternative used by benches and integration tests.

use crate::linalg::Matrix;
use crate::util::pool::Pool;

/// Upper bound on in-flight partial accumulators in the parallel
/// accumulation paths — each partial is three dim×dim f64 matrices, so
/// memory must scale with this constant, not with calibration size.
const MAX_PARTIALS: usize = 16;

/// Cut `0..n` into at most [`MAX_PARTIALS`] contiguous groups. Boundaries
/// depend only on `n`: the accumulation order (within groups and across
/// the ordered merge) is identical for every worker count.
fn group_ranges(n: usize) -> Vec<std::ops::Range<usize>> {
    let groups = n.min(MAX_PARTIALS).max(1);
    let per = n.div_ceil(groups);
    (0..groups)
        .map(|g| (g * per).min(n)..((g + 1) * per).min(n))
        .filter(|r| !r.is_empty())
        .collect()
}

/// Accumulates the three covariance matrices of one tap position.
#[derive(Clone, Debug)]
pub struct CovTriple {
    pub dim: usize,
    pub s_orig: Matrix,
    pub s_shift: Matrix,
    pub c_cross: Matrix,
    pub tokens: usize,
}

impl CovTriple {
    pub fn new(dim: usize) -> CovTriple {
        CovTriple {
            dim,
            s_orig: Matrix::zeros(dim, dim),
            s_shift: Matrix::zeros(dim, dim),
            c_cross: Matrix::zeros(dim, dim),
            tokens: 0,
        }
    }

    /// Add a chunk: `x`/`x_shift` are [rows, dim] row-major activations.
    pub fn add_chunk(&mut self, x: &[f32], x_shift: &[f32]) {
        let d = self.dim;
        assert_eq!(x.len(), x_shift.len());
        assert_eq!(x.len() % d, 0);
        let rows = x.len() / d;
        // accumulate outer products in f64; row-blocked for cache locality
        for r in 0..rows {
            let xr = &x[r * d..(r + 1) * d];
            let sr = &x_shift[r * d..(r + 1) * d];
            for i in 0..d {
                let xi = xr[i] as f64;
                let si = sr[i] as f64;
                let so_row = &mut self.s_orig.data[i * d..(i + 1) * d];
                let ss_row = &mut self.s_shift.data[i * d..(i + 1) * d];
                let cc_row = &mut self.c_cross.data[i * d..(i + 1) * d];
                if xi != 0.0 {
                    for (j, v) in so_row.iter_mut().enumerate() {
                        *v += xi * xr[j] as f64;
                    }
                    for (j, v) in cc_row.iter_mut().enumerate() {
                        *v += xi * sr[j] as f64;
                    }
                }
                if si != 0.0 {
                    for (j, v) in ss_row.iter_mut().enumerate() {
                        *v += si * sr[j] as f64;
                    }
                }
            }
        }
        self.tokens += rows;
    }

    /// Identical-input fast path (X == X'): accumulates S_orig only and
    /// mirrors it into the other two at `finish` time via `mirrored()`.
    pub fn add_chunk_same(&mut self, x: &[f32]) {
        let d = self.dim;
        let rows = x.len() / d;
        for r in 0..rows {
            let xr = &x[r * d..(r + 1) * d];
            for i in 0..d {
                let xi = xr[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let so_row = &mut self.s_orig.data[i * d..(i + 1) * d];
                for (j, v) in so_row.iter_mut().enumerate() {
                    *v += xi * xr[j] as f64;
                }
            }
        }
        self.tokens += rows;
    }

    /// After `add_chunk_same`, make S_shift and C_cross copies of S_orig.
    pub fn mirror_same(&mut self) {
        self.s_shift = self.s_orig.clone();
        self.c_cross = self.s_orig.clone();
    }

    /// Fold another accumulator into this one (elementwise sums). Merging
    /// partials in a fixed order is the parallel path's determinism
    /// contract: the result depends on the partition, never on timing.
    pub fn merge(&mut self, other: &CovTriple) {
        assert!(
            self.dim == other.dim,
            "CovTriple::merge dim mismatch: {} vs {}",
            self.dim,
            other.dim
        );
        for (a, b) in self.s_orig.data.iter_mut().zip(&other.s_orig.data) {
            *a += b;
        }
        for (a, b) in self.s_shift.data.iter_mut().zip(&other.s_shift.data) {
            *a += b;
        }
        for (a, b) in self.c_cross.data.iter_mut().zip(&other.c_cross.data) {
            *a += b;
        }
        self.tokens += other.tokens;
    }

    /// Accumulate many (x, x') chunk pairs in parallel: chunks are cut
    /// into at most [`MAX_PARTIALS`] fixed groups (boundaries depend only
    /// on the chunk count, never the worker count), each group streams
    /// sequentially into one partial accumulator, and partials merge in
    /// group order. The result is bitwise identical for 1 or N threads,
    /// and transient memory stays bounded no matter how many calibration
    /// chunks stream in.
    pub fn accumulate(pool: &Pool, dim: usize, pairs: &[(&[f32], &[f32])]) -> CovTriple {
        let partials = pool.run(
            group_ranges(pairs.len())
                .into_iter()
                .map(|r| {
                    move || {
                        let mut c = CovTriple::new(dim);
                        for &(x, s) in &pairs[r] {
                            c.add_chunk(x, s);
                        }
                        c
                    }
                })
                .collect(),
        );
        let mut out = CovTriple::new(dim);
        for p in &partials {
            out.merge(p);
        }
        out
    }

    /// Identical-input variant of [`CovTriple::accumulate`]; the caller
    /// still finishes with [`CovTriple::mirror_same`].
    pub fn accumulate_same(pool: &Pool, dim: usize, chunks: &[&[f32]]) -> CovTriple {
        let partials = pool.run(
            group_ranges(chunks.len())
                .into_iter()
                .map(|r| {
                    move || {
                        let mut c = CovTriple::new(dim);
                        for &x in &chunks[r] {
                            c.add_chunk_same(x);
                        }
                        c
                    }
                })
                .collect(),
        );
        let mut out = CovTriple::new(dim);
        for p in &partials {
            out.merge(p);
        }
        out
    }

    /// Mean absolute activation per channel from S_orig diagonal
    /// (the ASVD-style sensitivity scale: sqrt(E[x²])).
    pub fn channel_scales(&self) -> Vec<f64> {
        let n = self.tokens.max(1) as f64;
        (0..self.dim)
            .map(|i| (self.s_orig.get(i, i) / n).sqrt().max(1e-12))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;
    use crate::util::rng::Rng;

    fn dense_cov(a: &[f32], b: &[f32], d: usize) -> Matrix {
        let rows = a.len() / d;
        let ma = Matrix::from_f32(rows, d, a);
        let mb = Matrix::from_f32(rows, d, b);
        ma.matmul_at(&mb)
    }

    #[test]
    fn matches_dense_computation() {
        let mut rng = Rng::new(1);
        let d = 9;
        let rows = 40;
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let mut cov = CovTriple::new(d);
        cov.add_chunk(&x, &y);
        assert_close(&cov.s_orig.data, &dense_cov(&x, &x, d).data, 1e-9);
        assert_close(&cov.s_shift.data, &dense_cov(&y, &y, d).data, 1e-9);
        assert_close(&cov.c_cross.data, &dense_cov(&x, &y, d).data, 1e-9);
        assert_eq!(cov.tokens, rows);
    }

    #[test]
    fn streaming_equals_batch() {
        let mut rng = Rng::new(2);
        let d = 7;
        let x: Vec<f32> = (0..50 * d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..50 * d).map(|_| rng.normal()).collect();
        let mut whole = CovTriple::new(d);
        whole.add_chunk(&x, &y);
        let mut parts = CovTriple::new(d);
        parts.add_chunk(&x[..20 * d], &y[..20 * d]);
        parts.add_chunk(&x[20 * d..], &y[20 * d..]);
        assert_close(&whole.c_cross.data, &parts.c_cross.data, 1e-9);
        assert_close(&whole.s_shift.data, &parts.s_shift.data, 1e-9);
    }

    #[test]
    fn same_path_mirrors() {
        let mut rng = Rng::new(3);
        let d = 5;
        let x: Vec<f32> = (0..30 * d).map(|_| rng.normal()).collect();
        let mut cov = CovTriple::new(d);
        cov.add_chunk_same(&x);
        cov.mirror_same();
        let want = dense_cov(&x, &x, d);
        assert_close(&cov.s_orig.data, &want.data, 1e-9);
        assert_close(&cov.s_shift.data, &want.data, 1e-9);
        assert_close(&cov.c_cross.data, &want.data, 1e-9);
    }

    #[test]
    fn covariances_are_symmetric_psd_diag() {
        let mut rng = Rng::new(4);
        let d = 6;
        let x: Vec<f32> = (0..100 * d).map(|_| rng.normal()).collect();
        let mut cov = CovTriple::new(d);
        cov.add_chunk_same(&x);
        let asym = cov.s_orig.sub(&cov.s_orig.transpose()).max_abs();
        assert!(asym < 1e-9);
        for i in 0..d {
            assert!(cov.s_orig.get(i, i) >= 0.0);
        }
    }

    #[test]
    fn parallel_accumulate_is_thread_count_invariant() {
        let mut rng = Rng::new(5);
        let d = 11;
        let chunks: Vec<(Vec<f32>, Vec<f32>)> = (0..6)
            .map(|_| {
                let x: Vec<f32> = (0..17 * d).map(|_| rng.normal()).collect();
                let y: Vec<f32> = (0..17 * d).map(|_| rng.normal()).collect();
                (x, y)
            })
            .collect();
        let pairs: Vec<(&[f32], &[f32])> = chunks
            .iter()
            .map(|(x, y)| (x.as_slice(), y.as_slice()))
            .collect();
        let c1 = CovTriple::accumulate(&Pool::exact(1), d, &pairs);
        let c4 = CovTriple::accumulate(&Pool::exact(4), d, &pairs);
        assert_eq!(c1.s_orig.data, c4.s_orig.data);
        assert_eq!(c1.s_shift.data, c4.s_shift.data);
        assert_eq!(c1.c_cross.data, c4.c_cross.data);
        assert_eq!(c1.tokens, c4.tokens);
        // and the merged total matches the one-shot accumulation closely
        let (xs, ys): (Vec<f32>, Vec<f32>) = chunks.iter().fold(
            (Vec::new(), Vec::new()),
            |(mut xs, mut ys), (x, y)| {
                xs.extend_from_slice(x);
                ys.extend_from_slice(y);
                (xs, ys)
            },
        );
        let mut whole = CovTriple::new(d);
        whole.add_chunk(&xs, &ys);
        assert_close(&c1.c_cross.data, &whole.c_cross.data, 1e-9);
        assert_eq!(c1.tokens, whole.tokens);
    }

    #[test]
    fn parallel_accumulate_same_matches_sequential() {
        let mut rng = Rng::new(6);
        let d = 9;
        let chunks: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..23 * d).map(|_| rng.normal()).collect())
            .collect();
        let views: Vec<&[f32]> = chunks.iter().map(|c| c.as_slice()).collect();
        let mut c1 = CovTriple::accumulate_same(&Pool::exact(1), d, &views);
        let mut c4 = CovTriple::accumulate_same(&Pool::exact(4), d, &views);
        assert_eq!(c1.s_orig.data, c4.s_orig.data);
        c1.mirror_same();
        c4.mirror_same();
        assert_eq!(c1.c_cross.data, c4.c_cross.data);
    }

    #[test]
    fn merge_adds_tokens_and_sums() {
        let mut rng = Rng::new(7);
        let d = 4;
        let x: Vec<f32> = (0..10 * d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..15 * d).map(|_| rng.normal()).collect();
        let mut a = CovTriple::new(d);
        a.add_chunk_same(&x);
        let mut b = CovTriple::new(d);
        b.add_chunk_same(&y);
        let mut merged = CovTriple::new(d);
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.tokens, 25);
        let mut whole = CovTriple::new(d);
        whole.add_chunk_same(&x);
        whole.add_chunk_same(&y);
        assert_close(&merged.s_orig.data, &whole.s_orig.data, 1e-12);
    }

    #[test]
    fn channel_scales_reflect_energy() {
        let d = 3;
        // channel 0 twice as large as channel 1; channel 2 silent
        let x = vec![2.0f32, 1.0, 0.0, 2.0, 1.0, 0.0, 2.0, 1.0, 0.0];
        let mut cov = CovTriple::new(d);
        cov.add_chunk_same(&x);
        let s = cov.channel_scales();
        assert!((s[0] - 2.0).abs() < 1e-6);
        assert!((s[1] - 1.0).abs() < 1e-6);
        assert!(s[2] <= 1e-12 * 2.0 + 1e-12);
    }
}
