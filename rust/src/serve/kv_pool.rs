//! Serving-side paged-KV state: the bounded block pool plus the radix
//! prefix cache that lets sessions sharing a prompt prefix reuse KV
//! blocks copy-on-write.
//!
//! The storage substrate ([`KvBlockPool`], [`PagedKvCache`]) lives in
//! `model::paged_kv`; this module owns the *policy*:
//!
//! - [`PrefixCache`] — a radix trie keyed by exact `block_tokens`-sized
//!   token chunks. A node holds one full `Arc<KvBlock>` per layer for its
//!   chunk. Lookup walks the longest cached prefix; insert publishes a
//!   freshly prefilled session's full chunks. Only *full* blocks are ever
//!   published, so shared blocks are never written (see the COW notes in
//!   `model::paged_kv`). `BTreeMap` keys make iteration — and therefore
//!   LRU tie-breaking and eviction — deterministic.
//! - LRU eviction on unreferenced nodes: when the pool is exhausted,
//!   [`PagedState::alloc_evicting`] peels trie leaves whose blocks no
//!   live session references (`Arc::strong_count == 1`), oldest
//!   `last_use` first, until the allocation fits or nothing evictable
//!   remains. Recency is a logical clock — no wall-clock reads.
//! - [`PagedState`] — what a backend holds when paged KV is configured:
//!   the pool, the optional trie, and the session bootstrap
//!   ([`PagedState::start_session`]) that adopts the longest cached
//!   prefix while always leaving at least the final prompt token to be
//!   computed (prefill must produce next-token logits).
//!
//! Reuse is bitwise-exact by construction: adopted blocks hold the very
//! rows a cold prefill of the same prefix would write (RoPE'd keys
//! depend only on token and absolute position), and the decode kernels
//! are the same generics either way.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::model::paged_kv::{KvBlock, KvBlockPool, KvPressure, PagedKvCache};

/// Paged-KV configuration carried by `ServerOptions::paged_kv`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PagedKvOptions {
    /// Total block budget for the pool (all layers, all sessions).
    pub blocks: usize,
    /// KV rows per block.
    pub block_tokens: usize,
    /// Whether to run the radix prefix cache on top of the pool.
    pub prefix_cache: bool,
}

impl Default for PagedKvOptions {
    fn default() -> Self {
        PagedKvOptions {
            blocks: 256,
            block_tokens: 16,
            prefix_cache: true,
        }
    }
}

/// Point-in-time pool/prefix counters a paged backend reports up to the
/// engine for `ServeMetrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    /// Pool block budget.
    pub capacity: usize,
    /// Blocks currently resident.
    pub in_use: usize,
    /// High-water mark of `in_use`.
    pub peak: usize,
    /// KV rows per block.
    pub block_tokens: usize,
    /// Prefix nodes evicted under pool pressure so far.
    pub evictions: u64,
    /// Prefix trie nodes currently cached.
    pub trie_nodes: usize,
}

/// One trie node: the per-layer KV blocks covering one token chunk, the
/// children keyed by the next chunk, and a logical-clock recency stamp.
#[derive(Debug)]
struct PrefixNode {
    /// `blocks[l]` is layer `l`'s full block for this chunk.
    blocks: Vec<Arc<KvBlock>>,
    children: BTreeMap<Vec<u32>, PrefixNode>,
    last_use: u64,
}

/// Radix trie over `block_tokens`-sized token chunks.
#[derive(Debug)]
pub struct PrefixCache {
    children: BTreeMap<Vec<u32>, PrefixNode>,
    block_tokens: usize,
    /// Logical clock: bumped on every node touch, so `last_use` values
    /// are unique and LRU ordering is total and deterministic.
    clock: u64,
    evictions: u64,
}

impl PrefixCache {
    pub fn new(block_tokens: usize) -> Self {
        PrefixCache {
            children: BTreeMap::new(),
            block_tokens,
            clock: 0,
            evictions: 0,
        }
    }

    /// Walk the longest cached prefix of `tokens`, at most `max_chunks`
    /// chunks deep. Returns one entry per matched chunk: that chunk's
    /// per-layer block handles. Touched nodes are stamped most-recent.
    pub fn lookup(&mut self, tokens: &[u32], max_chunks: usize) -> Vec<Vec<Arc<KvBlock>>> {
        let clock = &mut self.clock;
        let mut level = &mut self.children;
        let mut out = Vec::new();
        for chunk in tokens.chunks_exact(self.block_tokens).take(max_chunks) {
            match level.get_mut(chunk) {
                Some(node) => {
                    *clock += 1;
                    node.last_use = *clock;
                    out.push(node.blocks.clone());
                    level = &mut node.children;
                }
                None => break,
            }
        }
        out
    }

    /// Publish every full chunk of `tokens` whose KV rows `cache` holds
    /// (a just-prefilled session). Existing nodes keep their blocks —
    /// identical prefixes produce bitwise-identical rows, so first-writer
    /// wins is exact, and it avoids churning `Arc`s other sessions hold.
    pub fn insert(&mut self, tokens: &[u32], cache: &PagedKvCache) {
        let bt = self.block_tokens;
        debug_assert_eq!(bt, cache.block_tokens(), "trie/cache block size mismatch");
        let full_chunks = std::cmp::min(tokens.len(), cache.len) / bt;
        let clock = &mut self.clock;
        let mut level = &mut self.children;
        for (ci, chunk) in tokens.chunks_exact(bt).take(full_chunks).enumerate() {
            *clock += 1;
            let stamp = *clock;
            let node = level.entry(chunk.to_vec()).or_insert_with(|| PrefixNode {
                blocks: Vec::new(),
                children: BTreeMap::new(),
                last_use: 0,
            });
            node.last_use = stamp;
            if node.blocks.is_empty() {
                node.blocks = cache
                    .layers
                    .iter()
                    .map(|l| Arc::clone(&l.blocks[ci]))
                    .collect();
            }
            level = &mut node.children;
        }
    }

    /// Evict the least-recently-used *unreferenced leaf* node, dropping
    /// its block handles back to the pool. A node is evictable when it
    /// has no children (longer cached prefixes depend on it) and no live
    /// session holds its blocks (`Arc::strong_count == 1`). Returns
    /// whether a node was evicted; repeated calls peel the tree inward.
    pub fn evict_lru(&mut self) -> bool {
        fn find_min(
            level: &BTreeMap<Vec<u32>, PrefixNode>,
            path: &mut Vec<Vec<u32>>,
            best: &mut Option<(u64, Vec<Vec<u32>>)>,
        ) {
            for (key, node) in level {
                path.push(key.clone());
                let evictable = node.children.is_empty()
                    && node.blocks.iter().all(|b| Arc::strong_count(b) == 1);
                if evictable && best.as_ref().map_or(true, |(t, _)| node.last_use < *t) {
                    *best = Some((node.last_use, path.clone()));
                }
                find_min(&node.children, path, best);
                path.pop();
            }
        }
        let mut best = None;
        find_min(&self.children, &mut Vec::new(), &mut best);
        let Some((_, path)) = best else {
            return false;
        };
        let Some((last, parents)) = path.split_last() else {
            return false;
        };
        let mut level = &mut self.children;
        for key in parents {
            match level.get_mut(key) {
                Some(node) => level = &mut node.children,
                None => return false,
            }
        }
        if level.remove(last).is_some() {
            self.evictions += 1;
            return true;
        }
        false
    }

    /// Drop every cached prefix (drain/reset). Blocks still referenced by
    /// live sessions survive through their own `Arc`s.
    pub fn clear(&mut self) {
        self.children.clear();
    }

    /// Nodes evicted under pressure so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Prefix nodes currently cached.
    pub fn nodes(&self) -> usize {
        fn count(level: &BTreeMap<Vec<u32>, PrefixNode>) -> usize {
            level.values().map(|n| 1 + count(&n.children)).sum()
        }
        count(&self.children)
    }
}

/// Everything a backend holds when paged KV is configured: the bounded
/// block pool and (optionally) the prefix trie.
#[derive(Debug)]
pub struct PagedState {
    pub pool: KvBlockPool,
    pub trie: Option<PrefixCache>,
}

impl PagedState {
    pub fn new(opts: &PagedKvOptions, d_model: usize) -> Self {
        PagedState {
            pool: KvBlockPool::new(opts.blocks, opts.block_tokens, d_model),
            trie: opts
                .prefix_cache
                .then(|| PrefixCache::new(opts.block_tokens)),
        }
    }

    /// Allocate one block, evicting LRU unreferenced prefix nodes until
    /// the allocation fits or nothing evictable remains.
    pub fn alloc_evicting(&mut self) -> Result<Arc<KvBlock>, KvPressure> {
        loop {
            match self.pool.try_alloc() {
                Ok(b) => return Ok(b),
                Err(pressure) => match &mut self.trie {
                    Some(trie) if trie.evict_lru() => continue,
                    _ => return Err(pressure),
                },
            }
        }
    }

    /// Start a session cache for `tokens`: adopt the longest cached
    /// prefix, capped so at least the final prompt token is computed
    /// (prefill must run ≥1 real step to produce next-token logits).
    /// Returns the seeded cache and the number of prompt positions whose
    /// KV rows were reused.
    pub fn start_session(&mut self, n_layers: usize, tokens: &[u32]) -> (PagedKvCache, usize) {
        let bt = self.pool.block_tokens();
        let mut cache = PagedKvCache::new(n_layers, bt);
        let mut reused = 0;
        if let Some(trie) = &mut self.trie {
            if tokens.len() > 1 {
                let max_chunks = (tokens.len() - 1) / bt;
                let hit = trie.lookup(tokens, max_chunks);
                if !hit.is_empty() {
                    for (l, layer) in cache.layers.iter_mut().enumerate() {
                        let per_layer: Vec<Arc<KvBlock>> =
                            hit.iter().map(|chunk| Arc::clone(&chunk[l])).collect();
                        layer.adopt_prefix(&per_layer);
                    }
                    reused = hit.len() * bt;
                    cache.len = reused;
                }
            }
        }
        (cache, reused)
    }

    /// Publish a just-prefilled session's full prompt chunks for reuse.
    pub fn register(&mut self, tokens: &[u32], cache: &PagedKvCache) {
        if let Some(trie) = &mut self.trie {
            trie.insert(tokens, cache);
        }
    }

    /// Drop all cached prefixes (engine drain). Pool residency left after
    /// this — with no live sessions — is a leak.
    pub fn reset(&mut self) {
        if let Some(trie) = &mut self.trie {
            trie.clear();
        }
    }

    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            capacity: self.pool.capacity(),
            in_use: self.pool.in_use(),
            peak: self.pool.peak(),
            block_tokens: self.pool.block_tokens(),
            evictions: self.trie.as_ref().map_or(0, |t| t.evictions()),
            trie_nodes: self.trie.as_ref().map_or(0, |t| t.nodes()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{KvSeq, KvSeqStore};

    const D: usize = 2;

    fn state(blocks: usize, bt: usize, prefix: bool) -> PagedState {
        PagedState::new(
            &PagedKvOptions {
                blocks,
                block_tokens: bt,
                prefix_cache: prefix,
            },
            D,
        )
    }

    /// Prefill `toks` into a fresh session cache (deterministic fake KV
    /// rows keyed by token/position/layer), registering it in the trie.
    fn prefill(ps: &mut PagedState, n_layers: usize, toks: &[u32]) -> (PagedKvCache, usize) {
        let (mut cache, reused) = ps.start_session(n_layers, toks);
        for (pos, &t) in toks.iter().enumerate().skip(reused) {
            cache
                .reserve_append(&mut || ps.alloc_evicting())
                .expect("pool has room (tests size it generously)");
            for l in 0..n_layers {
                let x = t as f32 + pos as f32 * 0.25 + l as f32 * 100.0;
                cache.layers[l].push_row(&[x; D], &[-x; D]);
            }
            cache.advance();
        }
        ps.register(toks, &cache);
        (cache, reused)
    }

    #[test]
    fn lookup_misses_then_hits_shared_chunks() {
        let mut ps = state(64, 2, true);
        let toks: Vec<u32> = vec![5, 6, 7, 8, 9]; // 2 full chunks + 1 tail token
        let (first, reused) = prefill(&mut ps, 2, &toks);
        assert_eq!(reused, 0, "cold trie cannot reuse");

        let (second, reused) = ps.start_session(2, &toks);
        assert_eq!(reused, 4, "both full chunks reused; tail token computed");
        assert_eq!(second.len, 4);
        for l in 0..2 {
            for j in 0..4 {
                assert_eq!(
                    second.layers[l].k_row(j, D),
                    first.layers[l].k_row(j, D),
                    "layer {l} row {j} is the same physical block"
                );
            }
        }
    }

    #[test]
    fn reuse_always_leaves_a_tail_token() {
        let mut ps = state(64, 2, true);
        let toks: Vec<u32> = vec![1, 2, 3, 4]; // prompt length = 2 blocks exactly
        prefill(&mut ps, 1, &toks);
        let (_, reused) = ps.start_session(1, &toks);
        assert_eq!(reused, 2, "final block not reused: the last token must be computed");
        let (_, reused_single) = ps.start_session(1, &[1]);
        assert_eq!(reused_single, 0, "single-token prompt never reuses");
    }

    #[test]
    fn divergent_suffixes_share_only_the_common_prefix() {
        let mut ps = state(64, 2, true);
        prefill(&mut ps, 1, &[1, 2, 3, 4, 5]);
        let (cache, reused) = ps.start_session(1, &[1, 2, 9, 9, 9]);
        assert_eq!(reused, 2, "only the first chunk matches");
        assert_eq!(cache.len, 2);
        let (_, reused) = ps.start_session(1, &[7, 7, 7, 7, 7]);
        assert_eq!(reused, 0, "no shared prefix, no reuse");
    }

    #[test]
    fn prefix_cache_off_never_reuses() {
        let mut ps = state(64, 2, false);
        let toks: Vec<u32> = vec![1, 2, 3, 4, 5];
        prefill(&mut ps, 1, &toks);
        let (_, reused) = ps.start_session(1, &toks);
        assert_eq!(reused, 0);
        assert_eq!(ps.stats().trie_nodes, 0);
    }

    #[test]
    fn eviction_skips_referenced_blocks_and_peels_lru_first() {
        let mut ps = state(64, 2, true);
        let (held, _) = prefill(&mut ps, 1, &[1, 2, 3, 4, 5]); // chunks [1,2],[3,4] held alive
        prefill(&mut ps, 1, &[8, 9, 8, 9, 8]); // chunks [8,9],[8,9]
        // drop the second session; its trie nodes become unreferenced
        assert_eq!(ps.stats().trie_nodes, 4);
        let trie = ps.trie.as_mut().unwrap();
        assert!(trie.evict_lru(), "unreferenced leaf evicts");
        assert!(trie.evict_lru(), "parent became an unreferenced leaf");
        assert!(
            !trie.evict_lru(),
            "remaining nodes are held by the live session"
        );
        assert_eq!(ps.stats().evictions, 2);
        assert_eq!(ps.stats().trie_nodes, 2);
        drop(held);
        assert!(ps.trie.as_mut().unwrap().evict_lru(), "now evictable");
    }

    #[test]
    fn alloc_evicting_reclaims_trie_blocks_under_pressure() {
        // pool of 4 blocks, 1 layer: a 5-token prompt (bt=2) uses 3.
        let mut ps = state(4, 2, true);
        let (cache, _) = prefill(&mut ps, 1, &[1, 2, 3, 4, 5]);
        drop(cache); // trie still holds 2 full-chunk blocks; 1 block freed
        assert_eq!(ps.stats().in_use, 2);
        let a = ps.alloc_evicting().unwrap();
        let b = ps.alloc_evicting().unwrap();
        assert_eq!(ps.stats().in_use, 4, "pool full: 2 trie blocks + 2 fresh");
        let c = ps.alloc_evicting().unwrap(); // evicts the LRU prefix node
        let d = ps.alloc_evicting().unwrap(); // evicts the last prefix node
        assert_eq!(ps.stats().evictions, 2);
        assert_eq!(ps.stats().trie_nodes, 0);
        assert_eq!(ps.stats().in_use, 4);
        assert!(ps.alloc_evicting().is_err(), "nothing left to evict");
        drop((a, b, c, d));
        assert_eq!(ps.stats().in_use, 0, "no leaks after drops");
    }

    #[test]
    fn reset_clears_trie_and_frees_unreferenced_blocks() {
        let mut ps = state(64, 2, true);
        let (cache, _) = prefill(&mut ps, 2, &[1, 2, 3, 4, 5]);
        drop(cache);
        assert!(ps.stats().in_use > 0, "trie keeps full chunks resident");
        ps.reset();
        assert_eq!(ps.stats().trie_nodes, 0);
        assert_eq!(ps.stats().in_use, 0, "reset releases the last references");
    }

    #[test]
    fn stats_surface_pool_counters() {
        let mut ps = state(8, 4, true);
        let s = ps.stats();
        assert_eq!((s.capacity, s.in_use, s.peak, s.block_tokens), (8, 0, 0, 4));
        let _b = ps.alloc_evicting().unwrap();
        let s = ps.stats();
        assert_eq!((s.in_use, s.peak), (1, 1));
    }
}
