"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package is validated against these references by
python/tests/test_kernels.py across a sweep of shapes and seeds.
"""

import jax
import jax.numpy as jnp


def cov_accum(c, x):
    """C + X^T X over the token axis. x: [l, d] row-major tokens."""
    return c + x.T @ x


def cross_cov_accum(c, a, b):
    """C + A^T B — the cross-covariance term of the anchored objective.

    In the paper's column-major notation this is  C += A B^T  with
    A = X (original inputs) and B = X' (shifted inputs).
    """
    return c + a.T @ b


def lowrank_apply(u, v, x):
    """y = x V U^T, i.e. the factorized linear (U V^T) applied to rows of x.

    u: [m, k], v: [n, k], x: [l, n] -> [l, m].
    """
    return (x @ v) @ u.T


def attention_head(q, k, v, scale):
    """Single-head causal attention. q,k,v: [t, hd] -> [t, hd]."""
    t = q.shape[0]
    scores = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return probs @ v
