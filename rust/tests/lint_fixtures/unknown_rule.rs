// aasvd-lint: allow(flux-capacitor): not a real rule, must be reported as a malformed directive

pub fn nothing() {}
