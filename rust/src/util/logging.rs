//! Leveled, timestamped logger. `AASVD_LOG=debug|info|warn|quiet`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Quiet = 3,
}

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    // aasvd-lint: allow(env-var): log verbosity only — cannot change any computed result
    let from_env = match std::env::var("AASVD_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("quiet") => Level::Quiet,
        _ => Level::Info,
    } as u8;
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(l: Level, msg: &str) {
    if (l as u8) >= level() && l != Level::Quiet {
        let tag = match l {
            Level::Debug => "DBG",
            Level::Info => "INF",
            Level::Warn => "WRN",
            Level::Quiet => return,
        };
        eprintln!("[{:9.2}s {tag}] {msg}", elapsed());
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
