//! Pure-Rust reference transformer forward pass.
//!
//! This is the *validation* path: it must match the AOT HLO artifacts to
//! f32 tolerance (enforced by integration tests) and serves as a PJRT-free
//! fallback for tools. The hot paths (calibration sweeps, refinement, eval,
//! serving) run the XLA artifacts instead.
//!
//! Activation tensors are flat f32 in [batch, time, dim] row-major order.

use super::config::Config;
use super::params::FlatStore;
use crate::compress::quant::QuantMatrix;
use crate::util::pool::Pool;

pub const NORM_EPS: f32 = 1e-5;
const MASK_NEG: f32 = -1e30;

/// y = rmsnorm(x) * g over the last axis. x: [.., d].
pub fn rmsnorm(x: &[f32], g: &[f32], d: usize, out: &mut [f32]) {
    assert_eq!(x.len() % d, 0);
    assert_eq!(g.len(), d);
    for (xr, yr) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + NORM_EPS).sqrt();
        for j in 0..d {
            yr[j] = xr[j] * inv * g[j];
        }
    }
}

/// y = x W^T with W row-major [m, n]; x: [rows, n] -> y: [rows, m].
pub fn linear(x: &[f32], w: &[f32], n: usize, m: usize, out: &mut [f32]) {
    let rows = x.len() / n;
    assert_eq!(x.len(), rows * n);
    assert_eq!(w.len(), m * n);
    assert_eq!(out.len(), rows * m);
    for (xr, yr) in x.chunks_exact(n).zip(out.chunks_exact_mut(m)) {
        for (j, yj) in yr.iter_mut().enumerate() {
            let wrow = &w[j * n..(j + 1) * n];
            let mut acc = 0.0f32;
            for (xv, wv) in xr.iter().zip(wrow) {
                acc += xv * wv;
            }
            *yj = acc;
        }
    }
}

/// Rotary embedding for one position's head row (`row`: [hd]) at absolute
/// position `pos`. Pairs are interleaved (even, odd) — matches
/// model.apply_rope. The packed [`apply_rope`] and the KV-cached
/// [`attention_step`] both go through here, so a cached position is roped
/// with exactly the ops the full forward would use.
pub fn apply_rope_row(row: &mut [f32], pos: usize, hd: usize, theta: f64) {
    for i in 0..hd / 2 {
        let freq = 1.0 / theta.powf(2.0 * i as f64 / hd as f64);
        let ang = pos as f64 * freq;
        let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
        let (a, b) = (row[2 * i], row[2 * i + 1]);
        row[2 * i] = a * cos - b * sin;
        row[2 * i + 1] = a * sin + b * cos;
    }
}

/// Rotary embedding applied in place to one head's [T, hd] block.
pub fn apply_rope(x: &mut [f32], t: usize, hd: usize, theta: f64) {
    assert_eq!(x.len(), t * hd);
    for (pos, row) in x.chunks_exact_mut(hd).enumerate() {
        apply_rope_row(row, pos, hd, theta);
    }
}

/// y = x W^T like [`linear`], with the output rows cut into contiguous
/// bands solved in parallel on `pool` — the batched-decode twin of the
/// single-row projections. Every band runs the row kernel of [`linear`]
/// unchanged, and rows never share accumulators, so each output row is
/// **bitwise identical** to its single-row `linear` call at any worker
/// count (the same contract as the f64 banded matmuls in
/// `linalg::matrix`).
pub fn linear_batch(x: &[f32], w: &[f32], n: usize, m: usize, pool: &Pool, out: &mut [f32]) {
    let rows = x.len() / n;
    let bands = if pool.threads() <= 1 {
        1
    } else {
        pool.threads().min(rows)
    };
    if bands <= 1 {
        linear(x, w, n, m, out);
        return;
    }
    let rows_per = rows.div_ceil(bands);
    let jobs: Vec<_> = x
        .chunks(rows_per * n)
        .zip(out.chunks_mut(rows_per * m))
        .map(|(xb, ob)| move || linear(xb, w, n, m, ob))
        .collect();
    pool.run(jobs);
}

/// y = x W^T with W int8-quantized [m, n], dequantized **in-register**:
/// each weight is reconstructed as `q as f32 * scale` right at its
/// multiply, never materializing an f32 weight matrix. Because
/// [`QuantMatrix::dequantize`] produces exactly `q as f32 * scale` per
/// element and this loop runs [`linear`]'s index order unchanged, the
/// output is **bitwise identical** to `linear(x, &w.dequantize(), ..)` —
/// the oracle tests/quantized_backend.rs pins.
pub fn qlinear(x: &[f32], w: &QuantMatrix, out: &mut [f32]) {
    let (m, n) = (w.rows, w.cols);
    let rows = x.len() / n;
    assert_eq!(x.len(), rows * n);
    assert_eq!(w.data.len(), m * n);
    assert_eq!(out.len(), rows * m);
    for (xr, yr) in x.chunks_exact(n).zip(out.chunks_exact_mut(m)) {
        for (j, yj) in yr.iter_mut().enumerate() {
            let qrow = &w.data[j * n..(j + 1) * n];
            let srow = w.scale_row(j);
            let mut acc = 0.0f32;
            for ((xv, &qv), &sv) in xr.iter().zip(qrow).zip(srow) {
                acc += xv * (qv as f32 * sv);
            }
            *yj = acc;
        }
    }
}

/// Row-banded [`qlinear`]: the int8 twin of [`linear_batch`], with the
/// same banding rule — so every output row is **bitwise identical** to
/// its single-band `qlinear` result at any worker count, and therefore
/// to the dequantize-then-`linear_batch` oracle.
pub fn qlinear_batch(x: &[f32], w: &QuantMatrix, pool: &Pool, out: &mut [f32]) {
    let (m, n) = (w.rows, w.cols);
    let rows = x.len() / n;
    let bands = if pool.threads() <= 1 {
        1
    } else {
        pool.threads().min(rows)
    };
    if bands <= 1 {
        qlinear(x, w, out);
        return;
    }
    let rows_per = rows.div_ceil(bands);
    let jobs: Vec<_> = x
        .chunks(rows_per * n)
        .zip(out.chunks_mut(rows_per * m))
        .map(|(xb, ob)| move || qlinear(xb, w, ob))
        .collect();
    pool.run(jobs);
}

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Softmax over the last `n` entries of each row, in place.
fn softmax_rows(x: &mut [f32], n: usize) {
    for row in x.chunks_exact_mut(n) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Causal multi-head attention over already-projected q/k/v: [B, T, d].
pub fn attention(cfg: &Config, q: &mut [f32], k: &mut [f32], v: &[f32], t: usize) -> Vec<f32> {
    let (d, h) = (cfg.d_model, cfg.n_heads);
    let hd = cfg.head_dim();
    let b = q.len() / (t * d);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; b * t * d];

    // scratch per (batch, head)
    let mut qh = vec![0.0f32; t * hd];
    let mut kh = vec![0.0f32; t * hd];
    let mut scores = vec![0.0f32; t * t];

    for bi in 0..b {
        for hi in 0..h {
            // gather head slices (strided) into contiguous buffers
            for pos in 0..t {
                let src = bi * t * d + pos * d + hi * hd;
                qh[pos * hd..(pos + 1) * hd].copy_from_slice(&q[src..src + hd]);
                kh[pos * hd..(pos + 1) * hd].copy_from_slice(&k[src..src + hd]);
            }
            apply_rope(&mut qh, t, hd, cfg.rope_theta);
            apply_rope(&mut kh, t, hd, cfg.rope_theta);
            // scores = qh kh^T * scale with causal mask
            for i in 0..t {
                let qrow = &qh[i * hd..(i + 1) * hd];
                for j in 0..t {
                    scores[i * t + j] = if j > i {
                        MASK_NEG
                    } else {
                        let krow = &kh[j * hd..(j + 1) * hd];
                        let mut acc = 0.0;
                        for (a, b_) in qrow.iter().zip(krow) {
                            acc += a * b_;
                        }
                        acc * scale
                    };
                }
            }
            softmax_rows(&mut scores, t);
            // out = probs @ v_head
            for i in 0..t {
                let dst = bi * t * d + i * d + hi * hd;
                let prow = &scores[i * t..i * t + t];
                let orow = &mut out[dst..dst + hd];
                orow.fill(0.0);
                for j in 0..=i {
                    let p = prow[j];
                    if p == 0.0 {
                        continue;
                    }
                    let vsrc = bi * t * d + j * d + hi * hd;
                    for (o, vv) in orow.iter_mut().zip(&v[vsrc..vsrc + hd]) {
                        *o += p * vv;
                    }
                }
            }
        }
    }
    out
}

/// Read/append view over one layer's KV rows — the storage interface
/// [`attention_step`] walks. Implemented by the contiguous [`LayerKv`]
/// and by the paged `model::paged_kv::PagedLayer`, so dense-buffer and
/// block-table storage run the *same* kernel code: the float ops and
/// their order never depend on the layout, which is what makes paged
/// decode bitwise identical to the contiguous path by construction.
///
/// Row width `d` (= d_model) is passed explicitly: rows are opaque
/// [d]-float K and V slices, roped/raw exactly as [`attention_step`]
/// produced them.
pub trait KvSeq {
    /// Rows currently stored (positions absorbed into this layer).
    fn seq_rows(&self, d: usize) -> usize;
    /// Append one roped key row and one raw value row (each [d]). Paged
    /// implementations require a reserved tail block with room for the
    /// row — reservation happens outside the kernels (and outside any
    /// parallel band), so `push_row` itself never allocates.
    fn push_row(&mut self, k: &[f32], v: &[f32]);
    /// The j-th key row, contiguous [d].
    fn k_row(&self, j: usize, d: usize) -> &[f32];
    /// The j-th value row, contiguous [d].
    fn v_row(&self, j: usize, d: usize) -> &[f32];
}

/// A per-request store of [`KvSeq`] layers the model-level step functions
/// are generic over — contiguous ([`KvCache`]) or paged
/// (`model::paged_kv::PagedKvCache`). Both run literally the same
/// forward code.
pub trait KvSeqStore {
    type Layer: KvSeq + Send;
    fn n_layers(&self) -> usize;
    fn layer_mut(&mut self, i: usize) -> &mut Self::Layer;
    /// Record one more absorbed position (prompt or generated).
    fn advance(&mut self);
}

/// Per-layer KV rows for one sequence: RoPE'd keys and raw values,
/// appended one position at a time by [`attention_step`]. Layout is
/// [len, d_model] row-major with heads contiguous inside a row — the same
/// d-axis layout the packed [`attention`] gathers its head slices from.
#[derive(Clone, Debug, Default)]
pub struct LayerKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvSeq for LayerKv {
    fn seq_rows(&self, d: usize) -> usize {
        self.k.len() / d
    }

    fn push_row(&mut self, k: &[f32], v: &[f32]) {
        self.k.extend_from_slice(k);
        self.v.extend_from_slice(v);
    }

    fn k_row(&self, j: usize, d: usize) -> &[f32] {
        &self.k[j * d..(j + 1) * d]
    }

    fn v_row(&self, j: usize, d: usize) -> &[f32] {
        &self.v[j * d..(j + 1) * d]
    }
}

/// Per-request KV cache: one growing K/V row pair per layer. `len` counts
/// the positions absorbed through [`model_forward_step`] /
/// [`crate::model::lowrank::model_lr_forward_step`] (prompt + generated).
#[derive(Clone, Debug)]
pub struct KvCache {
    pub layers: Vec<LayerKv>,
    pub len: usize,
}

impl KvCache {
    pub fn new(n_layers: usize) -> KvCache {
        KvCache {
            layers: vec![LayerKv::default(); n_layers],
            len: 0,
        }
    }

    /// Cache-resident bytes (K + V rows across all layers).
    pub fn bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| (l.k.len() + l.v.len()) * std::mem::size_of::<f32>())
            .sum()
    }
}

impl KvSeqStore for KvCache {
    type Layer = LayerKv;

    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn layer_mut(&mut self, i: usize) -> &mut LayerKv {
        &mut self.layers[i]
    }

    fn advance(&mut self) {
        self.len += 1;
    }
}

/// One causal attention step against a layer's KV cache: ropes the new
/// q/k rows (all heads, [d]) at the next position, appends the roped key
/// and raw value to the cache, and returns the attention output row [d].
///
/// Cache-exactness contract: for the same prefix this returns exactly —
/// bitwise — the last row of [`attention`] over that prefix. The masked
/// full-row softmax agrees with the causal-prefix softmax here because a
/// masked position contributes `exp(MASK_NEG - mx)`, which underflows to
/// `+0.0` and leaves the running sum bit-identical; every other
/// accumulation (q·k dot, probs·v) runs in the same index order as the
/// packed kernel. Enforced by tests/kv_cache.rs.
///
/// Generic over [`KvSeq`] storage (contiguous or paged): row *reads* go
/// through `k_row`/`v_row`, which only changes where a row lives, never
/// a float op or its order — so paged attention inherits the bitwise
/// contract verbatim.
pub fn attention_step<K: KvSeq + ?Sized>(
    cfg: &Config,
    layer: &mut K,
    q: &mut [f32],
    k: &mut [f32],
    v: &[f32],
) -> Vec<f32> {
    let (d, h) = (cfg.d_model, cfg.n_heads);
    let hd = cfg.head_dim();
    assert_eq!(q.len(), d);
    assert_eq!(k.len(), d);
    assert_eq!(v.len(), d);
    let pos = layer.seq_rows(d);
    let scale = 1.0 / (hd as f32).sqrt();
    for hi in 0..h {
        apply_rope_row(&mut q[hi * hd..(hi + 1) * hd], pos, hd, cfg.rope_theta);
        apply_rope_row(&mut k[hi * hd..(hi + 1) * hd], pos, hd, cfg.rope_theta);
    }
    layer.push_row(k, v);

    let t = pos + 1;
    let mut out = vec![0.0f32; d];
    let mut scores = vec![0.0f32; t];
    for hi in 0..h {
        let qrow = &q[hi * hd..(hi + 1) * hd];
        for (j, s) in scores.iter_mut().enumerate() {
            let krow = &layer.k_row(j, d)[hi * hd..hi * hd + hd];
            let mut acc = 0.0;
            for (a, b_) in qrow.iter().zip(krow) {
                acc += a * b_;
            }
            *s = acc * scale;
        }
        let mx = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for s in scores.iter_mut() {
            *s = (*s - mx).exp();
            sum += *s;
        }
        for s in scores.iter_mut() {
            *s /= sum;
        }
        let orow = &mut out[hi * hd..(hi + 1) * hd];
        for (j, &p) in scores.iter().enumerate() {
            if p == 0.0 {
                continue;
            }
            let vrow = &layer.v_row(j, d)[hi * hd..hi * hd + hd];
            for (o, vv) in orow.iter_mut().zip(vrow) {
                *o += p * vv;
            }
        }
    }
    out
}

/// Intermediate activations collected by a dense block forward — the X_j
/// inputs Algorithm 2 feeds to CompressLayer.
pub struct BlockTaps {
    pub y: Vec<f32>,     // block output        [B, T, d]
    pub a_in: Vec<f32>,  // q/k/v input         [B, T, d]
    pub o_in: Vec<f32>,  // wo input            [B, T, d]
    pub m_in: Vec<f32>,  // gate/up input       [B, T, d]
    pub d_in: Vec<f32>,  // w_down input        [B, T, ff]
}

/// Dense transformer block forward with taps. `x`: [B, T, d].
/// `prefix` addresses the block's tensors inside `params`
/// (e.g. "blocks.3."), or "" for a bare block store.
pub fn block_forward(
    cfg: &Config,
    params: &FlatStore,
    prefix: &str,
    x: &[f32],
    t: usize,
) -> BlockTaps {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let rows = x.len() / d;
    let g = |n: &str| params.view(&format!("{prefix}{n}"));

    let mut a_in = vec![0.0; x.len()];
    rmsnorm(x, g("attn_norm"), d, &mut a_in);

    let mut q = vec![0.0; rows * d];
    let mut k = vec![0.0; rows * d];
    let mut v = vec![0.0; rows * d];
    linear(&a_in, g("wq"), d, d, &mut q);
    linear(&a_in, g("wk"), d, d, &mut k);
    linear(&a_in, g("wv"), d, d, &mut v);
    let o_in = attention(cfg, &mut q, &mut k, &v, t);

    let mut attn_out = vec![0.0; rows * d];
    linear(&o_in, g("wo"), d, d, &mut attn_out);
    let h: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

    let mut m_in = vec![0.0; h.len()];
    rmsnorm(&h, g("mlp_norm"), d, &mut m_in);
    let mut gate = vec![0.0; rows * f];
    let mut up = vec![0.0; rows * f];
    linear(&m_in, g("w_gate"), d, f, &mut gate);
    linear(&m_in, g("w_up"), d, f, &mut up);
    let d_in: Vec<f32> = gate
        .iter()
        .zip(&up)
        .map(|(&gv, &uv)| silu(gv) * uv)
        .collect();
    let mut down = vec![0.0; rows * d];
    linear(&d_in, g("w_down"), f, d, &mut down);
    let y: Vec<f32> = h.iter().zip(&down).map(|(a, b)| a + b).collect();

    BlockTaps {
        y,
        a_in,
        o_in,
        m_in,
        d_in,
    }
}

/// One-position dense block step against the layer's KV cache. `x` is the
/// hidden row [d] at the new position; returns the block output row [d].
/// Row-for-row the same ops as [`block_forward`], so it inherits the
/// cache-exactness contract of [`attention_step`].
pub fn block_forward_step<K: KvSeq>(
    cfg: &Config,
    params: &FlatStore,
    prefix: &str,
    layer: &mut K,
    x: &[f32],
) -> Vec<f32> {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let g = |n: &str| params.view(&format!("{prefix}{n}"));

    let mut a_in = vec![0.0; d];
    rmsnorm(x, g("attn_norm"), d, &mut a_in);

    let mut q = vec![0.0; d];
    let mut k = vec![0.0; d];
    let mut v = vec![0.0; d];
    linear(&a_in, g("wq"), d, d, &mut q);
    linear(&a_in, g("wk"), d, d, &mut k);
    linear(&a_in, g("wv"), d, d, &mut v);
    let o_in = attention_step(cfg, layer, &mut q, &mut k, &v);

    let mut attn_out = vec![0.0; d];
    linear(&o_in, g("wo"), d, d, &mut attn_out);
    let h: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

    let mut m_in = vec![0.0; d];
    rmsnorm(&h, g("mlp_norm"), d, &mut m_in);
    let mut gate = vec![0.0; f];
    let mut up = vec![0.0; f];
    linear(&m_in, g("w_gate"), d, f, &mut gate);
    linear(&m_in, g("w_up"), d, f, &mut up);
    let d_in: Vec<f32> = gate
        .iter()
        .zip(&up)
        .map(|(&gv, &uv)| silu(gv) * uv)
        .collect();
    let mut down = vec![0.0; d];
    linear(&d_in, g("w_down"), f, d, &mut down);
    h.iter().zip(&down).map(|(a, b)| a + b).collect()
}

/// Batched one-position dense block step: `x` stacks B hidden rows
/// [B, d], `layers` holds each session's KV rows for this block, and the
/// return stacks the B block-output rows [B, d].
///
/// The batch is cut into contiguous row bands solved in parallel on
/// `pool`; inside a band the stacked QKV/MLP projections run through the
/// multi-row [`linear`] kernel (one weight sweep per band, the row-banded
/// matmul shape) while attention stays a per-session [`attention_step`]
/// against that row's own cache. No computation ever mixes rows, and the
/// per-row ops are exactly [`block_forward_step`]'s, so every output row
/// is **bitwise identical** to the batch-1 step at any worker count.
pub fn block_forward_step_batch<K: KvSeq + Send>(
    cfg: &Config,
    params: &FlatStore,
    prefix: &str,
    layers: &mut [&mut K],
    x: &[f32],
    pool: &Pool,
) -> Vec<f32> {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let b = layers.len();
    assert_eq!(x.len(), b * d);
    if b == 0 {
        return Vec::new();
    }
    let g = |n: &str| params.view(&format!("{prefix}{n}"));
    let (attn_norm, mlp_norm) = (g("attn_norm"), g("mlp_norm"));
    let (wq, wk, wv, wo) = (g("wq"), g("wk"), g("wv"), g("wo"));
    let (w_gate, w_up, w_down) = (g("w_gate"), g("w_up"), g("w_down"));

    let mut y = vec![0.0f32; b * d];
    let bands = if pool.threads() <= 1 {
        1
    } else {
        pool.threads().min(b)
    };
    let rows_per = b.div_ceil(bands);
    let jobs: Vec<_> = x
        .chunks(rows_per * d)
        .zip(y.chunks_mut(rows_per * d))
        .zip(layers.chunks_mut(rows_per))
        .map(|((xb, yb), lb)| {
            move || {
                let rb = lb.len();
                let mut a_in = vec![0.0; rb * d];
                rmsnorm(xb, attn_norm, d, &mut a_in);

                let mut q = vec![0.0; rb * d];
                let mut k = vec![0.0; rb * d];
                let mut v = vec![0.0; rb * d];
                linear(&a_in, wq, d, d, &mut q);
                linear(&a_in, wk, d, d, &mut k);
                linear(&a_in, wv, d, d, &mut v);

                // per-session KV attention rows
                let mut o_in = vec![0.0; rb * d];
                for (r, layer) in lb.iter_mut().enumerate() {
                    let row = attention_step(
                        cfg,
                        layer,
                        &mut q[r * d..(r + 1) * d],
                        &mut k[r * d..(r + 1) * d],
                        &v[r * d..(r + 1) * d],
                    );
                    o_in[r * d..(r + 1) * d].copy_from_slice(&row);
                }

                let mut attn_out = vec![0.0; rb * d];
                linear(&o_in, wo, d, d, &mut attn_out);
                let h: Vec<f32> = xb.iter().zip(&attn_out).map(|(a, b)| a + b).collect();

                let mut m_in = vec![0.0; rb * d];
                rmsnorm(&h, mlp_norm, d, &mut m_in);
                let mut gate = vec![0.0; rb * f];
                let mut up = vec![0.0; rb * f];
                linear(&m_in, w_gate, d, f, &mut gate);
                linear(&m_in, w_up, d, f, &mut up);
                let d_in: Vec<f32> = gate
                    .iter()
                    .zip(&up)
                    .map(|(&gv, &uv)| silu(gv) * uv)
                    .collect();
                let mut down = vec![0.0; rb * d];
                linear(&d_in, w_down, f, d, &mut down);
                for (yv, (hv, dv)) in yb.iter_mut().zip(h.iter().zip(&down)) {
                    *yv = hv + dv;
                }
            }
        })
        .collect();
    pool.run(jobs);
    y
}

/// One KV-cached decode step: absorb `token` at position `cache.len` and
/// return its logits row [vocab]. Bitwise identical to the last row of
/// [`model_forward`] over the same token prefix — O(len) attention work
/// instead of O(len²) per step.
pub fn model_forward_step<S: KvSeqStore>(
    cfg: &Config,
    params: &FlatStore,
    cache: &mut S,
    token: u32,
) -> Vec<f32> {
    assert_eq!(cache.n_layers(), cfg.n_layers);
    let d = cfg.d_model;
    let tok = token as usize;
    assert!(tok < cfg.vocab, "token {tok} out of range");
    let embed = params.view("embed");
    let mut x = embed[tok * d..(tok + 1) * d].to_vec();
    for blk in 0..cfg.n_layers {
        x = block_forward_step(
            cfg,
            params,
            &format!("blocks.{blk}."),
            cache.layer_mut(blk),
            &x,
        );
    }
    cache.advance();
    let mut hn = vec![0.0; d];
    rmsnorm(&x, params.view("final_norm"), d, &mut hn);
    let mut logits = vec![0.0; cfg.vocab];
    linear(&hn, params.view("lm_head"), d, cfg.vocab, &mut logits);
    logits
}

/// Batched KV-cached decode: absorb one token per session — stacked into
/// a single [B, d] pass per layer — and return each session's logits row.
/// Row i is **bitwise identical** to `model_forward_step` on cache i with
/// token i (sessions never mix; see [`block_forward_step_batch`]), at any
/// pool width, so batched and per-session decode are interchangeable.
pub fn model_forward_step_batch<S: KvSeqStore>(
    cfg: &Config,
    params: &FlatStore,
    caches: &mut [&mut S],
    tokens: &[u32],
    pool: &Pool,
) -> Vec<Vec<f32>> {
    assert_eq!(caches.len(), tokens.len());
    let b = tokens.len();
    if b == 0 {
        return Vec::new();
    }
    for c in caches.iter() {
        assert_eq!(c.n_layers(), cfg.n_layers);
    }
    let d = cfg.d_model;
    let embed = params.view("embed");
    let mut x = vec![0.0f32; b * d];
    for (i, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < cfg.vocab, "token {tok} out of range");
        x[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    for blk in 0..cfg.n_layers {
        let mut layers: Vec<&mut S::Layer> =
            caches.iter_mut().map(|c| c.layer_mut(blk)).collect();
        x = block_forward_step_batch(
            cfg,
            params,
            &format!("blocks.{blk}."),
            &mut layers,
            &x,
            pool,
        );
    }
    for c in caches.iter_mut() {
        c.advance();
    }
    let mut hn = vec![0.0; b * d];
    rmsnorm(&x, params.view("final_norm"), d, &mut hn);
    let mut logits = vec![0.0f32; b * cfg.vocab];
    linear_batch(&hn, params.view("lm_head"), d, cfg.vocab, pool, &mut logits);
    logits.chunks_exact(cfg.vocab).map(|r| r.to_vec()).collect()
}

/// Prefill: absorb a whole prompt into `cache` and return the logits row
/// at its last position (one O(T²) pass over the prompt — the same total
/// attention work as a single full forward, not one pass per token).
pub fn model_forward_prefill<S: KvSeqStore>(
    cfg: &Config,
    params: &FlatStore,
    cache: &mut S,
    tokens: &[u32],
) -> Vec<f32> {
    assert!(!tokens.is_empty(), "prefill needs at least one token");
    let mut logits = Vec::new();
    for &tok in tokens {
        logits = model_forward_step(cfg, params, cache, tok);
    }
    logits
}

/// Full dense model forward: tokens [B, T] -> logits [B, T, vocab].
pub fn model_forward(cfg: &Config, params: &FlatStore, tokens: &[u32], t: usize) -> Vec<f32> {
    let d = cfg.d_model;
    let b = tokens.len() / t;
    let embed = params.view("embed");
    let mut x = vec![0.0f32; b * t * d];
    for (i, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        assert!(tok < cfg.vocab, "token {tok} out of range");
        x[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    for blk in 0..cfg.n_layers {
        let taps = block_forward(cfg, params, &format!("blocks.{blk}."), &x, t);
        x = taps.y;
    }
    let mut hn = vec![0.0; x.len()];
    rmsnorm(&x, params.view("final_norm"), d, &mut hn);
    let mut logits = vec![0.0; b * t * cfg.vocab];
    linear(&hn, params.view("lm_head"), d, cfg.vocab, &mut logits);
    logits
}

/// Per-token NLL of `targets` under the model: [B, T].
pub fn model_nll(
    cfg: &Config,
    params: &FlatStore,
    tokens: &[u32],
    targets: &[u32],
    t: usize,
) -> Vec<f32> {
    let logits = model_forward(cfg, params, tokens, t);
    nll_from_logits(&logits, targets, cfg.vocab)
}

pub fn nll_from_logits(logits: &[f32], targets: &[u32], vocab: usize) -> Vec<f32> {
    assert_eq!(logits.len(), targets.len() * vocab);
    logits
        .chunks_exact(vocab)
        .zip(targets)
        .map(|(row, &tgt)| {
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logz: f32 =
                mx + row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln();
            logz - row[tgt as usize]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::model::params::param_layout;
    use crate::util::rng::Rng;

    fn setup() -> (Config, FlatStore) {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(3));
        (cfg, params)
    }

    #[test]
    fn rmsnorm_unit_gain_normalizes() {
        let d = 4;
        let x = vec![2.0f32, 2.0, 2.0, 2.0];
        let g = vec![1.0f32; d];
        let mut y = vec![0.0; d];
        rmsnorm(&x, &g, d, &mut y);
        // rms = 2 -> y ≈ 1
        for v in y {
            assert!((v - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn linear_hand_example() {
        // W = [[1,2],[3,4],[5,6]] (m=3, n=2); x = [1, 1] -> y = [3, 7, 11]
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = vec![1.0, 1.0];
        let mut y = vec![0.0; 3];
        linear(&x, &w, 2, 3, &mut y);
        assert_eq!(y, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn rope_preserves_norm_and_pos0() {
        let t = 4;
        let hd = 8;
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        apply_rope(&mut x, t, hd, 10000.0);
        // position 0 unchanged (angle 0)
        assert_eq!(&x[..hd], &orig[..hd]);
        // rotation preserves pairwise norms
        for pos in 0..t {
            let n0: f32 = orig[pos * hd..(pos + 1) * hd].iter().map(|v| v * v).sum();
            let n1: f32 = x[pos * hd..(pos + 1) * hd].iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() < 1e-3 * n0.max(1.0));
        }
    }

    #[test]
    fn model_forward_shapes_and_finite() {
        let (cfg, params) = setup();
        let t = cfg.seq;
        let tokens: Vec<u32> = (0..2 * t).map(|i| (i % cfg.vocab) as u32).collect();
        let logits = model_forward(&cfg, &params, &tokens, t);
        assert_eq!(logits.len(), 2 * t * cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn model_forward_is_causal() {
        let (cfg, params) = setup();
        let t = cfg.seq;
        let mut rng = Rng::new(9);
        let tokens: Vec<u32> = (0..t).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut tokens2 = tokens.clone();
        for v in tokens2[t / 2..].iter_mut() {
            *v = (*v + 13) % cfg.vocab as u32;
        }
        let l1 = model_forward(&cfg, &params, &tokens, t);
        let l2 = model_forward(&cfg, &params, &tokens2, t);
        let cut = (t / 2) * cfg.vocab;
        crate::testkit::approx::assert_close_f32(&l1[..cut], &l2[..cut], 1e-5);
        assert!(l1[cut..] != l2[cut..]);
    }

    #[test]
    fn block_taps_reconstruct_output() {
        let (cfg, params) = setup();
        let t = cfg.seq;
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..2 * t * cfg.d_model).map(|_| rng.normal() * 0.5).collect();
        let taps = block_forward(&cfg, &params, "blocks.0.", &x, t);
        // y = (x + wo(o_in)) + w_down(d_in)
        let d = cfg.d_model;
        let rows = x.len() / d;
        let mut wo_out = vec![0.0; rows * d];
        linear(&taps.o_in, params.view("blocks.0.wo"), d, d, &mut wo_out);
        let mut down = vec![0.0; rows * d];
        linear(&taps.d_in, params.view("blocks.0.w_down"), cfg.d_ff, d, &mut down);
        let y2: Vec<f32> = x
            .iter()
            .zip(&wo_out)
            .zip(&down)
            .map(|((a, b), c)| a + b + c)
            .collect();
        crate::testkit::approx::assert_close_f32(&taps.y, &y2, 1e-4);
    }

    #[test]
    fn nll_matches_manual() {
        let logits = vec![0.0f32, 0.0, 0.0, 1.0, 0.0, 0.0];
        let nll = nll_from_logits(&logits, &[1, 0], 3);
        let unif = (3.0f32).ln();
        assert!((nll[0] - unif).abs() < 1e-5);
        assert!(nll[1] < unif); // target 0 holds the highest logit in row 2
        // and picking a low-logit target costs more than uniform
        let nll_bad = nll_from_logits(&logits[3..], &[1], 3);
        assert!(nll_bad[0] > unif);
    }

    #[test]
    fn batch_independence() {
        let (cfg, params) = setup();
        let t = cfg.seq;
        let mut rng = Rng::new(6);
        let seq_a: Vec<u32> = (0..t).map(|_| rng.below(cfg.vocab) as u32).collect();
        let seq_b: Vec<u32> = (0..t).map(|_| rng.below(cfg.vocab) as u32).collect();
        let solo = model_forward(&cfg, &params, &seq_a, t);
        let both: Vec<u32> = seq_a.iter().chain(&seq_b).cloned().collect();
        let batched = model_forward(&cfg, &params, &both, t);
        crate::testkit::approx::assert_close_f32(
            &solo,
            &batched[..t * cfg.vocab],
            1e-5,
        );
    }

    #[test]
    fn param_layout_matches_store() {
        let (cfg, params) = setup();
        assert_eq!(params.data.len(), param_layout(&cfg).total);
    }

    #[test]
    fn cached_step_matches_full_forward_bitwise() {
        let (cfg, params) = setup();
        let mut rng = Rng::new(77);
        // run past cfg.seq: the cached path has no window
        let n = cfg.seq + 5;
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(cfg.vocab) as u32).collect();
        let mut cache = KvCache::new(cfg.n_layers);
        for (p, &tok) in tokens.iter().enumerate() {
            let step = model_forward_step(&cfg, &params, &mut cache, tok);
            let full = model_forward(&cfg, &params, &tokens[..=p], p + 1);
            let want = &full[p * cfg.vocab..];
            for (i, (a, b)) in step.iter().zip(want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "pos {p} logit {i}: {a} vs {b}");
            }
        }
        assert_eq!(cache.len, n);
        // K + V rows: n positions x n_layers x 2 x d floats
        assert_eq!(cache.bytes(), n * cfg.n_layers * 2 * cfg.d_model * 4);
    }

    #[test]
    fn prefill_equals_step_loop() {
        let (cfg, params) = setup();
        let tokens: Vec<u32> = (0..10).map(|i| (i * 13 % cfg.vocab) as u32).collect();
        let mut c1 = KvCache::new(cfg.n_layers);
        let pre = model_forward_prefill(&cfg, &params, &mut c1, &tokens);
        let mut c2 = KvCache::new(cfg.n_layers);
        let mut step = Vec::new();
        for &tok in &tokens {
            step = model_forward_step(&cfg, &params, &mut c2, tok);
        }
        assert_eq!(pre, step);
        assert_eq!(c1.len, c2.len);
        assert_eq!(c1.bytes(), c2.bytes());
    }

    #[test]
    fn batched_step_rows_match_single_steps_bitwise() {
        let (cfg, params) = setup();
        let b = 3;
        // distinct prefixes of distinct lengths per session
        let prompts: Vec<Vec<u32>> = (0..b)
            .map(|r| (0..3 + r).map(|i| ((i * 19 + r * 7) % cfg.vocab) as u32).collect())
            .collect();
        let mut batched: Vec<KvCache> = prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(cfg.n_layers);
                model_forward_prefill(&cfg, &params, &mut c, p);
                c
            })
            .collect();
        let mut solo = batched.clone();
        let pool = crate::util::pool::Pool::exact(2);
        for step in 0..4usize {
            let toks: Vec<u32> =
                (0..b).map(|r| ((r * 29 + step * 13) % cfg.vocab) as u32).collect();
            let mut refs: Vec<&mut KvCache> = batched.iter_mut().collect();
            let rows = model_forward_step_batch(&cfg, &params, &mut refs, &toks, &pool);
            assert_eq!(rows.len(), b);
            for (r, row) in rows.iter().enumerate() {
                let want = model_forward_step(&cfg, &params, &mut solo[r], toks[r]);
                for (i, (a, b_)) in row.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b_.to_bits(),
                        "row {r} step {step} logit {i}: {a} vs {b_}"
                    );
                }
            }
        }
        // the caches advanced exactly as the single-row steps did
        for (cb, cs) in batched.iter().zip(&solo) {
            assert_eq!(cb.len, cs.len);
            for (lb, ls) in cb.layers.iter().zip(&cs.layers) {
                assert_eq!(lb.k, ls.k);
                assert_eq!(lb.v, ls.v);
            }
        }
        // empty batch is a no-op
        let rows = model_forward_step_batch(&cfg, &params, &mut [], &[], &pool);
        assert!(rows.is_empty());
    }

    #[test]
    fn linear_batch_matches_linear_at_any_width() {
        let mut rng = Rng::new(41);
        let (rows, n, m) = (7, 24, 17);
        let x: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut want = vec![0.0; rows * m];
        linear(&x, &w, n, m, &mut want);
        for threads in [1usize, 2, 4, 16] {
            let mut got = vec![0.0; rows * m];
            linear_batch(&x, &w, n, m, &crate::util::pool::Pool::exact(threads), &mut got);
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "linear_batch diverged at {threads} threads"
            );
        }
    }

    #[test]
    fn qlinear_is_bitwise_equal_to_dequantize_then_linear() {
        let mut rng = Rng::new(51);
        let (rows, n, m) = (5, 24, 17);
        let x: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
        let wf: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let w = QuantMatrix::quantize(&wf, m, n).unwrap();
        let mut want = vec![0.0; rows * m];
        linear(&x, &w.dequantize(), n, m, &mut want);
        let mut got = vec![0.0; rows * m];
        qlinear(&x, &w, &mut got);
        assert!(
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "fused qlinear diverged from the dequant oracle"
        );
        for threads in [1usize, 2, 4, 16] {
            let mut banded = vec![0.0; rows * m];
            qlinear_batch(&x, &w, &crate::util::pool::Pool::exact(threads), &mut banded);
            assert!(
                banded.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "qlinear_batch diverged at {threads} threads"
            );
        }
    }

    #[test]
    fn qlinear_grouped_scales_stay_bitwise_exact() {
        // force multiple scale groups (m > QUANT_GROUP_ROWS)
        let mut rng = Rng::new(52);
        let (rows, n, m) = (3, 8, crate::compress::quant::QUANT_GROUP_ROWS + 40);
        let x: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
        let wf: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let w = QuantMatrix::quantize(&wf, m, n).unwrap();
        assert!(w.n_groups() > 1);
        let mut want = vec![0.0; rows * m];
        linear(&x, &w.dequantize(), n, m, &mut want);
        let mut got = vec![0.0; rows * m];
        qlinear(&x, &w, &mut got);
        assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn apply_rope_row_consistent_with_packed() {
        let t = 6;
        let hd = 8;
        let mut rng = Rng::new(2);
        let orig: Vec<f32> = (0..t * hd).map(|_| rng.normal()).collect();
        let mut packed = orig.clone();
        apply_rope(&mut packed, t, hd, 10000.0);
        for pos in 0..t {
            let mut row = orig[pos * hd..(pos + 1) * hd].to_vec();
            apply_rope_row(&mut row, pos, hd, 10000.0);
            assert_eq!(&row[..], &packed[pos * hd..(pos + 1) * hd]);
        }
    }
}
