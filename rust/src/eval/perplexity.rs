//! Perplexity evaluation over held-out corpora (the PPL columns of every
//! table): exp(mean per-token NLL) via the model_nll / model_lr_nll
//! artifacts, masking padded batch rows.

use crate::data::TokenBatch;
use crate::model::forward::nll_from_logits;
use crate::model::lowrank::{concat_factors, model_lr_forward, BlockFactors};
use crate::model::quant_lowrank::{model_q_forward, QuantBlockFactors};
use crate::model::{Config, FlatStore};
use crate::runtime::{Engine, Value};
use anyhow::Result;

/// Mean NLL -> PPL over the real rows of `batches` for the dense model.
pub fn dense_ppl(
    engine: &Engine,
    cfg: &Config,
    params: &FlatStore,
    batches: &[TokenBatch],
) -> Result<f64> {
    let mut total = 0f64;
    let mut count = 0usize;
    for tb in batches {
        let out = engine.run(
            &cfg.name,
            "model_nll",
            &[
                Value::F32(&params.data),
                Value::I32(&tb.tokens),
                Value::I32(&tb.targets),
            ],
        )?;
        accumulate(&out[0].f32, tb, cfg, &mut total, &mut count);
    }
    Ok((total / count.max(1) as f64).exp())
}

/// PPL of a compressed model (dense embed/head + low-rank blocks).
pub fn compressed_ppl(
    engine: &Engine,
    cfg: &Config,
    params: &FlatStore,
    blocks: &[BlockFactors],
    batches: &[TokenBatch],
) -> Result<f64> {
    let (fs, ms) = concat_factors(blocks);
    let mut total = 0f64;
    let mut count = 0usize;
    for tb in batches {
        let out = engine.run(
            &cfg.name,
            "model_lr_nll",
            &[
                Value::F32(&params.data),
                Value::F32(&fs),
                Value::F32(&ms),
                Value::I32(&tb.tokens),
                Value::I32(&tb.targets),
            ],
        )?;
        accumulate(&out[0].f32, tb, cfg, &mut total, &mut count);
    }
    Ok((total / count.max(1) as f64).exp())
}

/// Artifact-free PPL of an f32 low-rank model through the pure-Rust
/// reference forward — no Engine needed. The baseline that
/// [`quant_ppl`] deltas are measured against (benches, CI gates).
pub fn lowrank_ppl(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[BlockFactors],
    batches: &[TokenBatch],
) -> f64 {
    ppl_with(cfg, batches, |toks, t| {
        model_lr_forward(cfg, params, blocks, toks, t)
    })
}

/// Artifact-free PPL of an int8-quantized low-rank model through the
/// fused-dequant reference forward.
pub fn quant_ppl(
    cfg: &Config,
    params: &FlatStore,
    blocks: &[QuantBlockFactors],
    batches: &[TokenBatch],
) -> f64 {
    ppl_with(cfg, batches, |toks, t| {
        model_q_forward(cfg, params, blocks, toks, t)
    })
}

fn ppl_with(
    cfg: &Config,
    batches: &[TokenBatch],
    forward: impl Fn(&[u32], usize) -> Vec<f32>,
) -> f64 {
    let mut total = 0f64;
    let mut count = 0usize;
    for tb in batches {
        let toks: Vec<u32> = tb.tokens.iter().map(|&t| t as u32).collect();
        let tgts: Vec<u32> = tb.targets.iter().map(|&t| t as u32).collect();
        let logits = forward(&toks, cfg.seq);
        let nll = nll_from_logits(&logits, &tgts, cfg.vocab);
        accumulate(&nll, tb, cfg, &mut total, &mut count);
    }
    (total / count.max(1) as f64).exp()
}

fn accumulate(nll: &[f32], tb: &TokenBatch, cfg: &Config, total: &mut f64, count: &mut usize) {
    let t = cfg.seq;
    for row in 0..tb.real_rows {
        for v in &nll[row * t..(row + 1) * t] {
            *total += *v as f64;
        }
        *count += t;
    }
}

/// Cap a PPL for display the way the paper does for degenerate models.
pub fn display_ppl(p: f64) -> String {
    if !p.is_finite() || p > 1e6 {
        format!("{:.0e}", p.min(1e30))
    } else if p >= 100.0 {
        format!("{p:.0}")
    } else {
        format!("{p:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Batcher, Corpus, Domain};
    use crate::model::init::init_params;
    use crate::model::lowrank::exact_factors;
    use crate::util::rng::Rng;

    #[test]
    fn display_formats() {
        assert_eq!(display_ppl(5.684), "5.68");
        assert_eq!(display_ppl(438.58), "439");
        assert_eq!(display_ppl(5e7), "5e7");
        assert_eq!(display_ppl(f64::INFINITY), "1e30");
    }

    #[test]
    fn quant_ppl_tracks_lowrank_ppl() {
        use crate::model::quant_lowrank::QuantBlockFactors;
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(5));
        let corpus = Corpus::generate(Domain::Wiki, 20_000, 5);
        let batches: Vec<_> = Batcher::new(cfg.batch, cfg.seq)
            .sequential(&corpus.valid, 2);
        let blocks: Vec<_> = (0..cfg.n_layers)
            .map(|i| exact_factors(&cfg, &params, i))
            .collect();
        let qblocks: Vec<_> = blocks
            .iter()
            .map(|bf| QuantBlockFactors::from_block(&cfg, bf).unwrap())
            .collect();
        let lr = lowrank_ppl(&cfg, &params, &blocks, &batches);
        let q = quant_ppl(&cfg, &params, &qblocks, &batches);
        assert!(lr.is_finite() && q.is_finite());
        // int8 rounding moves PPL a little, not qualitatively
        assert!((q - lr).abs() < 0.10 * lr, "lowrank {lr} vs quant {q}");
    }

    #[test]
    fn random_model_ppl_near_vocab_size() {
        let Ok(engine) = Engine::new("artifacts") else { return };
        if engine.entry("tiny").is_err() {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(1));
        let corpus = Corpus::generate(Domain::Wiki, 20_000, 1);
        let batches: Vec<_> = Batcher::new(cfg.batch, cfg.seq)
            .sequential(&corpus.test, 4);
        let ppl = dense_ppl(&engine, &cfg, &params, &batches).unwrap();
        // untrained byte model: ppl should be near 256 (uniform)
        assert!((100.0..400.0).contains(&ppl), "ppl={ppl}");
    }

    #[test]
    fn exact_compressed_ppl_matches_dense() {
        let Ok(engine) = Engine::new("artifacts") else { return };
        if engine.entry("tiny").is_err() {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(2));
        let corpus = Corpus::generate(Domain::Wiki, 20_000, 2);
        let batches: Vec<_> = Batcher::new(cfg.batch, cfg.seq)
            .sequential(&corpus.valid, 3);
        let blocks: Vec<_> = (0..cfg.n_layers)
            .map(|i| exact_factors(&cfg, &params, i))
            .collect();
        let d = dense_ppl(&engine, &cfg, &params, &batches).unwrap();
        let c = compressed_ppl(&engine, &cfg, &params, &blocks, &batches).unwrap();
        assert!((d - c).abs() < 0.02 * d, "dense {d} vs exact-compressed {c}");
    }
}
