//! Synthetic corpora in three styles standing in for WikiText2 / PTB / C4.
//!
//! All three domains express the *same* underlying facts (lang.rs) through
//! different surface templates and mixture weights, so "wiki" (calibration
//! domain), "ptb" (style shift) and "c4" (broad mixture) reproduce the
//! in-domain vs out-of-domain axis of the paper's perplexity columns.

use super::lang::*;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Domain {
    Wiki,
    Ptb,
    C4,
}

impl Domain {
    pub fn name(&self) -> &'static str {
        match self {
            Domain::Wiki => "wiki",
            Domain::Ptb => "ptb",
            Domain::C4 => "c4",
        }
    }

    pub fn from_name(s: &str) -> Option<Domain> {
        match s {
            "wiki" => Some(Domain::Wiki),
            "ptb" => Some(Domain::Ptb),
            "c4" => Some(Domain::C4),
            _ => None,
        }
    }
}

/// Zipf-ish index sampler: favors small indices (natural-language flavor).
fn zipf(rng: &mut Rng, n: usize) -> usize {
    let w: Vec<f64> = (0..n).map(|i| 1.0 / (1.0 + i as f64)).collect();
    rng.categorical(&w)
}

/// One sentence in the given domain style.
pub fn sentence(rng: &mut Rng, domain: Domain) -> String {
    // template mixture differs per domain
    let weights: &[f64] = match domain {
        Domain::Wiki => &[3.0, 3.0, 2.0, 2.0, 2.0, 2.0, 0.5],
        Domain::Ptb => &[3.0, 3.0, 2.0, 2.0, 2.0, 2.0, 0.5],
        Domain::C4 => &[2.0, 2.0, 1.5, 1.5, 1.5, 1.5, 4.0],
    };
    let t = rng.categorical(weights);
    match t {
        // color fact
        0 => {
            let a = zipf(rng, ANIMALS.len());
            match domain {
                Domain::Wiki => format!("the {} is {} .", ANIMALS[a], color_of(a)),
                Domain::Ptb => format!("a {} appears {} .", ANIMALS[a], color_of(a)),
                Domain::C4 => format!("i saw the {} and it is {} .", ANIMALS[a], color_of(a)),
            }
        }
        // size comparison (consistent with the total order)
        1 => {
            let mut a = rng.below(ANIMALS.len());
            let mut b = rng.below(ANIMALS.len());
            if a == b {
                b = (b + 1) % ANIMALS.len();
            }
            if a < b {
                std::mem::swap(&mut a, &mut b);
            }
            match domain {
                Domain::Wiki => {
                    format!("the {} is bigger than the {} .", ANIMALS[a], ANIMALS[b])
                }
                Domain::Ptb => {
                    format!("a {} is larger than a {} .", ANIMALS[a], ANIMALS[b])
                }
                Domain::C4 => format!(
                    "everyone knows the {} is bigger than the {} .",
                    ANIMALS[a], ANIMALS[b]
                ),
            }
        }
        // animate verb frame (plausibility regularity)
        2 => {
            let s = zipf(rng, ANIMALS.len());
            let v = rng.below(ANIMATE_VERBS.len());
            let o = rng.below(ANIMALS.len());
            match domain {
                Domain::Wiki => format!(
                    "the {} {} the {} .",
                    ANIMALS[s], ANIMATE_VERBS[v], ANIMALS[o]
                ),
                Domain::Ptb => format!(
                    "a {} {} a {} .",
                    ANIMALS[s], ANIMATE_VERBS[v], ANIMALS[o]
                ),
                Domain::C4 => format!(
                    "yesterday the {} {} the {} .",
                    ANIMALS[s], ANIMATE_VERBS[v], ANIMALS[o]
                ),
            }
        }
        // addition fact
        3 => {
            let a = rng.below(10);
            let b = rng.below(10);
            match domain {
                Domain::Wiki => {
                    format!("{} plus {} is {} .", DIGITS[a], DIGITS[b], plus(a, b))
                }
                Domain::Ptb => {
                    format!("{} and {} make {} .", DIGITS[a], DIGITS[b], plus(a, b))
                }
                Domain::C4 => format!(
                    "we computed {} plus {} is {} .",
                    DIGITS[a], DIGITS[b], plus(a, b)
                ),
            }
        }
        // subtraction fact
        4 => {
            let a = rng.below(10);
            let b = rng.below(10);
            match domain {
                Domain::Wiki => {
                    format!("{} minus {} is {} .", DIGITS[a], DIGITS[b], minus(a, b))
                }
                Domain::Ptb => {
                    format!("{} less {} leaves {} .", DIGITS[a], DIGITS[b], minus(a, b))
                }
                Domain::C4 => format!(
                    "note that {} minus {} is {} .",
                    DIGITS[a], DIGITS[b], minus(a, b)
                ),
            }
        }
        // weekday sequence
        5 => {
            let i = rng.below(7);
            let j = (i + 1) % 7;
            let k = (i + 2) % 7;
            match domain {
                Domain::Wiki => format!("after {} comes {} then {} .", DAYS[i], DAYS[j], DAYS[k]),
                Domain::Ptb => format!("{} follows {} .", DAYS[j], DAYS[i]),
                Domain::C4 => format!("{} {} {} and so on .", DAYS[i], DAYS[j], DAYS[k]),
            }
        }
        // filler/noise sentence (dominant in c4)
        _ => {
            let f1 = FILLER[rng.below(FILLER.len())];
            let o1 = OBJECTS[rng.below(OBJECTS.len())];
            let o2 = OBJECTS[rng.below(OBJECTS.len())];
            let a = ANIMALS[zipf(rng, ANIMALS.len())];
            format!("the {a} is {f1} the {o1} {f1} the {o2} .")
        }
    }
}

/// A generated corpus: one long byte-token stream per split.
pub struct Corpus {
    pub domain: Domain,
    pub train: Vec<u32>,
    pub valid: Vec<u32>,
    pub test: Vec<u32>,
}

impl Corpus {
    /// Generate ~`total_bytes` of text, split 80/10/10 by sentence.
    pub fn generate(domain: Domain, total_bytes: usize, seed: u64) -> Corpus {
        // distinct stream per domain so corpora are decorrelated
        let mut rng = Rng::with_stream(seed, 0x1000 + domain.name().len() as u64 * 7919);
        let (mut train, mut valid, mut test) = (Vec::new(), Vec::new(), Vec::new());
        let mut produced = 0usize;
        while produced < total_bytes {
            let s = sentence(&mut rng, domain);
            let bytes: Vec<u32> = s.bytes().map(|b| b as u32).collect();
            produced += bytes.len() + 1;
            let split = rng.f64();
            let dst = if split < 0.8 {
                &mut train
            } else if split < 0.9 {
                &mut valid
            } else {
                &mut test
            };
            dst.extend(bytes);
            dst.push(b' ' as u32);
        }
        Corpus {
            domain,
            train,
            valid,
            test,
        }
    }

    /// Cut a split into non-overlapping (input, target) windows of length
    /// `seq` (targets shifted by one).
    pub fn windows(split: &[u32], seq: usize, max_windows: usize) -> Vec<(Vec<u32>, Vec<u32>)> {
        let mut out = Vec::new();
        let mut pos = 0;
        while pos + seq + 1 <= split.len() && out.len() < max_windows {
            let x = split[pos..pos + seq].to_vec();
            let y = split[pos + 1..pos + seq + 1].to_vec();
            out.push((x, y));
            pos += seq;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic() {
        let a = Corpus::generate(Domain::Wiki, 10_000, 1);
        let b = Corpus::generate(Domain::Wiki, 10_000, 1);
        assert_eq!(a.train, b.train);
        assert_eq!(a.test, b.test);
    }

    #[test]
    fn seeds_and_domains_decorrelate() {
        let a = Corpus::generate(Domain::Wiki, 5_000, 1);
        let b = Corpus::generate(Domain::Wiki, 5_000, 2);
        let c = Corpus::generate(Domain::Ptb, 5_000, 1);
        assert_ne!(a.train, b.train);
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn splits_cover_requested_size() {
        let c = Corpus::generate(Domain::C4, 50_000, 3);
        let total = c.train.len() + c.valid.len() + c.test.len();
        assert!(total >= 50_000);
        // rough 80/10/10
        let frac = c.train.len() as f64 / total as f64;
        assert!((0.7..0.9).contains(&frac), "train frac {frac}");
    }

    #[test]
    fn tokens_are_printable_ascii() {
        let c = Corpus::generate(Domain::Ptb, 5_000, 4);
        assert!(c.train.iter().all(|&t| t >= 32 && t < 127));
    }

    #[test]
    fn windows_shift_by_one() {
        let split: Vec<u32> = (0..100).collect();
        let w = Corpus::windows(&split, 10, 5);
        assert_eq!(w.len(), 5);
        for (x, y) in &w {
            assert_eq!(x.len(), 10);
            for i in 0..9 {
                assert_eq!(x[i + 1], y[i]);
            }
        }
    }

    #[test]
    fn domains_share_facts() {
        // every domain mentions the color fact for animal 0 eventually
        for d in [Domain::Wiki, Domain::Ptb, Domain::C4] {
            let mut rng = Rng::new(5);
            let text: String = (0..500).map(|_| sentence(&mut rng, d) + " ").collect();
            let fact = format!("{} ", color_of(0));
            assert!(
                text.contains(&format!("{} ", ANIMALS[0])) && text.contains(fact.trim()),
                "domain {} missing shared facts",
                d.name()
            );
        }
    }

    #[test]
    fn wiki_and_ptb_styles_differ() {
        let mut r1 = Rng::new(6);
        let mut r2 = Rng::new(6);
        let wiki: String = (0..200).map(|_| sentence(&mut r1, Domain::Wiki) + " ").collect();
        let ptb: String = (0..200).map(|_| sentence(&mut r2, Domain::Ptb) + " ").collect();
        assert!(wiki.contains("the "));
        assert!(ptb.contains("a "));
        assert!(!ptb.contains("after ")); // wiki-only template head
    }
}
