//! Model hyper-parameters (mirrors python/compile/model.py::Config).
//!
//! The authoritative copy of each config is the AOT manifest written by
//! `make artifacts`; the built-ins here must agree with model.CONFIGS and
//! are validated against the manifest at runtime load.

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    pub batch: usize,
    pub seq: usize,
    pub refine_batch: usize,
    pub train_batch: usize,
}

/// The seven linear layers inside every block, canonical order
/// (must match model.BLOCK_LINEARS).
pub const BLOCK_LINEARS: [&str; 7] =
    ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

impl Config {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// (out_dim, in_dim) of a block linear.
    pub fn linear_dims(&self, name: &str) -> (usize, usize) {
        let (d, f) = (self.d_model, self.d_ff);
        match name {
            "wq" | "wk" | "wv" | "wo" => (d, d),
            "w_gate" | "w_up" => (f, d),
            "w_down" => (d, f),
            _ => panic!("unknown linear '{name}'"),
        }
    }

    /// Padded factor rank for a linear = min(out, in).
    pub fn kmax(&self, name: &str) -> usize {
        let (m, n) = self.linear_dims(name);
        m.min(n)
    }

    /// Dense parameter count of one block's linears.
    pub fn block_linear_params(&self) -> usize {
        BLOCK_LINEARS
            .iter()
            .map(|l| {
                let (m, n) = self.linear_dims(l);
                m * n
            })
            .sum()
    }

    pub fn builtin(name: &str) -> Option<Config> {
        let base = |name: &str, d, h, l, f| Config {
            name: name.to_string(),
            vocab: 256,
            d_model: d,
            n_heads: h,
            n_layers: l,
            d_ff: f,
            rope_theta: 10000.0,
            batch: 8,
            seq: 64,
            refine_batch: 32,
            train_batch: 16,
        };
        Some(match name {
            "tiny" => Config {
                batch: 4,
                seq: 16,
                refine_batch: 8,
                train_batch: 8,
                ..base("tiny", 64, 2, 2, 176)
            },
            "small" => base("small", 128, 4, 4, 352),
            "base" => base("base", 256, 4, 6, 704),
            "wide" => base("wide", 320, 5, 7, 880),
            "compact" => base("compact", 96, 3, 5, 264),
            "deep" => base("deep", 192, 4, 8, 528),
            "alt" => base("alt", 256, 8, 6, 640),
            _ => return None,
        })
    }

    pub fn from_manifest(name: &str, dims: &Json) -> Config {
        let u = |k: &str| dims.req(k).as_usize().unwrap();
        Config {
            name: name.to_string(),
            vocab: u("vocab"),
            d_model: u("d_model"),
            n_heads: u("n_heads"),
            n_layers: u("n_layers"),
            d_ff: u("d_ff"),
            rope_theta: dims.req("rope_theta").as_f64().unwrap(),
            batch: u("batch"),
            seq: u("seq"),
            refine_batch: u("refine_batch"),
            train_batch: u("train_batch"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_configs_consistent() {
        for name in ["tiny", "small", "base", "wide", "compact", "deep", "alt"] {
            let c = Config::builtin(name).unwrap();
            assert_eq!(c.d_model % c.n_heads, 0, "{name}");
            assert_eq!(c.head_dim() % 2, 0, "{name} (RoPE pairs)");
            for l in BLOCK_LINEARS {
                let (m, n) = c.linear_dims(l);
                assert_eq!(c.kmax(l), m.min(n));
            }
        }
        assert!(Config::builtin("nope").is_none());
    }

    #[test]
    fn from_manifest_parses() {
        let dims = Json::parse(
            r#"{"vocab":256,"d_model":64,"n_heads":2,"n_layers":2,"d_ff":176,
                "head_dim":32,"batch":4,"seq":16,"refine_batch":8,
                "train_batch":8,"rope_theta":10000.0,"cov_chunk":256}"#,
        )
        .unwrap();
        let c = Config::from_manifest("tiny", &dims);
        assert_eq!(c, Config::builtin("tiny").unwrap());
    }

    #[test]
    fn block_linear_params_formula() {
        let c = Config::builtin("tiny").unwrap();
        let (d, f) = (c.d_model, c.d_ff);
        assert_eq!(c.block_linear_params(), 4 * d * d + 3 * d * f);
    }
}
