//! The HTTP front door: a `std::net` accept loop bridging sockets onto
//! the serving engine.
//!
//! One OS thread per live connection (capped by
//! [`HttpOptions::max_connections`]; connections over the cap are shed
//! inline with 429 before any thread is spawned). Each connection serves
//! exactly one request (`connection: close`) — the open-loop load model
//! this front door is built for opens a fresh socket per request anyway,
//! and single-shot connections keep cancel-on-disconnect semantics
//! trivially correct: dropping the [`Completion`] when the socket dies
//! retires the request at the engine's next tick.
//!
//! Error mapping (see `tests/http_api.rs` for the full matrix):
//!
//! | condition                                   | wire status |
//! |---------------------------------------------|-------------|
//! | malformed request line / header / JSON body | 400         |
//! | missing `content-length`                    | 411         |
//! | body over `Limits::max_body_bytes`          | 413         |
//! | head over limits (size or count)            | 431         |
//! | slow-loris read past `read_timeout`         | 408         |
//! | deadline expired before the first token     | 408         |
//! | connection cap or admission queue full      | 429         |
//! | engine shutting down                        | 503         |
//! | client gone mid-request                     | (499 accounting, nothing written) |
//!
//! The streaming response head is deferred until the first engine event,
//! so every pre-token failure above maps to a *real* status line rather
//! than an aborted 200.

use super::parse::{find_head_end, parse_head, Limits};
use super::sse::{self, SseStream};
use crate::serve::engine::{Completion, Server, Submitter, WaitError};
use crate::serve::metrics::ServeMetrics;
use crate::serve::request::{CancelReason, Event, GenParams, SubmitError};
use crate::util::json::{Json, JsonError, JsonScan};
use std::io::{self, ErrorKind, Read};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Knobs for the HTTP front door.
#[derive(Clone, Debug)]
pub struct HttpOptions {
    /// Bind address; port 0 picks a free port (read it back via
    /// [`HttpServer::addr`]).
    pub addr: String,
    /// Live-connection cap; accepts beyond it are shed inline with 429.
    pub max_connections: usize,
    /// Socket read deadline for the request head (and, doubled, the
    /// body). Slow-loris clients are shed with 408 at this horizon.
    pub read_timeout: Duration,
    /// Parse-time caps (head bytes, header count, body bytes).
    pub limits: Limits,
    /// `max_tokens` applied when the request omits it.
    pub default_max_tokens: usize,
    /// Hard ceiling on per-request `max_tokens`.
    pub max_tokens_cap: usize,
}

impl Default for HttpOptions {
    fn default() -> Self {
        HttpOptions {
            addr: "127.0.0.1:0".to_string(),
            max_connections: 1024,
            read_timeout: Duration::from_secs(2),
            limits: Limits::default(),
            default_max_tokens: 32,
            max_tokens_cap: 4096,
        }
    }
}

/// Socket-side counters, folded into [`ServeMetrics`] at shutdown.
#[derive(Default)]
struct HttpShared {
    stop: AtomicBool,
    /// connections currently being served (the cap applies to this)
    active: AtomicUsize,
    connections: AtomicUsize,
    s2xx: AtomicUsize,
    s4xx: AtomicUsize,
    s5xx: AtomicUsize,
    s429: AtomicUsize,
    s408: AtomicUsize,
    s499: AtomicUsize,
    bytes_in: AtomicUsize,
    bytes_out: AtomicUsize,
    ttfts: Mutex<Vec<f64>>,
}

impl HttpShared {
    fn push_ttft(&self, secs: f64) {
        // a poisoned lock only means another connection thread panicked
        // mid-push; the samples already in the vec are still valid
        let mut g = match self.ttfts.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        g.push(secs);
    }
}

/// How one connection ended, for central status accounting.
enum Outcome {
    /// a response with this status reached the socket
    Wrote(u16),
    /// the client vanished before anything useful could be written
    /// (nginx-style 499 accounting)
    ClientGone,
}

/// A running HTTP front door over a [`Server`].
///
/// [`HttpServer::shutdown`] stops accepting, drains live connections,
/// shuts the engine down, and returns [`ServeMetrics`] with the
/// socket-side `http_*` counters folded in.
pub struct HttpServer {
    addr: SocketAddr,
    shared: Arc<HttpShared>,
    accept: Option<JoinHandle<()>>,
    server: Option<Server>,
}

impl HttpServer {
    /// Bind, spawn the accept loop, and start serving.
    pub fn start(server: Server, options: HttpOptions) -> io::Result<HttpServer> {
        let listener = TcpListener::bind(&options.addr)?;
        let addr = listener.local_addr()?;
        let submitter = server
            .submitter()
            .map_err(|e| io::Error::new(ErrorKind::NotConnected, e.to_string()))?;
        let shared = Arc::new(HttpShared::default());
        let accept_shared = Arc::clone(&shared);
        let accept_options = Arc::new(options);
        // aasvd-lint: allow(adhoc-parallelism): long-lived socket accept loop — I/O concurrency, not compute fan-out (the compute pool stays in util::pool)
        let accept = std::thread::Builder::new()
            .name("aasvd-http-accept".to_string())
            .spawn(move || accept_loop(listener, submitter, accept_options, accept_shared))?;
        Ok(HttpServer {
            addr,
            shared,
            accept: Some(accept),
            server: Some(server),
        })
    }

    /// The bound address (resolves port 0 to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain live connections, shut the engine down,
    /// and return the merged metrics.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.shared.stop.store(true, Ordering::Relaxed);
        // the accept loop is parked in accept(2); poke it awake
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        // live connection threads hold Submitter clones; the engine's
        // channel only drains once they exit. Every connection is
        // bounded by read timeouts and request deadlines, so this wait
        // terminates; the horizon is a backstop, not a control knob.
        // aasvd-lint: allow(wallclock): shutdown drain backstop — scheduling only, never feeds numerics
        let drain_until = Instant::now() + Duration::from_secs(30);
        while self.shared.active.load(Ordering::Relaxed) > 0 {
            // aasvd-lint: allow(wallclock): shutdown drain backstop — scheduling only, never feeds numerics
            if Instant::now() >= drain_until {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let mut m = match self.server.take() {
            Some(s) => s.shutdown(),
            None => ServeMetrics::default(),
        };
        m.http_connections = self.shared.connections.load(Ordering::Relaxed);
        m.http_2xx = self.shared.s2xx.load(Ordering::Relaxed);
        m.http_4xx = self.shared.s4xx.load(Ordering::Relaxed);
        m.http_5xx = self.shared.s5xx.load(Ordering::Relaxed);
        m.http_429 = self.shared.s429.load(Ordering::Relaxed);
        m.http_408 = self.shared.s408.load(Ordering::Relaxed);
        m.http_499 = self.shared.s499.load(Ordering::Relaxed);
        m.http_bytes_in = self.shared.bytes_in.load(Ordering::Relaxed);
        m.http_bytes_out = self.shared.bytes_out.load(Ordering::Relaxed);
        m.http_ttfts = {
            let mut g = match self.shared.ttfts.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            std::mem::take(&mut *g)
        };
        m
    }
}

fn accept_loop(
    listener: TcpListener,
    submitter: Submitter,
    options: Arc<HttpOptions>,
    shared: Arc<HttpShared>,
) {
    for conn in listener.incoming() {
        let mut stream = match conn {
            Ok(s) => s,
            Err(e) => {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                crate::log_warn!("http accept failed: {e}");
                continue;
            }
        };
        if shared.stop.load(Ordering::Relaxed) {
            // the shutdown wake-up connection lands here
            break;
        }
        shared.connections.fetch_add(1, Ordering::Relaxed);
        // admission at the socket: reserve a slot or shed inline with
        // 429 before spending a thread on the connection
        let admitted = shared
            .active
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                (n < options.max_connections).then_some(n + 1)
            })
            .is_ok();
        if !admitted {
            shared.s4xx.fetch_add(1, Ordering::Relaxed);
            shared.s429.fetch_add(1, Ordering::Relaxed);
            let n = sse::write_error(&mut stream, 429, "connection limit reached").unwrap_or(0);
            shared.bytes_out.fetch_add(n, Ordering::Relaxed);
            continue;
        }
        let submitter = submitter.clone();
        let options = Arc::clone(&options);
        let conn_shared = Arc::clone(&shared);
        // aasvd-lint: allow(adhoc-parallelism): one I/O thread per admitted connection (capped above) — blocking-socket concurrency, not compute fan-out
        let spawned = std::thread::Builder::new()
            .name("aasvd-http-conn".to_string())
            .spawn(move || {
                let guard = ActiveGuard(Arc::clone(&conn_shared));
                handle_connection(stream, &submitter, &options, &conn_shared);
                drop(guard);
            });
        if let Err(e) = spawned {
            // thread exhaustion: the closure (and the stream in it) was
            // dropped, so the client sees a reset; release the slot
            shared.active.fetch_sub(1, Ordering::Relaxed);
            shared.s5xx.fetch_add(1, Ordering::Relaxed);
            crate::log_warn!("http connection thread spawn failed: {e}");
        }
    }
}

/// Releases the connection slot even if the handler unwinds.
struct ActiveGuard(Arc<HttpShared>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::Relaxed);
    }
}

fn handle_connection(
    mut stream: TcpStream,
    submitter: &Submitter,
    options: &HttpOptions,
    shared: &HttpShared,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(options.read_timeout));
    // aasvd-lint: allow(wallclock): request receipt timestamp — anchors read deadlines and the socket-side TTFT sample, never token sampling
    let received = Instant::now();
    let outcome = serve_request(&mut stream, received, submitter, options, shared);
    match outcome {
        Outcome::Wrote(status) => match status {
            200..=299 => {
                shared.s2xx.fetch_add(1, Ordering::Relaxed);
            }
            400..=499 => {
                shared.s4xx.fetch_add(1, Ordering::Relaxed);
                if status == 429 {
                    shared.s429.fetch_add(1, Ordering::Relaxed);
                }
                if status == 408 {
                    shared.s408.fetch_add(1, Ordering::Relaxed);
                }
            }
            _ => {
                shared.s5xx.fetch_add(1, Ordering::Relaxed);
            }
        },
        Outcome::ClientGone => {
            shared.s499.fetch_add(1, Ordering::Relaxed);
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// Write an error response; the socket dying while we write downgrades
/// the outcome to `ClientGone`.
fn error_reply(stream: &mut TcpStream, shared: &HttpShared, status: u16, detail: &str) -> Outcome {
    match sse::write_error(stream, status, detail) {
        Ok(n) => {
            shared.bytes_out.fetch_add(n, Ordering::Relaxed);
            Outcome::Wrote(status)
        }
        Err(_) => Outcome::ClientGone,
    }
}

fn json_reply(stream: &mut TcpStream, shared: &HttpShared, status: u16, body: &str) -> Outcome {
    match sse::write_response(stream, status, "application/json", body) {
        Ok(n) => {
            shared.bytes_out.fetch_add(n, Ordering::Relaxed);
            Outcome::Wrote(status)
        }
        Err(_) => Outcome::ClientGone,
    }
}

fn serve_request(
    stream: &mut TcpStream,
    received: Instant,
    submitter: &Submitter,
    options: &HttpOptions,
    shared: &HttpShared,
) -> Outcome {
    // ---- read the head under the read deadline ----------------------
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    let head_end = loop {
        if let Some(end) = find_head_end(&buf) {
            break end;
        }
        if buf.len() > options.limits.max_head_bytes {
            return error_reply(stream, shared, 431, "request head too large");
        }
        // slow-loris guard: the whole head must arrive inside the window
        if received.elapsed() > options.read_timeout {
            return error_reply(stream, shared, 408, "timed out reading the request head");
        }
        match stream.read(&mut tmp) {
            Ok(0) => return Outcome::ClientGone, // hung up before a full head
            Ok(n) => {
                shared.bytes_in.fetch_add(n, Ordering::Relaxed);
                buf.extend_from_slice(&tmp[..n]);
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return error_reply(stream, shared, 408, "timed out reading the request head");
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return Outcome::ClientGone,
        }
    };

    // ---- parse + route ----------------------------------------------
    let head = match parse_head(&buf[..head_end], &options.limits) {
        Ok(h) => h,
        Err(e) => return error_reply(stream, shared, e.status(), e.detail()),
    };
    match (head.method.as_str(), head.target.as_str()) {
        ("POST", "/v1/completions") => {}
        ("GET", "/healthz") => {
            let body = Json::obj()
                .set("ok", true)
                .set("queue_depth", submitter.queue_depth())
                .to_string();
            return json_reply(stream, shared, 200, &body);
        }
        (_, "/v1/completions") | (_, "/healthz") => {
            return error_reply(stream, shared, 405, "method not allowed")
        }
        _ => return error_reply(stream, shared, 404, "no such endpoint"),
    }

    // ---- read the body ----------------------------------------------
    let body_len = match head.content_length() {
        Err(e) => return error_reply(stream, shared, e.status(), e.detail()),
        Ok(None) => return error_reply(stream, shared, 411, "content-length required"),
        Ok(Some(n)) if n > options.limits.max_body_bytes => {
            return error_reply(stream, shared, 413, "request body too large")
        }
        Ok(Some(n)) => n,
    };
    let mut body = buf[head_end..].to_vec();
    while body.len() < body_len {
        // head and body share a doubled deadline: a client that trickles
        // the body is the same slow-loris shape as one trickling headers
        if received.elapsed() > options.read_timeout * 2 {
            return error_reply(stream, shared, 408, "timed out reading the request body");
        }
        match stream.read(&mut tmp) {
            Ok(0) => return Outcome::ClientGone,
            Ok(n) => {
                shared.bytes_in.fetch_add(n, Ordering::Relaxed);
                body.extend_from_slice(&tmp[..n]);
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return error_reply(stream, shared, 408, "timed out reading the request body");
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return Outcome::ClientGone,
        }
    }
    body.truncate(body_len);

    // ---- decode the request lazily (no tree build) ------------------
    let Ok(text) = std::str::from_utf8(&body) else {
        return error_reply(stream, shared, 400, "body is not valid utf-8");
    };
    let scan = JsonScan::new(text);
    let bad = |e: JsonError| format!("bad request json: {e}");
    let prompt = match scan.path_str(&["prompt"]) {
        Ok(Some(p)) => p,
        Ok(None) => return error_reply(stream, shared, 400, "missing required field 'prompt'"),
        Err(e) => return error_reply(stream, shared, 400, &bad(e)),
    };
    let max_new_tokens = match scan.path_f64(&["max_tokens"]) {
        Ok(Some(x)) if x < 0.0 => {
            return error_reply(stream, shared, 400, "max_tokens must be non-negative")
        }
        Ok(Some(x)) => (x as usize).min(options.max_tokens_cap),
        Ok(None) => options.default_max_tokens.min(options.max_tokens_cap),
        Err(e) => return error_reply(stream, shared, 400, &bad(e)),
    };
    let temperature = match scan.path_f64(&["temperature"]) {
        Ok(v) => v.unwrap_or(0.0) as f32,
        Err(e) => return error_reply(stream, shared, 400, &bad(e)),
    };
    let top_k = match scan.path_f64(&["top_k"]) {
        Ok(v) => v.map(|x| x.max(0.0) as usize).filter(|&k| k > 0),
        Err(e) => return error_reply(stream, shared, 400, &bad(e)),
    };
    let seed = match scan.path_f64(&["seed"]) {
        Ok(v) => v.map(|x| x.max(0.0) as u64),
        Err(e) => return error_reply(stream, shared, 400, &bad(e)),
    };
    let stop_sequences = match scan.path_str_array(&["stop"]) {
        Ok(v) => v.unwrap_or_default(),
        Err(e) => return error_reply(stream, shared, 400, &bad(e)),
    };
    let deadline = match scan.path_f64(&["deadline_ms"]) {
        Ok(v) => v.map(|ms| Duration::from_millis(ms.max(0.0) as u64)),
        Err(e) => return error_reply(stream, shared, 400, &bad(e)),
    };
    let streaming = match scan.path_bool(&["stream"]) {
        Ok(v) => v.unwrap_or(true),
        Err(e) => return error_reply(stream, shared, 400, &bad(e)),
    };
    let params = GenParams {
        max_new_tokens,
        temperature,
        top_k,
        seed,
        stop_sequences,
        deadline,
    };

    // ---- submit to the engine ---------------------------------------
    let completion = match submitter.submit(&prompt, params) {
        Ok(c) => c,
        Err(SubmitError::Overloaded) => {
            return error_reply(stream, shared, 429, "admission queue full")
        }
        Err(SubmitError::ShutDown) => {
            return error_reply(stream, shared, 503, "server shutting down")
        }
    };

    if streaming {
        stream_completion(stream, &completion, received, shared)
    } else {
        blocking_completion(stream, completion, shared)
    }
}

/// Non-streaming mode: wait out the whole generation, answer with one
/// JSON body.
fn blocking_completion(stream: &mut TcpStream, completion: Completion, shared: &HttpShared) -> Outcome {
    match completion.wait() {
        Ok(resp) => {
            let body = Json::obj()
                .set("id", resp.id as f64)
                .set("text", resp.text)
                .set("tokens_generated", resp.tokens_generated)
                .set("ttft", resp.ttft)
                .set("latency", resp.latency)
                .to_string();
            json_reply(stream, shared, 200, &body)
        }
        Err(WaitError::Cancelled(CancelReason::Deadline)) => {
            error_reply(stream, shared, 408, "request deadline expired")
        }
        Err(WaitError::Cancelled(CancelReason::Backend)) => {
            error_reply(stream, shared, 500, "backend failed")
        }
        Err(WaitError::Cancelled(CancelReason::KvPressure)) => {
            // the same shed-and-retry contract as a full admission queue
            error_reply(stream, shared, 429, "kv pool pressure: retry later")
        }
        Err(WaitError::Cancelled(CancelReason::Client)) => Outcome::ClientGone,
        Err(WaitError::Disconnected) => error_reply(stream, shared, 503, "server shutting down"),
        // wait() is unbounded and never times out; arm kept for exhaustiveness
        Err(WaitError::TimedOut) => error_reply(stream, shared, 503, "server shutting down"),
    }
}

/// Streaming mode: bridge engine events onto a chunked SSE response.
///
/// The response head goes out with the *first* event, so failures before
/// the first token keep a truthful status line. A write error at any
/// point means the client is gone; dropping the `Completion` on return
/// cancels the request at the engine's next tick.
fn stream_completion(
    stream: &mut TcpStream,
    completion: &Completion,
    received: Instant,
    shared: &HttpShared,
) -> Outcome {
    let Some(first) = completion.next_event() else {
        return error_reply(stream, shared, 503, "server shutting down");
    };
    if let Event::Cancelled { reason, .. } = first {
        // still pre-head: map the retirement to a real status
        return match reason {
            CancelReason::Deadline => {
                error_reply(stream, shared, 408, "deadline expired before the first token")
            }
            CancelReason::Backend => error_reply(stream, shared, 500, "backend failed"),
            CancelReason::KvPressure => {
                // rejected by memory-aware admission before any token:
                // same shed-and-retry contract as a full admission queue
                error_reply(stream, shared, 429, "kv pool pressure: retry later")
            }
            CancelReason::Client => Outcome::ClientGone,
        };
    }
    let mut sse = match SseStream::start(stream) {
        Ok(s) => s,
        Err(_) => return Outcome::ClientGone,
    };
    let mut saw_token = false;
    let mut event = first;
    loop {
        match event {
            Event::Token(t) => {
                if !saw_token {
                    saw_token = true;
                    // socket-side TTFT: receipt to first token event on
                    // the wire (the engine's own TTFT excludes HTTP)
                    shared.push_ttft(received.elapsed().as_secs_f64());
                }
                let data = Json::obj()
                    .set("id", t.id as f64)
                    .set("index", t.index)
                    .set("text", t.ch.to_string())
                    .set("at", t.at);
                if sse.event("token", &data).is_err() {
                    shared.bytes_out.fetch_add(sse.bytes(), Ordering::Relaxed);
                    return Outcome::ClientGone;
                }
            }
            Event::Done(resp) => {
                let data = Json::obj()
                    .set("id", resp.id as f64)
                    .set("text", resp.text)
                    .set("tokens_generated", resp.tokens_generated)
                    .set("ttft", resp.ttft)
                    .set("latency", resp.latency);
                let delivered = sse.event("done", &data).is_ok() && sse.finish().is_ok();
                shared.bytes_out.fetch_add(sse.bytes(), Ordering::Relaxed);
                return if delivered {
                    Outcome::Wrote(200)
                } else {
                    Outcome::ClientGone
                };
            }
            Event::Cancelled { id, reason } => {
                // the 200 head is already on the wire; deliver a terminal
                // error event and account the abort out-of-band
                let data = Json::obj()
                    .set("id", id as f64)
                    .set("reason", reason.to_string());
                let _ = sse.event("error", &data);
                let _ = sse.finish();
                shared.bytes_out.fetch_add(sse.bytes(), Ordering::Relaxed);
                if reason == CancelReason::Deadline {
                    shared.s408.fetch_add(1, Ordering::Relaxed);
                }
                return Outcome::Wrote(200);
            }
        }
        event = match completion.next_event() {
            Some(ev) => ev,
            None => {
                // engine vanished without a terminal event
                let _ = sse.finish();
                shared.bytes_out.fetch_add(sse.bytes(), Ordering::Relaxed);
                return Outcome::Wrote(200);
            }
        };
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn options_default_bounds() {
        let o = HttpOptions::default();
        assert_eq!(o.addr, "127.0.0.1:0");
        assert!(o.max_connections >= 1);
        assert!(o.read_timeout > Duration::ZERO);
        assert!(o.max_tokens_cap >= o.default_max_tokens);
        assert!(o.limits.max_head_bytes >= 1024);
    }
}
