//! The paper's contribution: anchored & adaptive SVD compression.
//!
//! - `objective`: the four layer-wise objectives (Figure 2 left)
//! - `cov`: streaming covariance accumulation (§B.1)
//! - `layer`: CompressLayer closed form (Theorem 3.2 / Algorithm 1)
//! - `rank` / `quant`: allocation schemes + Dobi-style remapping (§B.3/B.4)
//! - `pipeline`: block-wise orchestration with refinement (Algorithm 2)
//! - `run`: streaming, checkpointed, resumable compression session
//! - `pruning`: structured-pruning baselines (Tables 3/4)
//! - `error`: depth-wise error profiling (Figures 1/4)

pub mod cov;
pub mod error;
pub mod layer;
pub mod objective;
pub mod pipeline;
pub mod pruning;
pub mod quant;
pub mod rank;
pub mod run;

pub use cov::CovTriple;
pub use layer::{compress_layer, compress_layer_asvd, compress_layer_plain, Factors};
pub use objective::{Objective, ALL_OBJECTIVES};
pub use pipeline::{
    compress_model, Collector, CompressReport, CompressedModel, Method, MethodBuilder,
    ReferenceCollector,
};
pub use pruning::{prune_model, PruneMethod, PrunedModel, ALL_PRUNERS};
pub use quant::{QuantError, QuantMatrix, QUANT_GROUP_ROWS};
pub use rank::{dense_params, ratio_for_budget, Allocation, RankScheme};
pub use run::{BlockOutcome, CompressRun, CompressSummary, RunOptions};
