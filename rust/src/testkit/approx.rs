//! Approximate-equality assertions for numeric tests.

/// Assert elementwise |a-b| <= tol * (1 + max(|a|,|b|)) — mixed abs/rel.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "index {i}: {x} vs {y} (diff {:.3e}, tol {:.3e})",
            (x - y).abs(),
            tol * scale
        );
    }
}

/// f32 variant.
pub fn assert_close_f32(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "index {i}: {x} vs {y} (diff {:.3e}, tol {:.3e})",
            (x - y).abs(),
            tol * scale
        );
    }
}

/// Max per-eigenvalue gap |a_i − b_i| between two equally-sorted spectra,
/// relative to the reference spectrum's scale (max |b_i|). Scale-relative
/// absolute agreement is the numerically meaningful criterion for the
/// near-zero eigenvalues of rank-deficient matrices; shared by the
/// eigh-vs-Jacobi property tests and the bench-smoke accuracy gate so
/// both enforce the same contract.
pub fn spectrum_gap(vals: &[f64], oracle: &[f64]) -> f64 {
    assert_eq!(vals.len(), oracle.len(), "spectra must have equal length");
    let scale = oracle.iter().fold(1e-300f64, |a, &x| a.max(x.abs()));
    let mut gap = 0.0f64;
    for (a, b) in vals.iter().zip(oracle) {
        gap = gap.max((a - b).abs() / scale);
    }
    gap
}

/// Relative Frobenius distance ‖a−b‖/‖b‖ (slices viewed as flat vectors).
pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (y as f64).powi(2);
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_passes() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9);
    }

    #[test]
    #[should_panic]
    fn far_fails() {
        assert_close(&[1.0], &[1.1], 1e-9);
    }

    #[test]
    fn rel_err_zero_for_equal() {
        assert_eq!(rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn spectrum_gap_is_relative_to_largest_eigenvalue() {
        assert_eq!(spectrum_gap(&[10.0, 1.0], &[10.0, 1.0]), 0.0);
        let gap = spectrum_gap(&[10.0, 2.0], &[10.0, 1.0]);
        assert!((gap - 0.1).abs() < 1e-12, "gap={gap}");
    }
}
