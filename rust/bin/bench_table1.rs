//! Table 1: SVD-compression method comparison across ratios.
//!
//! Paper: LLaMA-7B, {ASVD, SVD-LLM, Dobi-SVD, Dip-SVD, SAES-SVD, AA-SVD}
//! ± remapping at ratios {0.8, 0.6, 0.4}; 3 perplexity corpora + 7
//! zero-shot tasks. Here: the pretrained `small` model, our in-repo method
//! family at the same ratios, same metric battery; paper LLaMA-7B numbers
//! are printed alongside for shape comparison.

use aasvd::compress::{BlockOutcome, Method};
use aasvd::data::Domain;
use aasvd::eval::{display_ppl, Table};
use aasvd::experiments::{
    eval_compressed_method_observed, eval_dense, paper_ref_table1, setup, Knobs,
};
use aasvd::util::cli::Args;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env("Table 1: SVD-method comparison across ratios");
    let knobs = Knobs::parse(&args, "small");
    args.finish_or_help();
    let ctx = setup(&knobs)?;

    let mut table = Table::new(
        &format!("Table 1 — model '{}' (paper: LLaMA-7B)", ctx.cfg.name),
        &[
            "ratio", "method", "wiki", "ptb", "c4", "acc", "drop%",
            "paper:wiki", "paper:acc",
        ],
    );

    let dense = eval_dense(&ctx)?;
    table.row(vec![
        "1.0".into(),
        "dense".into(),
        display_ppl(dense.ppl_of(Domain::Wiki)),
        display_ppl(dense.ppl_of(Domain::Ptb)),
        display_ppl(dense.ppl_of(Domain::C4)),
        format!("{:.3}", dense.avg_acc),
        "-".into(),
        "5.68".into(),
        "0.55".into(),
    ]);

    let methods: Vec<Method> = vec![
        Method::naive_svd(),
        Method::asvd(),
        Method::svd_llm(),
        Method::dobi(),
        Method::aa_svd(knobs.refine()),
        Method::dobi_q(),
        Method::aa_svd_q(knobs.refine()),
    ];

    for &ratio in &knobs.ratios {
        for method in &methods {
            let (ev, _) =
                eval_compressed_method_observed(&ctx, method, ratio, &mut |o: &BlockOutcome| {
                    eprintln!(
                        "[table1] {} @ {ratio}: block {}/{} ({:.1}s)",
                        method.name,
                        o.index + 1,
                        o.total,
                        o.secs
                    );
                })?;
            let drop = 100.0 * (dense.avg_acc - ev.avg_acc) / dense.avg_acc;
            let (pw, pa) = paper_ref_table1(ratio, &method.name)
                .map(|(w, a)| (display_ppl(w), format!("{a:.2}")))
                .unwrap_or(("-".into(), "-".into()));
            table.row(vec![
                format!("{ratio}"),
                ev.method.clone(),
                display_ppl(ev.ppl_of(Domain::Wiki)),
                display_ppl(ev.ppl_of(Domain::Ptb)),
                display_ppl(ev.ppl_of(Domain::C4)),
                format!("{:.3}", ev.avg_acc),
                format!("{drop:.1}%"),
                pw,
                pa,
            ]);
        }
    }
    table.emit("table1")?;
    Ok(())
}
