//! End-to-end tests for the HTTP front door: raw `TcpStream` clients
//! against an in-process [`HttpServer`] over the synthetic backend, so
//! the whole matrix runs artifact-free.
//!
//! The synthetic backend's logit contract (next = prev+1 mod vocab, and
//! temperature 0 decodes greedily) makes outputs exact: prompt `"a"`
//! yields `"bcde"` for four tokens.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use aasvd::model::Config;
use aasvd::serve::http::{HttpOptions, HttpServer, Limits};
use aasvd::serve::{Server, ServerOptions, SyntheticBackend};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn boot_with(prefill_delay: Duration, step_delay: Duration, options: HttpOptions) -> HttpServer {
    let cfg = Config::builtin("tiny").expect("builtin tiny");
    let backend_cfg = cfg.clone();
    let server = Server::with_backend(
        cfg,
        ServerOptions {
            max_queue: 64,
            max_batch: 16,
            prefill_per_tick: 0,
            ..Default::default()
        },
        move || {
            Ok(Box::new(SyntheticBackend::with_delays(
                backend_cfg,
                prefill_delay,
                step_delay,
            )))
        },
    );
    HttpServer::start(server, options).expect("bind http server")
}

fn boot(step_delay: Duration, options: HttpOptions) -> HttpServer {
    boot_with(Duration::ZERO, step_delay, options)
}

/// Read to EOF (`connection: close` framing) and split out the status.
fn read_to_eof(s: &mut TcpStream) -> (u16, String) {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    loop {
        match s.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
    let text = String::from_utf8_lossy(&buf).to_string();
    let status = text
        .lines()
        .next()
        .and_then(|l| l.split(' ').nth(1))
        .and_then(|x| x.parse().ok())
        .unwrap_or(0);
    (status, text)
}

/// Write `raw`, then read the whole response.
fn request(addr: SocketAddr, raw: &[u8]) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.write_all(raw).expect("write request");
    read_to_eof(&mut s)
}

fn post_completions(addr: SocketAddr, body: &str) -> (u16, String) {
    let raw = format!(
        "POST /v1/completions HTTP/1.1\r\nhost: t\r\ncontent-type: application/json\r\ncontent-length: {}\r\n\r\n{body}",
        body.len(),
    );
    request(addr, raw.as_bytes())
}

#[test]
fn happy_path_streams_greedy_tokens_over_sse() {
    let http = boot(Duration::ZERO, HttpOptions::default());
    let addr = http.addr();
    let (status, text) = post_completions(addr, r#"{"prompt":"a","max_tokens":4}"#);
    assert_eq!(status, 200, "{text}");
    assert!(text.contains("transfer-encoding: chunked"), "{text}");
    assert!(text.contains("content-type: text/event-stream"), "{text}");
    assert_eq!(text.matches("event: token").count(), 4, "{text}");
    // greedy synthetic decode: a -> b c d e
    for frag in ["\"text\":\"b\"", "\"text\":\"c\"", "\"text\":\"d\"", "\"text\":\"e\""] {
        assert!(text.contains(frag), "missing {frag} in {text}");
    }
    assert_eq!(text.matches("event: done").count(), 1, "{text}");
    assert!(text.contains("\"text\":\"bcde\""), "{text}");
    assert!(text.contains("\"tokens_generated\":4"), "{text}");
    assert!(text.ends_with("0\r\n\r\n"), "missing terminal chunk: {text}");
    let m = http.shutdown();
    assert_eq!(m.http_2xx, 1);
    assert_eq!(m.http_connections, 1);
    assert_eq!(m.http_ttfts.len(), 1, "socket-side TTFT recorded");
    assert!(m.http_bytes_in > 0 && m.http_bytes_out > 0);
}

#[test]
fn non_stream_mode_returns_one_json_body() {
    let http = boot(Duration::ZERO, HttpOptions::default());
    let (status, text) =
        post_completions(http.addr(), r#"{"prompt":"a","max_tokens":4,"stream":false}"#);
    assert_eq!(status, 200, "{text}");
    assert!(text.contains("content-type: application/json"), "{text}");
    assert!(text.contains("content-length:"), "{text}");
    assert!(text.contains("\"text\":\"bcde\""), "{text}");
    assert!(text.contains("\"tokens_generated\":4"), "{text}");
    assert!(!text.contains("event:"), "{text}");
    http.shutdown();
}

#[test]
fn healthz_reports_ok() {
    let http = boot(Duration::ZERO, HttpOptions::default());
    let (status, text) = request(http.addr(), b"GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status, 200, "{text}");
    assert!(text.contains("\"ok\":true"), "{text}");
    http.shutdown();
}

#[test]
fn malformed_request_lines_and_headers_are_400() {
    let http = boot(Duration::ZERO, HttpOptions::default());
    let addr = http.addr();
    let (status, text) = request(addr, b"GARBAGE NONSENSE\r\n\r\n");
    assert_eq!(status, 400, "{text}");
    let (status, text) = request(addr, b"GET / HTTP/1.1\r\nthis-is-not-a-header\r\n\r\n");
    assert_eq!(status, 400, "{text}");
    // not even utf-8
    let (status, text) = request(addr, &[0xff, 0xfe, 0xfd, b'\r', b'\n', b'\r', b'\n']);
    assert_eq!(status, 400, "{text}");
    let m = http.shutdown();
    assert_eq!(m.http_4xx, 3);
    assert_eq!(m.http_2xx, 0);
}

#[test]
fn unknown_paths_404_and_wrong_methods_405() {
    let http = boot(Duration::ZERO, HttpOptions::default());
    let addr = http.addr();
    let (status, _) = request(addr, b"GET /nope HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status, 404);
    let (status, _) = request(addr, b"GET /v1/completions HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status, 405);
    let (status, _) = request(addr, b"POST /healthz HTTP/1.1\r\nhost: t\r\ncontent-length: 0\r\n\r\n");
    assert_eq!(status, 405);
    http.shutdown();
}

#[test]
fn missing_content_length_is_411_and_oversized_body_is_413() {
    let http = boot(
        Duration::ZERO,
        HttpOptions {
            limits: Limits {
                max_body_bytes: 64,
                ..Limits::default()
            },
            ..HttpOptions::default()
        },
    );
    let addr = http.addr();
    let (status, text) = request(addr, b"POST /v1/completions HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status, 411, "{text}");
    let (status, text) = request(
        addr,
        b"POST /v1/completions HTTP/1.1\r\nhost: t\r\ncontent-length: 100000\r\n\r\n",
    );
    assert_eq!(status, 413, "{text}");
    let (status, text) = request(
        addr,
        b"POST /v1/completions HTTP/1.1\r\nhost: t\r\ncontent-length: banana\r\n\r\n",
    );
    assert_eq!(status, 400, "{text}");
    http.shutdown();
}

#[test]
fn oversized_head_is_431() {
    let http = boot(
        Duration::ZERO,
        HttpOptions {
            limits: Limits {
                max_head_bytes: 256,
                ..Limits::default()
            },
            ..HttpOptions::default()
        },
    );
    // stream > max_head_bytes without ever finishing the head
    let mut junk = String::from("POST /v1/completions HTTP/1.1\r\n");
    for i in 0..40 {
        junk.push_str(&format!("x-filler-{i}: aaaaaaaaaaaaaaaa\r\n"));
    }
    // no terminating blank line — the size cap must fire first
    let (status, text) = request(http.addr(), junk.as_bytes());
    assert_eq!(status, 431, "{text}");
    http.shutdown();
}

#[test]
fn bad_json_and_missing_prompt_are_400_with_positions() {
    let http = boot(Duration::ZERO, HttpOptions::default());
    let addr = http.addr();
    let (status, text) = post_completions(addr, r#"{"prompt": "unterminated"#);
    assert_eq!(status, 400, "{text}");
    assert!(text.contains("byte"), "lazy decoder error carries a position: {text}");
    let (status, text) = post_completions(addr, r#"{"max_tokens":4}"#);
    assert_eq!(status, 400, "{text}");
    assert!(text.contains("prompt"), "{text}");
    // wrong type for a known field is 400, not a silent default
    let (status, text) = post_completions(addr, r#"{"prompt":"a","max_tokens":"many"}"#);
    assert_eq!(status, 400, "{text}");
    http.shutdown();
}

#[test]
fn slow_loris_is_shed_with_408() {
    let http = boot(
        Duration::ZERO,
        HttpOptions {
            read_timeout: Duration::from_millis(150),
            ..HttpOptions::default()
        },
    );
    let mut s = TcpStream::connect(http.addr()).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    // trickle a partial request line and stall past the read deadline
    s.write_all(b"POST /v1/completi").expect("partial write");
    std::thread::sleep(Duration::from_millis(500));
    let (status, text) = read_to_eof(&mut s);
    assert_eq!(status, 408, "{text}");
    let m = http.shutdown();
    assert_eq!(m.http_408, 1);
    assert_eq!(m.http_4xx, 1);
}

#[test]
fn deadline_before_first_token_is_a_real_408() {
    // the 30ms prefill alone outlives the 1ms deadline, so the engine's
    // pre-decode deadline sweep retires the request before any token —
    // the deferred-head design must then surface a genuine 408 status
    // line, not an aborted 200 stream
    let http = boot_with(
        Duration::from_millis(30),
        Duration::from_millis(5),
        HttpOptions::default(),
    );
    let (status, text) =
        post_completions(http.addr(), r#"{"prompt":"a","max_tokens":8,"deadline_ms":1}"#);
    assert_eq!(status, 408, "{text}");
    assert!(!text.contains("200 OK"), "{text}");
    let m = http.shutdown();
    assert_eq!(m.http_408, 1);
    assert!(m.deadline_expired >= 1, "engine saw the deadline too");
}

#[test]
fn midstream_disconnect_cancels_the_completion() {
    let http = boot(Duration::from_millis(30), HttpOptions::default());
    let addr = http.addr();
    let body = r#"{"prompt":"a","max_tokens":200}"#;
    let raw = format!(
        "POST /v1/completions HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n\r\n{body}",
        body.len(),
    );
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        s.write_all(raw.as_bytes()).expect("write");
        // wait for streaming to actually start...
        let mut seen = Vec::new();
        let mut tmp = [0u8; 1024];
        loop {
            match s.read(&mut tmp) {
                Ok(0) => break,
                Ok(n) => {
                    seen.extend_from_slice(&tmp[..n]);
                    if String::from_utf8_lossy(&seen).contains("event: token") {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
        assert!(
            String::from_utf8_lossy(&seen).contains("event: token"),
            "stream never started"
        );
        // ...then vanish mid-stream (drop closes the socket)
    }
    // the next SSE write hits the dead socket; the dropped Completion
    // then retires the request at the engine's next tick
    std::thread::sleep(Duration::from_millis(600));
    let m = http.shutdown();
    assert!(m.http_499 >= 1, "socket accounted as 499: {}", m.summary());
    assert!(m.cancelled >= 1, "engine cancelled the request: {}", m.summary());
    assert_eq!(m.http_5xx, 0, "{}", m.summary());
}

#[test]
fn connection_cap_sheds_429_inline() {
    let http = boot(
        Duration::from_millis(50),
        HttpOptions {
            max_connections: 1,
            ..HttpOptions::default()
        },
    );
    let addr = http.addr();
    let body = r#"{"prompt":"a","max_tokens":50}"#;
    let raw = format!(
        "POST /v1/completions HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n\r\n{body}",
        body.len(),
    );
    // occupy the only slot with a live stream
    let mut first = TcpStream::connect(addr).expect("connect");
    first
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    first.write_all(raw.as_bytes()).expect("write");
    let mut seen = Vec::new();
    let mut tmp = [0u8; 1024];
    loop {
        match first.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => {
                seen.extend_from_slice(&tmp[..n]);
                if String::from_utf8_lossy(&seen).contains("event: token") {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    assert!(
        String::from_utf8_lossy(&seen).contains("event: token"),
        "first stream never started"
    );
    // the second connection must be shed before any parsing happens
    let (status, text) = request(addr, b"GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(status, 429, "{text}");
    drop(first);
    std::thread::sleep(Duration::from_millis(300));
    let m = http.shutdown();
    assert!(m.http_429 >= 1, "{}", m.summary());
}

#[test]
fn metrics_summary_carries_the_http_line() {
    let http = boot(Duration::ZERO, HttpOptions::default());
    let addr = http.addr();
    post_completions(addr, r#"{"prompt":"a","max_tokens":2}"#);
    request(addr, b"GET /nope HTTP/1.1\r\nhost: t\r\n\r\n");
    let m = http.shutdown();
    let s = m.summary();
    assert!(s.contains("http: conns=2"), "{s}");
    assert!(s.contains("2xx=1"), "{s}");
    assert!(s.contains("4xx=1"), "{s}");
    assert!(!s.contains("NaN"), "{s}");
}
