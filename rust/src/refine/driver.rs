//! Refinement driver: runs the AOT `refine_step` executable (AdamW on the
//! block's factors + norm gains, loss = block-output MSE) over the
//! calibration set with the paper's §B.2 recipe — batch 32, cosine LR with
//! warmup, several epochs.
//!
//! The coordinator precomputes Y = L_i(X) (dense block on original inputs)
//! and X' (shifted inputs); the driver owns optimizer state, epoch
//! shuffling, and early stopping on loss plateau.

use crate::model::lowrank::BlockFactors;
use crate::model::Config;
use crate::runtime::{Engine, Value};
use crate::util::pool::Pool;
use crate::util::rng::Rng;
use anyhow::Result;

use super::schedule::CosineSchedule;

/// Every field here feeds the compress-run fingerprint
/// (`compress::run`): refinement moves the output bits, so a
/// checkpointed run refuses to resume under different knobs.
#[derive(Clone, Copy, Debug)]
pub struct RefineOptions {
    pub epochs: usize,
    pub base_lr: f64,
    pub warmup_frac: f64,
    /// stop early when the epoch-mean loss improves less than this
    /// relative amount twice in a row
    pub plateau_tol: f64,
    pub seed: u64,
}

impl Default for RefineOptions {
    fn default() -> Self {
        RefineOptions {
            // paper B.2 uses 25 epochs @ 1e-4; our blocks are ~100x smaller
            // so fewer epochs at the same lr reach the same plateau — the
            // paper-faithful setting is available via --refine-epochs 25.
            epochs: 10,
            // paper B.2 uses 1e-4 on LLaMA-scale blocks; AdamW steps are
            // scale-free, so on our ~100x smaller blocks 1e-4 over-steps
            // and injects noise that the anchored objective then amplifies
            // through its shift-inversion (see EXPERIMENTS.md). 3e-5
            // reproduces the paper's refinement-helps behaviour here.
            base_lr: 3e-5,
            warmup_frac: 0.1,
            plateau_tol: 1e-3,
            seed: 0x5EED,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct RefineReport {
    pub steps: usize,
    pub first_loss: f64,
    pub last_loss: f64,
    pub epoch_losses: Vec<f64>,
}

/// Refine one block in place. `x_shift`/`y_target` are [n_seqs, T, d]
/// flattened sequence-major; sequences are resampled into batches of
/// `cfg.refine_batch` each epoch. The optimizer step itself is one AOT
/// artifact call; `pool` parallelizes the host-side batch packing that
/// feeds it.
pub fn refine_block(
    engine: &Engine,
    cfg: &Config,
    bf: &mut BlockFactors,
    x_shift: &[f32],
    y_target: &[f32],
    opts: &RefineOptions,
    pool: &Pool,
) -> Result<RefineReport> {
    let seq_elems = cfg.seq * cfg.d_model;
    assert_eq!(x_shift.len(), y_target.len());
    assert_eq!(x_shift.len() % seq_elems, 0);
    let n_seqs = x_shift.len() / seq_elems;
    let br = cfg.refine_batch;
    let steps_per_epoch = n_seqs.div_ceil(br).max(1);
    let total_steps = opts.epochs * steps_per_epoch;
    let sched = CosineSchedule::new(
        opts.base_lr,
        (total_steps as f64 * opts.warmup_frac) as usize,
        total_steps,
    );

    let fsize = bf.factors.data.len();
    let mut m = vec![0f32; fsize];
    let mut v = vec![0f32; fsize];
    let mut rng = Rng::new(opts.seed);
    let mut order: Vec<usize> = (0..n_seqs).collect();

    let mut report = RefineReport::default();
    let mut xbatch = vec![0f32; br * seq_elems];
    let mut ybatch = vec![0f32; br * seq_elems];
    let mut step = 0i32;
    let mut plateau = 0usize;

    for _epoch in 0..opts.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        // fan the per-row copies out only when a batch is big enough that
        // spawning scoped workers beats a sequential memcpy (the packing
        // is bandwidth-bound; small blocks lose to thread startup)
        const PAR_MIN_BATCH_ELEMS: usize = 1 << 20;
        let par_pack = pool.threads() > 1 && br * seq_elems >= PAR_MIN_BATCH_ELEMS;
        for chunk in order.chunks(br) {
            // pack batch (pad by cycling the chunk); rows are disjoint
            if par_pack {
                let jobs: Vec<_> = xbatch
                    .chunks_exact_mut(seq_elems)
                    .zip(ybatch.chunks_exact_mut(seq_elems))
                    .enumerate()
                    .map(|(row, (xb, yb))| {
                        let src = chunk[row % chunk.len()];
                        move || {
                            xb.copy_from_slice(
                                &x_shift[src * seq_elems..(src + 1) * seq_elems],
                            );
                            yb.copy_from_slice(
                                &y_target[src * seq_elems..(src + 1) * seq_elems],
                            );
                        }
                    })
                    .collect();
                pool.run(jobs);
            } else {
                for row in 0..br {
                    let src = chunk[row % chunk.len()];
                    xbatch[row * seq_elems..(row + 1) * seq_elems]
                        .copy_from_slice(&x_shift[src * seq_elems..(src + 1) * seq_elems]);
                    ybatch[row * seq_elems..(row + 1) * seq_elems]
                        .copy_from_slice(&y_target[src * seq_elems..(src + 1) * seq_elems]);
                }
            }
            let lr = sched.lr(step as usize) as f32;
            let out = engine.run(
                &cfg.name,
                "refine_step",
                &[
                    Value::F32(&bf.factors.data),
                    Value::F32(&m),
                    Value::F32(&v),
                    Value::ScalarI32(step),
                    Value::ScalarF32(lr),
                    Value::F32(&bf.masks.data),
                    Value::F32(&xbatch),
                    Value::F32(&ybatch),
                ],
            )?;
            bf.factors.data.copy_from_slice(&out[0].f32);
            m.copy_from_slice(&out[1].f32);
            v.copy_from_slice(&out[2].f32);
            let loss = out[3].f32[0] as f64;
            if report.steps == 0 {
                report.first_loss = loss;
            }
            report.last_loss = loss;
            report.steps += 1;
            epoch_loss += loss;
            step += 1;
        }
        let epoch_loss = epoch_loss / steps_per_epoch as f64;
        if let Some(&prev) = report.epoch_losses.last() {
            if prev - epoch_loss < opts.plateau_tol * prev.abs().max(1e-12) {
                plateau += 1;
                if plateau >= 2 {
                    report.epoch_losses.push(epoch_loss);
                    break;
                }
            } else {
                plateau = 0;
            }
        }
        report.epoch_losses.push(epoch_loss);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::model::lowrank::exact_factors;

    #[test]
    fn schedule_defaults_sane() {
        let o = RefineOptions::default();
        assert!(o.epochs >= 1 && o.base_lr > 0.0);
    }

    /// Full driver test against the real tiny artifacts (skips without them).
    #[test]
    fn refinement_recovers_truncation_error() {
        let Ok(engine) = Engine::new("artifacts") else { return };
        if engine.entry("tiny").is_err() {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(1));
        // truncate block 0 crudely to half rank -> refinement must recover
        let mut bf = exact_factors(&cfg, &params, 0);
        for lin in crate::model::BLOCK_LINEARS {
            bf.set_rank(lin, cfg.kmax(lin) / 2);
        }
        // synthetic calibration data
        let n_seqs = 16;
        let seq_elems = cfg.seq * cfg.d_model;
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..n_seqs * seq_elems).map(|_| rng.normal() * 0.5).collect();
        // target: dense block output on the same x
        let y = {
            let taps =
                crate::model::forward::block_forward(&cfg, &params, "blocks.0.", &x, cfg.seq);
            taps.y
        };
        let before = {
            let got = crate::model::lowrank::block_lr_forward(&cfg, &bf, &x, cfg.seq);
            crate::util::stats::mse(&got.y, &y)
        };
        let opts = RefineOptions {
            epochs: 6,
            base_lr: 2e-3,
            ..Default::default()
        };
        let report =
            refine_block(&engine, &cfg, &mut bf, &x, &y, &opts, &Pool::exact(2)).unwrap();
        let after = {
            let got = crate::model::lowrank::block_lr_forward(&cfg, &bf, &x, cfg.seq);
            crate::util::stats::mse(&got.y, &y)
        };
        assert!(
            after < before * 0.5,
            "refinement: mse {before:.3e} -> {after:.3e} (report {report:?})"
        );
        // padded components must stay exactly zero-masked
        for lin in crate::model::BLOCK_LINEARS {
            assert_eq!(bf.rank(lin), cfg.kmax(lin) / 2);
        }
    }
}
