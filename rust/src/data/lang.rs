//! Shared synthetic language: the regularities that both the pretraining
//! corpora and the zero-shot evaluation tasks are built from.
//!
//! Paper substitution (DESIGN.md §3): WikiText2/PTB/C4 and the seven
//! commonsense benchmarks are unavailable offline, so we define a small
//! world with learnable structure — color facts, a strict size order,
//! subject/verb plausibility classes, modular arithmetic, and weekday
//! sequences — sample corpora from it in three styles, and generate
//! multiple-choice tasks that probe exactly those regularities.

/// Animals (animate nouns). Index is also the size rank (ascending).
pub const ANIMALS: [&str; 10] = [
    "ant", "crab", "frog", "bird", "cat", "dog", "wolf", "deer", "lion", "bear",
];

/// Inanimate nouns (implausible subjects for animate verbs).
pub const OBJECTS: [&str; 8] = [
    "rock", "table", "chair", "cup", "door", "lamp", "book", "coin",
];

/// Colors; the fact table maps animal i -> COLORS[i % len].
pub const COLORS: [&str; 5] = ["red", "blue", "green", "black", "white"];

/// Verbs only animate subjects perform.
pub const ANIMATE_VERBS: [&str; 5] = ["eats", "chases", "sees", "hears", "hunts"];

/// Days cycle (sequence-completion regularity).
pub const DAYS: [&str; 7] = [
    "monday", "tuesday", "wednesday", "thursday", "friday", "saturday", "sunday",
];

/// Number words 0..=9 (arithmetic is mod 10).
pub const DIGITS: [&str; 10] = [
    "zero", "one", "two", "three", "four", "five", "six", "seven", "eight", "nine",
];

/// Filler words for noise sentences (c4-style breadth).
pub const FILLER: [&str; 12] = [
    "near", "under", "over", "behind", "beside", "inside", "outside",
    "always", "often", "rarely", "quietly", "quickly",
];

/// The color fact: every animal has one fixed color.
pub fn color_of(animal_idx: usize) -> &'static str {
    COLORS[animal_idx % COLORS.len()]
}

/// Ground truth of the size order: is a bigger than b?
pub fn bigger(a_idx: usize, b_idx: usize) -> bool {
    a_idx > b_idx
}

/// Sum mod 10 in number words.
pub fn plus(a: usize, b: usize) -> &'static str {
    DIGITS[(a + b) % 10]
}

/// Difference mod 10 in number words.
pub fn minus(a: usize, b: usize) -> &'static str {
    DIGITS[(a + 10 - b) % 10]
}

/// Day after DAYS[i].
pub fn next_day(i: usize) -> &'static str {
    DAYS[(i + 1) % 7]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn facts_are_total_and_stable() {
        for i in 0..ANIMALS.len() {
            assert!(!color_of(i).is_empty());
            assert_eq!(color_of(i), color_of(i)); // deterministic
        }
    }

    #[test]
    fn size_order_is_strict_total() {
        for i in 0..ANIMALS.len() {
            assert!(!bigger(i, i));
            for j in 0..ANIMALS.len() {
                if i != j {
                    assert!(bigger(i, j) ^ bigger(j, i));
                }
            }
        }
    }

    #[test]
    fn arithmetic_mod10() {
        assert_eq!(plus(2, 3), "five");
        assert_eq!(plus(7, 5), "two");
        assert_eq!(minus(7, 2), "five");
        assert_eq!(minus(2, 7), "five");
    }

    #[test]
    fn day_cycle() {
        assert_eq!(next_day(0), "tuesday");
        assert_eq!(next_day(6), "monday");
    }

    #[test]
    fn word_lists_disjoint() {
        let mut all: Vec<&str> = Vec::new();
        all.extend(ANIMALS);
        all.extend(OBJECTS);
        all.extend(COLORS);
        all.extend(DIGITS);
        all.extend(DAYS);
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "word lists must not overlap");
    }
}
