// aasvd-lint: path=src/refine/fixture.rs

pub fn energy(xs: &[f32]) -> f32 {
    // aasvd-lint: allow(float-reduce): fixture justification — sequential slice sum in fixed order
    xs.iter().map(|x| x * x).sum::<f32>()
}
