//! Minimal JSON parser/serializer (the offline build has no serde).
//!
//! Covers the full JSON grammar we produce and consume: the AOT manifest,
//! experiment result files, and config files. Numbers are f64; object key
//! order is preserved (insertion order) so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Walk a key path through nested objects: `j.get_path(&["a", "b"])`
    /// is `j.get("a")?.get("b")`. None when any hop is missing or not an
    /// object. The tree-level twin of [`JsonScan`]'s lazy accessors.
    pub fn get_path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), v.into()));
        }
        self
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !kv.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Json {
        Json::Obj(m.into_iter().collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    // ---- lazy skipping (no tree building) --------------------------------

    /// Skip one complete value without allocating: strings advance byte
    /// by byte (escape-aware), containers recurse. Leaves `i` just past
    /// the value. Errors carry the same positions `value()` would report.
    fn skip_value(&mut self) -> Result<(), JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null).map(drop),
            Some(b't') => self.lit("true", Json::Bool(true)).map(drop),
            Some(b'f') => self.lit("false", Json::Bool(false)).map(drop),
            Some(b'"') => self.skip_string(),
            Some(b'[') => {
                self.eat(b'[')?;
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected ',' or ']'")),
                    }
                }
            }
            Some(b'{') => {
                self.eat(b'{')?;
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.skip_string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected ',' or '}'")),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number().map(drop),
            _ => Err(self.err("unexpected character")),
        }
    }

    /// Skip a string literal without building it. Escape sequences are
    /// still validated so malformed input fails at the same byte position
    /// the eager parser reports.
    fn skip_string(&mut self) -> Result<(), JsonError> {
        self.eat(b'"')?;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(());
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = &self.b[self.i + 1..self.i + 5];
                            if !hex.iter().all(u8::is_ascii_hexdigit) {
                                return Err(self.err("bad \\u escape"));
                            }
                            self.i += 5;
                        }
                        Some(
                            b'"' | b'\\' | b'/' | b'n' | b't' | b'r' | b'b' | b'f',
                        ) => self.i += 1,
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => self.i += 1,
            }
        }
    }
}

/// Lazy path-scan accessors over raw JSON text (the mik-sdk idiom): seek
/// a key path by skipping sibling values byte-by-byte instead of building
/// a tree, then decode only the one value asked for. The HTTP request
/// decoder pulls half a dozen fields out of each body this way without
/// ever allocating the full document.
///
/// Semantics:
/// - `Ok(None)`: the document is well-formed along the scanned prefix
///   but the path is absent (a missing key, or a hop through a non-object).
/// - `Ok(Some(_))`: the value exists and has the requested type.
/// - `Err(_)`: malformed JSON on the scanned prefix, or a value of the
///   wrong type at the path — with the byte position, so callers can
///   surface precise 400s.
///
/// Only the bytes *before* the target value (plus the value itself) are
/// validated; garbage after it goes unnoticed by design. Run
/// [`Json::parse`] instead when full-document validation matters.
pub struct JsonScan<'a> {
    src: &'a str,
}

impl<'a> JsonScan<'a> {
    pub fn new(src: &'a str) -> JsonScan<'a> {
        JsonScan { src }
    }

    /// Position a parser at the value for `path`, or None when absent.
    fn seek(&self, path: &[&str]) -> Result<Option<Parser<'a>>, JsonError> {
        let mut p = Parser {
            b: self.src.as_bytes(),
            i: 0,
        };
        p.ws();
        for key in path {
            if p.peek() != Some(b'{') {
                // a hop through a non-object: absent, not malformed —
                // but the value must still be well-formed to say so
                p.skip_value()?;
                return Ok(None);
            }
            p.i += 1;
            p.ws();
            if p.peek() == Some(b'}') {
                return Ok(None);
            }
            loop {
                p.ws();
                let k = p.string()?;
                p.ws();
                p.eat(b':')?;
                p.ws();
                if k == *key {
                    // positioned at the value; descend into the next hop
                    break;
                }
                p.skip_value()?;
                p.ws();
                match p.peek() {
                    Some(b',') => p.i += 1,
                    Some(b'}') => return Ok(None),
                    _ => return Err(p.err("expected ',' or '}'")),
                }
            }
        }
        Ok(Some(p))
    }

    /// The raw text slice of the value at `path` (any type), exactly as
    /// it appears in the source.
    pub fn path_raw(&self, path: &[&str]) -> Result<Option<&'a str>, JsonError> {
        let Some(mut p) = self.seek(path)? else {
            return Ok(None);
        };
        let start = p.i;
        p.skip_value()?;
        Ok(Some(&self.src[start..p.i]))
    }

    /// Decoded string at `path`; Err when the value is not a string.
    pub fn path_str(&self, path: &[&str]) -> Result<Option<String>, JsonError> {
        let Some(mut p) = self.seek(path)? else {
            return Ok(None);
        };
        if p.peek() != Some(b'"') {
            return Err(p.err("expected a string"));
        }
        p.string().map(Some)
    }

    /// Number at `path`; Err when the value is not a number.
    pub fn path_f64(&self, path: &[&str]) -> Result<Option<f64>, JsonError> {
        let Some(mut p) = self.seek(path)? else {
            return Ok(None);
        };
        match p.peek() {
            Some(c) if c == b'-' || c.is_ascii_digit() => match p.number()? {
                Json::Num(x) => Ok(Some(x)),
                _ => Err(p.err("expected a number")),
            },
            _ => Err(p.err("expected a number")),
        }
    }

    /// Bool at `path`; Err when the value is not a bool.
    pub fn path_bool(&self, path: &[&str]) -> Result<Option<bool>, JsonError> {
        let Some(mut p) = self.seek(path)? else {
            return Ok(None);
        };
        match p.peek() {
            Some(b't') => p.lit("true", Json::Bool(true)).map(|_| Some(true)),
            Some(b'f') => p.lit("false", Json::Bool(false)).map(|_| Some(false)),
            _ => Err(p.err("expected a bool")),
        }
    }

    /// Array of strings at `path`; Err when the value is not an array or
    /// any element is not a string.
    pub fn path_str_array(&self, path: &[&str]) -> Result<Option<Vec<String>>, JsonError> {
        let Some(mut p) = self.seek(path)? else {
            return Ok(None);
        };
        if p.peek() != Some(b'[') {
            return Err(p.err("expected an array"));
        }
        p.i += 1;
        let mut out = Vec::new();
        p.ws();
        if p.peek() == Some(b']') {
            return Ok(Some(out));
        }
        loop {
            p.ws();
            if p.peek() != Some(b'"') {
                return Err(p.err("expected a string"));
            }
            out.push(p.string()?);
            p.ws();
            match p.peek() {
                Some(b',') => p.i += 1,
                Some(b']') => return Ok(Some(out)),
                _ => return Err(p.err("expected ',' or ']'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("x")
        );
        assert_eq!(j.req("c").as_obj().unwrap().len(), 0);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"dims":{"d":64,"theta":10000.5},"names":["a","b"],"ok":true,"none":null}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
        let pretty = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""éA""#).unwrap();
        assert_eq!(j.as_str(), Some("éA"));
    }

    #[test]
    fn builder_api() {
        let j = Json::obj()
            .set("x", 1.5)
            .set("name", "hi")
            .set("v", vec![1usize, 2, 3]);
        assert_eq!(j.req("x").as_f64(), Some(1.5));
        assert_eq!(j.req("v").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn get_path_walks_nested_objects() {
        let j = Json::parse(r#"{"a": {"b": {"c": 7}}, "x": [1]}"#).unwrap();
        assert_eq!(j.get_path(&["a", "b", "c"]).and_then(Json::as_f64), Some(7.0));
        assert_eq!(j.get_path(&[]).unwrap(), &j);
        assert!(j.get_path(&["a", "missing"]).is_none());
        // a hop through a non-object is absent, not a panic
        assert!(j.get_path(&["x", "b"]).is_none());
    }

    #[test]
    fn scan_finds_values_without_building_a_tree() {
        let src = r#"{"prompt": "the cat", "params": {"max_tokens": 64,
                      "temperature": 0.5, "greedy": false},
                      "stop": ["\n", "END"], "big": [1, 2, {"skip": "me"}]}"#;
        let scan = JsonScan::new(src);
        assert_eq!(scan.path_str(&["prompt"]).unwrap(), Some("the cat".into()));
        assert_eq!(
            scan.path_f64(&["params", "max_tokens"]).unwrap(),
            Some(64.0)
        );
        assert_eq!(
            scan.path_f64(&["params", "temperature"]).unwrap(),
            Some(0.5)
        );
        assert_eq!(scan.path_bool(&["params", "greedy"]).unwrap(), Some(false));
        assert_eq!(
            scan.path_str_array(&["stop"]).unwrap(),
            Some(vec!["\n".to_string(), "END".to_string()])
        );
        assert_eq!(scan.path_raw(&["big", "skip"]).unwrap(), None);
        // absent keys and non-object hops are None, not errors
        assert_eq!(scan.path_str(&["missing"]).unwrap(), None);
        assert_eq!(scan.path_str(&["prompt", "deeper"]).unwrap(), None);
        // the raw slice is the value text verbatim
        assert_eq!(
            scan.path_raw(&["params"]).unwrap().map(|s| s.starts_with('{')),
            Some(true)
        );
    }

    #[test]
    fn scan_type_mismatches_are_errors_with_positions() {
        let src = r#"{"n": "not a number", "s": 5}"#;
        let scan = JsonScan::new(src);
        let e = scan.path_f64(&["n"]).unwrap_err();
        // positioned at the opening quote of the wrong-typed value
        assert_eq!(e.pos, 6, "{e}");
        let e = scan.path_str(&["s"]).unwrap_err();
        assert_eq!(e.pos, 27, "{e}");
        let e = scan.path_str_array(&["s"]).unwrap_err();
        assert_eq!(e.pos, 27, "{e}");
    }

    #[test]
    fn escape_sequence_error_positions() {
        // eager parse: the bad escape char 'q' sits at byte 8 of {"a":"x\q"}
        let src = "{\"a\":\"x\\q\"}";
        let e = Json::parse(src).unwrap_err();
        assert_eq!(e.pos, 8, "{e}");
        assert!(e.msg.contains("bad escape"), "{e}");
        // lazy skip of the same string reports the same position
        let scan = JsonScan::new(src);
        let e = scan.path_str(&["missing"]).unwrap_err();
        assert_eq!(e.pos, 8, "{e}");
        // truncated \u escape: fewer than 4 hex digits before EOF
        let e = Json::parse("\"\\u00").unwrap_err();
        assert_eq!(e.pos, 2, "{e}");
        assert!(e.msg.contains("\\u"), "{e}");
        let e = JsonScan::new("{\"k\":\"\\u12G4\"}").path_str(&["k"]).unwrap_err();
        assert!(e.msg.contains("\\u"), "{e}");
    }

    #[test]
    fn truncated_input_error_positions() {
        // the eager parser points at the byte where input ran out
        let e = Json::parse(r#"{"a": [1, 2"#).unwrap_err();
        assert_eq!(e.pos, 11, "{e}");
        let e = Json::parse(r#"{"a""#).unwrap_err();
        assert_eq!(e.pos, 4, "{e}");
        let e = Json::parse("\"open").unwrap_err();
        assert_eq!(e.pos, 5, "{e}");
        // and the lazy scanner agrees byte-for-byte on the same prefixes
        let e = JsonScan::new(r#"{"a": [1, 2"#).path_raw(&["a"]).unwrap_err();
        assert_eq!(e.pos, 11, "{e}");
        let e = JsonScan::new("{\"a\": \"open").path_str(&["a"]).unwrap_err();
        assert_eq!(e.pos, 11, "{e}");
    }
}
