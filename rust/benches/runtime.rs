//! PJRT runtime perf: per-dispatch overhead and the block/model forward
//! throughput that bounds calibration sweeps, refinement and serving.

use aasvd::bench::Bench;
use aasvd::model::init::init_params;
use aasvd::model::Config;
use aasvd::runtime::{Engine, Value};
use aasvd::util::rng::Rng;

fn main() {
    let Ok(engine) = Engine::new("artifacts") else {
        eprintln!("no artifacts — run `make artifacts` first");
        return;
    };
    let mut b = Bench::new();
    for cfg_name in ["tiny", "base"] {
        if engine.entry(cfg_name).is_err() {
            continue;
        }
        let cfg: Config = engine.entry(cfg_name).unwrap().config.clone();
        let params = init_params(&cfg, &mut Rng::new(1));
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
            .map(|i| (i % cfg.vocab) as i32)
            .collect();
        engine
            .warmup(cfg_name, &["model_fwd", "block_fwd", "model_nll"])
            .unwrap();
        let toks_per_call = (cfg.batch * cfg.seq) as f64;

        b.run(
            &format!("[{cfg_name}] model_fwd B={} T={}", cfg.batch, cfg.seq),
            Some(toks_per_call),
            || {
                std::hint::black_box(
                    engine
                        .run(
                            cfg_name,
                            "model_fwd",
                            &[Value::F32(&params.data), Value::I32(&tokens)],
                        )
                        .unwrap(),
                );
            },
        );

        let bp = aasvd::compress::pipeline::pack_block_params(&cfg, &params, 0);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..cfg.batch * cfg.seq * cfg.d_model)
            .map(|_| rng.normal() * 0.5)
            .collect();
        b.run(
            &format!("[{cfg_name}] block_fwd"),
            Some(toks_per_call),
            || {
                std::hint::black_box(
                    engine
                        .run(cfg_name, "block_fwd", &[Value::F32(&bp), Value::F32(&x)])
                        .unwrap(),
                );
            },
        );
    }
    let stats = engine.stats_snapshot();
    println!(
        "engine stats: {} executions, {:.1} MB h2d, {:.3}s exec total",
        stats.executions,
        stats.h2d_bytes as f64 / 1e6,
        stats.execute_secs
    );
    b.save("runtime");
}
