//! L3 perf: the pure-Rust linalg kernels on compression-realistic shapes
//! (d_model=256, d_ff=704 from `base`; plus the 1k-class sizes), including
//! the banded-parallel kernels at pinned worker counts — the 1-vs-4-thread
//! rows are the scaling record CI's bench-smoke job archives per PR.
//!
//! The `eigh` rows double as the eigensolver regression gate: the
//! tridiagonal pipeline must beat the retained Jacobi oracle at
//! d_model-scale while matching its spectrum (asserted here, so a
//! accuracy regression fails bench-smoke, not just a dashboard).

use aasvd::bench::Bench;
use aasvd::linalg::{cholesky, eigh_jacobi, eigh_values_with, eigh_with, svd_k_with, Matrix};
use aasvd::testkit::approx::spectrum_gap;
use aasvd::util::pool::Pool;
use aasvd::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(1);

    for n in [256usize, 512, 704] {
        let a = Matrix::random(n, n, &mut rng, 1.0);
        let c = Matrix::random(n, n, &mut rng, 1.0);
        let flops = 2.0 * (n as f64).powi(3);
        b.run(&format!("matmul {n}x{n}"), Some(flops), || {
            std::hint::black_box(a.matmul(&c));
        });
    }

    // banded-parallel kernels at pinned widths (ignores AA_SVD_THREADS):
    // same results bitwise, different wall clock
    {
        let n = 512usize;
        let a = Matrix::random(n, n, &mut rng, 1.0);
        let c = Matrix::random(n, n, &mut rng, 1.0);
        let flops = 2.0 * (n as f64).powi(3);
        for threads in [1usize, 2, 4] {
            let pool = Pool::exact(threads);
            b.run(
                &format!("matmul {n}x{n} threads={threads}"),
                Some(flops),
                || {
                    std::hint::black_box(a.matmul_with(&c, &pool));
                },
            );
        }
        for threads in [1usize, 4] {
            let pool = Pool::exact(threads);
            b.run(
                &format!("gram A^T*A {n}x{n} threads={threads}"),
                Some(flops),
                || {
                    std::hint::black_box(a.matmul_at_with(&a, &pool));
                },
            );
            b.run(
                &format!("transpose {n}x{n} threads={threads}"),
                None,
                || {
                    std::hint::black_box(a.transpose_with(&pool));
                },
            );
        }
    }

    for n in [256usize, 704] {
        let s = Matrix::random_spd(n, &mut rng);
        b.run(&format!("cholesky {n}"), Some((n as f64).powi(3) / 3.0), || {
            std::hint::black_box(cholesky(&s).unwrap());
        });
    }

    // eigensolvers: tridiagonal pipeline (the hot path) vs the Jacobi
    // oracle, at d_model scale. The `eigh(jacobi) 512` / `eigh 512
    // threads=1` pair is the speedup trajectory CI's bench-smoke archives
    // and gates on (>= 5x required).
    for n in [128usize, 256, 512] {
        let s = Matrix::random_spd(n, &mut rng);

        // the oracle is O(sweeps * n^3) slow: at d_model scale pin it to
        // one warmup pass (cold-cache cost must not inflate the measured
        // speedup) plus exactly one timed iteration — max_iters is what
        // actually caps the loop; min_iters alone would keep iterating to
        // target_secs. The last run's result feeds the accuracy gate.
        let mut oracle = None;
        let (saved_min, saved_max, saved_warm) = (b.min_iters, b.max_iters, b.warmup);
        if n >= 256 {
            b.min_iters = 1;
            b.max_iters = 1;
            b.warmup = 1;
        }
        b.run(&format!("eigh(jacobi) {n}"), None, || {
            oracle = Some(eigh_jacobi(&s));
        });
        b.min_iters = saved_min;
        b.max_iters = saved_max;
        b.warmup = saved_warm;

        for threads in [1usize, 4] {
            let pool = Pool::exact(threads);
            b.run(&format!("eigh {n} threads={threads}"), None, || {
                std::hint::black_box(eigh_with(&s, &pool));
            });
        }
        b.run(&format!("eigh_values {n}"), None, || {
            std::hint::black_box(eigh_values_with(&s, &Pool::exact(1)));
        });

        // accuracy gate: the fast path must match the oracle's spectrum
        let (oracle, _) = oracle.expect("oracle bench ran at least once");
        let (vals, _) = eigh_with(&s, &Pool::exact(1));
        let gap = spectrum_gap(&vals, &oracle);
        println!("eigh vs jacobi spectrum gap n={n}: {gap:.3e}");
        assert!(gap <= 1e-9, "eigh accuracy regression at n={n}: gap {gap:.3e}");
    }

    // the actual CompressLayer SVD shapes: M is [m, n] with min side = d
    for (m, n, k) in [(256usize, 256usize, 85usize), (704, 256, 128), (256, 704, 85)] {
        let a = Matrix::random(m, n, &mut rng, 1.0);
        for threads in [1usize, 4] {
            let pool = Pool::exact(threads);
            b.run(
                &format!("svd_k {m}x{n} k={k} threads={threads}"),
                None,
                || {
                    std::hint::black_box(svd_k_with(&a, k, &pool));
                },
            );
        }
    }
    b.save("linalg");
}
