"""L2 correctness: model invariants, flat-layout round trips, step dynamics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

CFG = M.CONFIGS["tiny"]


def init_flat(cfg, seed=0, scale=0.05):
    r = np.random.RandomState(seed)
    specs = M.param_specs(cfg)
    return jnp.asarray(
        np.concatenate([
            (r.randn(int(np.prod(s))) * scale).astype(np.float32)
            for _, s in specs
        ]))


def test_flatten_unflatten_roundtrip():
    specs = M.param_specs(CFG)
    flat = init_flat(CFG, 1)
    tree = M.unflatten(flat, specs)
    back = M.flatten(tree, specs)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(back))


def test_param_layout_matches_total_size():
    specs = M.param_specs(CFG)
    sizes = [int(np.prod(s)) for _, s in specs]
    assert M.total_size(specs) == sum(sizes)
    assert len({n for n, _ in specs}) == len(specs)  # names unique


def test_model_fwd_shape_and_finite():
    flat = init_flat(CFG)
    p = M.unflatten(flat, M.param_specs(CFG))
    tokens = jnp.zeros((2, CFG.seq), jnp.int32)
    logits = M.model_fwd(CFG, p, tokens)
    assert logits.shape == (2, CFG.seq, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_model_fwd_is_causal():
    flat = init_flat(CFG)
    p = M.unflatten(flat, M.param_specs(CFG))
    r = np.random.RandomState(0)
    toks = r.randint(0, CFG.vocab, (1, CFG.seq)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, CFG.seq // 2:] = (toks2[0, CFG.seq // 2:] + 7) % CFG.vocab
    l1 = np.asarray(M.model_fwd(CFG, p, jnp.asarray(toks)))
    l2 = np.asarray(M.model_fwd(CFG, p, jnp.asarray(toks2)))
    cut = CFG.seq // 2
    np.testing.assert_allclose(l1[0, :cut], l2[0, :cut], rtol=1e-5, atol=1e-5)


def full_rank_factors(cfg, p, i):
    """Exact factorization: U = W, V = I, mask = 1 -> block_lr == block."""
    f, masks = {}, {}
    f["attn_norm"] = p[f"blocks.{i}.attn_norm"]
    f["mlp_norm"] = p[f"blocks.{i}.mlp_norm"]
    for name in M.BLOCK_LINEARS:
        m, n = M.linear_dims(cfg, name)
        k = M.kmax(cfg, name)
        w = p[f"blocks.{i}.{name}"]
        if k == n:           # W = W I^T
            u, v = w, jnp.eye(n, k, dtype=jnp.float32)
        else:                # k == m: W = I W^T^T -> U = I, V = W^T
            u, v = jnp.eye(m, k, dtype=jnp.float32), w.T
        f[f"{name}.u"], f[f"{name}.v"] = u, v
        masks[f"{name}.mask"] = jnp.ones((k,), jnp.float32)
    return f, masks


def test_lr_block_with_exact_factors_matches_dense():
    flat = init_flat(CFG, 3)
    p = M.unflatten(flat, M.param_specs(CFG))
    f, masks = full_rank_factors(CFG, p, 0)
    r = np.random.RandomState(1)
    x = jnp.asarray(r.randn(2, CFG.seq, CFG.d_model).astype(np.float32))
    dense = M.block_fwd(CFG, p, x, prefix="blocks.0.")
    lowr = M.block_lr_fwd(CFG, f, masks, x)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(lowr),
                               rtol=2e-4, atol=2e-4)


def test_block_collect_activations_feed_linears():
    """a_in/o_in/m_in/d_in are exactly the inputs of q/k/v, wo, gate/up, down."""
    flat = init_flat(CFG, 4)
    p = M.unflatten(flat, M.param_specs(CFG))
    pb = {k.split(".", 2)[-1]: v for k, v in p.items() if k.startswith("blocks.0.")}
    r = np.random.RandomState(2)
    x = jnp.asarray(r.randn(1, CFG.seq, CFG.d_model).astype(np.float32))
    y, a_in, o_in, m_in, d_in = M.block_inner(CFG, pb, x)
    # reconstruct y from the collected intermediates
    h = x + o_in @ pb["wo"].T
    y2 = h + d_in @ pb["w_down"].T
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(m_in), np.asarray(M.rmsnorm(h, pb["mlp_norm"])),
        rtol=1e-5, atol=1e-5)


def test_mask_zeroes_gradients_of_padded_components():
    """Padded rank components must receive zero gradient in refine_step."""
    cfg = CFG
    fspecs = M.factor_specs_one_block(cfg)
    mspecs = M.mask_specs_one_block(cfg)
    r = np.random.RandomState(5)
    train = jnp.asarray(r.randn(M.total_size(fspecs)).astype(np.float32) * 0.05)
    k_eff = {n: M.kmax(cfg, n) // 2 for n in M.BLOCK_LINEARS}
    masks = {f"{n}.mask": jnp.asarray(
        (np.arange(M.kmax(cfg, n)) < k_eff[n]).astype(np.float32))
        for n in M.BLOCK_LINEARS}
    masks_flat = M.flatten(masks, mspecs)
    x = jnp.asarray(r.randn(2, cfg.seq, cfg.d_model).astype(np.float32))
    y = jnp.asarray(r.randn(2, cfg.seq, cfg.d_model).astype(np.float32))

    def loss_fn(flat):
        f = M.unflatten(flat, fspecs)
        mk = M.unflatten(masks_flat, mspecs)
        out = M.block_lr_fwd(cfg, f, mk, x)
        return jnp.mean(jnp.square(out - y))

    g = M.unflatten(jax.grad(loss_fn)(train), fspecs)
    for n in M.BLOCK_LINEARS:
        gu = np.asarray(g[f"{n}.u"])
        gv = np.asarray(g[f"{n}.v"])
        ke = k_eff[n]
        assert np.abs(gu[:, ke:]).max() == 0.0, f"{n}.u padded grad nonzero"
        assert np.abs(gv[:, ke:]).max() == 0.0, f"{n}.v padded grad nonzero"
        assert np.abs(gu[:, :ke]).max() > 0.0
        assert np.abs(gv[:, :ke]).max() > 0.0


def test_refine_step_reduces_block_error():
    cfg = CFG
    fspecs = M.factor_specs_one_block(cfg)
    mspecs = M.mask_specs_one_block(cfg)
    r = np.random.RandomState(6)
    train = jnp.asarray(r.randn(M.total_size(fspecs)).astype(np.float32) * 0.05)
    masks_flat = jnp.ones((M.total_size(mspecs),), jnp.float32)
    x = jnp.asarray(r.randn(cfg.refine_batch, cfg.seq, cfg.d_model)
                    .astype(np.float32))
    y = jnp.asarray(r.randn(cfg.refine_batch, cfg.seq, cfg.d_model)
                    .astype(np.float32) * 0.1)
    m = jnp.zeros_like(train)
    v = jnp.zeros_like(train)
    losses = []
    for step in range(30):
        train, m, v, loss = M.refine_step(
            cfg, train, m, v, jnp.int32(step), jnp.float32(1e-2),
            masks_flat, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_train_step_reduces_lm_loss():
    cfg = CFG
    params = init_flat(cfg, 7)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    r = np.random.RandomState(8)
    toks = jnp.asarray(
        r.randint(0, cfg.vocab, (cfg.train_batch, cfg.seq)).astype(np.int32))
    tgts = jnp.asarray(
        r.randint(0, cfg.vocab, (cfg.train_batch, cfg.seq)).astype(np.int32))
    losses = []
    for step in range(20):
        params, m, v, loss = M.train_step(
            cfg, params, m, v, jnp.int32(step), jnp.float32(3e-3), toks, tgts)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_nll_matches_manual_softmax():
    r = np.random.RandomState(9)
    logits = r.randn(2, 5, 11).astype(np.float32)
    targets = r.randint(0, 11, (2, 5)).astype(np.int32)
    got = np.asarray(M.nll(jnp.asarray(logits), jnp.asarray(targets)))
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = -np.log(np.take_along_axis(p, targets[..., None], -1)[..., 0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", list(M.CONFIGS))
def test_config_dims_are_consistent(name):
    cfg = M.CONFIGS[name]
    assert cfg.d_model % cfg.n_heads == 0
    assert cfg.head_dim % 2 == 0  # RoPE pairs
    for lin in M.BLOCK_LINEARS:
        m, n = M.linear_dims(cfg, lin)
        assert M.kmax(cfg, lin) == min(m, n)
