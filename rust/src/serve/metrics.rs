//! Serving metrics: latency percentiles + throughput.

use crate::util::stats::{mean, percentile};

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub ttfts: Vec<f64>,
    pub latencies: Vec<f64>,
    pub tokens: usize,
    pub wall_secs: f64,
    pub batch_sizes: Vec<f64>,
}

impl ServeMetrics {
    pub fn record(&mut self, ttft: f64, latency: f64, tokens: usize) {
        self.ttfts.push(ttft);
        self.latencies.push(latency);
        self.tokens += tokens;
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.wall_secs.max(1e-9)
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        mean(&self.batch_sizes)
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} tokens={} throughput={:.1} tok/s \
             ttft p50={:.0}ms p95={:.0}ms latency p50={:.0}ms p95={:.0}ms \
             batch_occ={:.2}",
            self.latencies.len(),
            self.tokens,
            self.tokens_per_sec(),
            1e3 * percentile(&self.ttfts, 50.0),
            1e3 * percentile(&self.ttfts, 95.0),
            1e3 * percentile(&self.latencies, 50.0),
            1e3 * percentile(&self.latencies, 95.0),
            self.mean_batch_occupancy(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = ServeMetrics::default();
        m.record(0.1, 0.5, 10);
        m.record(0.2, 0.6, 20);
        m.wall_secs = 3.0;
        assert!((m.tokens_per_sec() - 10.0).abs() < 1e-9);
        assert!(m.summary().contains("requests=2"));
    }
}
