//! Pure-Rust dense linear algebra for the compression closed form.
//!
//! XLA-CPU lowers `jnp.linalg.*` to LAPACK custom-calls that the pinned
//! xla_extension 0.5.1 cannot execute, so Cholesky / EVD / SVD live here.
//! Sizes are bounded by the model's hidden dims (≤ ~1k), comfortably within
//! pure-Rust range; see benches/linalg.rs for measured throughput.
//!
//! The symmetric eigensolver (`eigh`, feeding both the EVD whitening
//! factor and the Gram-route `svd_k`) is the Householder + implicit-shift
//! QL pipeline in `tridiag`, row-banded on the worker pool with the same
//! bitwise thread-count-invariance contract as the matmul kernels; the
//! old cyclic Jacobi solver is kept as `eigh_jacobi`, the property-test
//! oracle.

pub mod chol;
pub mod eigh;
pub mod matrix;
pub mod qr;
pub mod svd;
pub mod tridiag;

pub use chol::{cholesky, cholesky_jittered, right_mul_inv_rt, solve_lower, solve_upper_t};
pub use eigh::{
    eigh, eigh_jacobi, eigh_values, eigh_values_with, eigh_with, evd_whitening_factor,
    evd_whitening_factor_with,
};
pub use matrix::Matrix;
pub use svd::{svd, svd_k, svd_k_with, tail_energy, Svd};
