//! Minimal JSON parser/serializer (the offline build has no serde).
//!
//! Covers the full JSON grammar we produce and consume: the AOT manifest,
//! experiment result files, and config files. Numbers are f64; object key
//! order is preserved (insertion order) so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), v.into()));
        }
        self
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !kv.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Json {
        Json::Obj(m.into_iter().collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("x")
        );
        assert_eq!(j.req("c").as_obj().unwrap().len(), 0);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"dims":{"d":64,"theta":10000.5},"names":["a","b"],"ok":true,"none":null}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
        let pretty = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""éA""#).unwrap();
        assert_eq!(j.as_str(), Some("éA"));
    }

    #[test]
    fn builder_api() {
        let j = Json::obj()
            .set("x", 1.5)
            .set("name", "hi")
            .set("v", vec![1usize, 2, 3]);
        assert_eq!(j.req("x").as_f64(), Some(1.5));
        assert_eq!(j.req("v").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
