// aasvd-lint: path=src/compress/fixture.rs

pub fn kernel_stub() -> f64 {
    0.0
}

#[cfg(test)]
mod tests {
    #[test]
    fn reference_sum_is_exempt() {
        let xs = [1.0f64, 2.0];
        assert_eq!(xs.iter().sum::<f64>(), 3.0);
        let t0 = std::time::Instant::now();
        assert!(t0.elapsed().as_secs_f64() >= 0.0);
    }
}
