//! Cholesky factorization + triangular solves.
//!
//! Algorithm 1 step 3 factorizes the shifted-input covariance S = B B^T as
//! S = R R^T. Calibration covariances can be numerically rank-deficient
//! (activations live in an anisotropic subspace — the very reason
//! activation-aware compression works), so `cholesky_jittered` escalates a
//! Tikhonov ε until factorization succeeds, implementing the paper's
//! Appendix A remark.

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor R with S = R R^T.
/// Fails if S is not (numerically) positive definite.
pub fn cholesky(s: &Matrix) -> Result<Matrix> {
    assert_eq!(s.rows, s.cols, "cholesky needs a square matrix");
    let n = s.rows;
    let mut r = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = s.get(i, j);
            for p in 0..j {
                sum -= r.data[i * n + p] * r.data[j * n + p];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (sum={sum:.3e})");
                }
                r.data[i * n + i] = sum.sqrt();
            } else {
                r.data[i * n + j] = sum / r.data[j * n + j];
            }
        }
    }
    Ok(r)
}

/// Cholesky with escalating Tikhonov jitter: S + ε·tr(S)/n·I = R R^T.
/// Returns (R, ε_used). ε doubles from `eps0` until success.
pub fn cholesky_jittered(s: &Matrix, eps0: f64) -> (Matrix, f64) {
    let n = s.rows;
    // aasvd-lint: allow(float-reduce): sequential trace in fixed index order; jitter scale is single-threaded and bitwise reproducible
    let scale = (0..n).map(|i| s.get(i, i)).sum::<f64>().max(1e-300) / n as f64;
    let mut eps = 0.0;
    loop {
        let mut sj = s.clone();
        if eps > 0.0 {
            for i in 0..n {
                sj.data[i * n + i] += eps * scale;
            }
        }
        match cholesky(&sj) {
            Ok(r) => return (r, eps),
            Err(_) => {
                eps = if eps == 0.0 { eps0 } else { eps * 2.0 };
                assert!(
                    eps < 1e6,
                    "cholesky_jittered failed to stabilize (eps={eps})"
                );
            }
        }
    }
}

/// Solve R X = B for X, with R lower-triangular (forward substitution).
/// B is [n × m]; X overwrites a copy of B.
pub fn solve_lower(r: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(r.rows, r.cols);
    assert_eq!(r.rows, b.rows);
    let (n, m) = (b.rows, b.cols);
    let mut x = b.clone();
    for i in 0..n {
        let rii = r.get(i, i);
        // x[i] = (b[i] - sum_{p<i} R[i,p] x[p]) / R[i,i]
        let (done, rest) = x.data.split_at_mut(i * m);
        let xi = &mut rest[..m];
        for p in 0..i {
            let rip = r.get(i, p);
            if rip == 0.0 {
                continue;
            }
            let xp = &done[p * m..(p + 1) * m];
            for (v, &w) in xi.iter_mut().zip(xp) {
                *v -= rip * w;
            }
        }
        for v in xi.iter_mut() {
            *v /= rii;
        }
    }
    x
}

/// Solve R^T X = B for X, with R lower-triangular (so R^T is upper;
/// backward substitution). B is [n × m].
pub fn solve_upper_t(r: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(r.rows, r.cols);
    assert_eq!(r.rows, b.rows);
    let (n, m) = (b.rows, b.cols);
    let mut x = b.clone();
    for i in (0..n).rev() {
        let rii = r.get(i, i);
        let (head, tail) = x.data.split_at_mut((i + 1) * m);
        let xi = &mut head[i * m..];
        // R^T[i,p] = R[p,i] for p > i
        for p in (i + 1)..n {
            let rpi = r.get(p, i);
            if rpi == 0.0 {
                continue;
            }
            let xp = &tail[(p - i - 1) * m..(p - i) * m];
            for (v, &w) in xi.iter_mut().zip(xp) {
                *v -= rpi * w;
            }
        }
        for v in xi.iter_mut() {
            *v /= rii;
        }
    }
    x
}

/// M = B R^{-T} computed as solve(R M^T = B^T): the whitening projection of
/// Algorithm 1 step 4, using the identity S^{-1} R = R^{-T}.
pub fn right_mul_inv_rt(b: &Matrix, r: &Matrix) -> Matrix {
    let bt = b.transpose();
    let mt = solve_lower(r, &bt);
    mt.transpose()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 16, 33] {
            let s = Matrix::random_spd(n, &mut rng);
            let r = cholesky(&s).unwrap();
            let rec = r.matmul_bt(&r);
            assert_close(&rec.data, &s.data, 1e-8);
            // lower-triangular: entries above diagonal are zero
            for i in 0..n {
                for j in (i + 1)..n {
                    assert_eq!(r.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let s = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(cholesky(&s).is_err());
    }

    #[test]
    fn jittered_handles_singular() {
        // rank-1 PSD matrix: x x^T
        let x = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let s = x.matmul_bt(&x);
        let (r, eps) = cholesky_jittered(&s, 1e-8);
        assert!(eps > 0.0);
        let rec = r.matmul_bt(&r);
        // reconstruction matches up to the jitter magnitude
        let diff = rec.sub(&s).max_abs();
        let scale = (s.get(0, 0) + s.get(1, 1) + s.get(2, 2)) / 3.0;
        assert!(diff <= eps * scale * 1.01 + 1e-12, "diff={diff}");
    }

    #[test]
    fn jittered_no_jitter_when_pd() {
        let mut rng = Rng::new(2);
        let s = Matrix::random_spd(8, &mut rng);
        let (_, eps) = cholesky_jittered(&s, 1e-8);
        assert_eq!(eps, 0.0);
    }

    #[test]
    fn solve_lower_inverts() {
        let mut rng = Rng::new(3);
        let s = Matrix::random_spd(12, &mut rng);
        let r = cholesky(&s).unwrap();
        let b = Matrix::random(12, 5, &mut rng, 1.0);
        let x = solve_lower(&r, &b);
        assert_close(&r.matmul(&x).data, &b.data, 1e-9);
    }

    #[test]
    fn solve_upper_t_inverts() {
        let mut rng = Rng::new(4);
        let s = Matrix::random_spd(10, &mut rng);
        let r = cholesky(&s).unwrap();
        let b = Matrix::random(10, 7, &mut rng, 1.0);
        let x = solve_upper_t(&r, &b);
        assert_close(&r.transpose().matmul(&x).data, &b.data, 1e-9);
    }

    #[test]
    fn right_mul_inv_rt_identity() {
        // B R^{-T} * R^T == B
        let mut rng = Rng::new(5);
        let s = Matrix::random_spd(9, &mut rng);
        let r = cholesky(&s).unwrap();
        let b = Matrix::random(4, 9, &mut rng, 1.0);
        let m = right_mul_inv_rt(&b, &r);
        let back = m.matmul(&r.transpose());
        assert_close(&back.data, &b.data, 1e-9);
    }

    #[test]
    fn whitening_identity_sinv_r_eq_rinv_t() {
        // S^{-1} R == R^{-T}: right_mul_inv_rt(W C, R) == W C S^{-1} R
        let mut rng = Rng::new(6);
        let n = 8;
        let s = Matrix::random_spd(n, &mut rng);
        let r = cholesky(&s).unwrap();
        let wc = Matrix::random(5, n, &mut rng, 1.0);
        let got = right_mul_inv_rt(&wc, &r);
        // explicit: W C S^{-1} R via solving S Y = (WC)^T then Y^T R
        let yt = {
            // S Y = (WC)^T  =>  Y = S^{-1} (WC)^T; solve via chol twice
            let z = solve_lower(&r, &wc.transpose());
            solve_upper_t(&r, &z)
        };
        let want = yt.transpose().matmul(&r);
        assert_close(&got.data, &want.data, 1e-8);
    }
}
