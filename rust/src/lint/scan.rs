//! Line/token scanner: strips comments and string/char literals, tracks
//! `#[cfg(test)]` regions, parses suppression directives, and matches
//! the rule patterns against what is left.
//!
//! The scanner is deliberately textual — it does not parse Rust. That
//! keeps it dependency-free and fast, at the cost of documented
//! blind spots (e.g. a float `+=` accumulation loop or a bare `.sum()`
//! without a float turbofish is not detected). The fixture corpus in
//! `tests/lint_fixtures/` pins the exact semantics.
//!
//! Suppression syntax (line comments only, not block comments):
//!
//! - `// aasvd-lint: allow(<rule>): <justification>` — suppresses
//!   `<rule>` on the same line if the comment trails code, otherwise on
//!   the next line that contains code.
//! - `// aasvd-lint: allow-file(<rule>): <justification>` — suppresses
//!   `<rule>` for the whole file, from anywhere in it.
//! - `// aasvd-lint: path=<virtual path>` — makes the file lint as if it
//!   lived at `<virtual path>` (fixture corpus only; lets a file under
//!   `tests/lint_fixtures/` exercise the `src/serve/` policy).
//!
//! A directive with an unknown rule name or a missing justification is
//! itself a violation (`lint-directive`) and suppresses nothing.

use std::fmt;
use std::path::Path;

use super::rules::{self, RULES, RULE_LINT_DIRECTIVE};

/// One finding: which rule fired, where, and on what code.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Rule name (kebab-case), or `lint-directive` for malformed
    /// suppression comments.
    pub rule: String,
    /// Path as supplied to the scanner (normalized to `/` separators).
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// The offending source line, trimmed.
    pub snippet: String,
    /// One-line rationale / error detail.
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}\n    {}",
            self.path, self.line, self.rule, self.detail, self.snippet
        )
    }
}

/// A source line after lexical stripping.
struct ScanLine {
    /// Code with comments and string/char literal *contents* removed
    /// (quotes are kept, so `".expect("` inside a string cannot fire).
    code: String,
    /// Concatenated `//` comment text on this line (block comments are
    /// discarded — directives must use line comments).
    comment: String,
    /// Raw source line (for snippets).
    raw: String,
}

/// Strip comments and literal contents, producing one [`ScanLine`] per
/// source line. Handles nested block comments, raw strings with hash
/// fences, byte strings/chars, and the `'a` lifetime vs `'a'` char
/// ambiguity.
fn strip(source: &str) -> Vec<ScanLine> {
    #[derive(Clone, Copy, PartialEq)]
    enum State {
        Code,
        Block(u32),   // nesting depth
        Str,          // normal "..." (contents skipped, escapes honored)
        RawStr(u32),  // r##"..."## with N hashes
    }
    let bytes = source.as_bytes();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut raw_line_start = 0usize;
    let mut state = State::Code;
    let mut i = 0usize;

    let utf8_len = |b: u8| -> usize {
        if b < 0x80 {
            1
        } else if b >= 0xF0 {
            4
        } else if b >= 0xE0 {
            3
        } else {
            2
        }
    };

    loop {
        // escape skipping can step past the end on malformed input;
        // clamp so the final line is still emitted
        if i > bytes.len() {
            i = bytes.len();
        }
        if i == bytes.len() || bytes[i] == b'\n' {
            let raw = source[raw_line_start..i].trim_end_matches('\r').to_string();
            lines.push(ScanLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                raw,
            });
            if i == bytes.len() {
                break;
            }
            i += 1;
            raw_line_start = i;
            continue;
        }
        let b = bytes[i];
        match state {
            State::Code => {
                if b == b'/' && i + 1 < bytes.len() && bytes[i + 1] == b'/' {
                    // line comment: capture text to end of line
                    let mut j = i + 2;
                    while j < bytes.len() && bytes[j] != b'\n' {
                        j += 1;
                    }
                    comment.push_str(&source[i + 2..j]);
                    i = j;
                } else if b == b'/' && i + 1 < bytes.len() && bytes[i + 1] == b'*' {
                    state = State::Block(1);
                    i += 2;
                } else if b == b'"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if b == b'r' && !prev_is_ident(bytes, i) {
                    if let Some(n) = raw_str_hashes(bytes, i + 1) {
                        code.push('"');
                        state = State::RawStr(n);
                        i += 1 + n as usize + 1; // r + hashes + quote
                    } else {
                        code.push('r');
                        i += 1;
                    }
                } else if b == b'b' && !prev_is_ident(bytes, i) && i + 1 < bytes.len() {
                    match bytes[i + 1] {
                        b'"' => {
                            code.push('"');
                            state = State::Str;
                            i += 2;
                        }
                        b'r' if raw_str_hashes(bytes, i + 2).is_some() => {
                            let n = raw_str_hashes(bytes, i + 2).unwrap_or(0);
                            code.push('"');
                            state = State::RawStr(n);
                            i += 2 + n as usize + 1;
                        }
                        b'\'' => {
                            // byte char literal b'x' — always a char, never
                            // a lifetime
                            code.push('\'');
                            i = skip_char_literal(bytes, i + 1);
                        }
                        _ => {
                            code.push('b');
                            i += 1;
                        }
                    }
                } else if b == b'\'' {
                    // char literal or lifetime: 'x' / '\n' are chars,
                    // 'static / 'a (no closing quote right after one
                    // char) are lifetimes
                    if is_char_literal(bytes, i) {
                        code.push('\'');
                        i = skip_char_literal(bytes, i);
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(source[i..].chars().next().unwrap_or('\u{FFFD}'));
                    i += utf8_len(b);
                }
            }
            State::Block(depth) => {
                if b == b'*' && i + 1 < bytes.len() && bytes[i + 1] == b'/' {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::Block(depth - 1)
                    };
                    i += 2;
                } else if b == b'/' && i + 1 < bytes.len() && bytes[i + 1] == b'*' {
                    state = State::Block(depth + 1);
                    i += 2;
                } else {
                    i += utf8_len(b);
                }
            }
            State::Str => {
                if b == b'\\' {
                    i += 2; // skip escaped byte (covers \" and \\)
                } else if b == b'"' {
                    code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    i += utf8_len(b);
                }
            }
            State::RawStr(n) => {
                if b == b'"' && hashes_after(bytes, i + 1) >= n {
                    code.push('"');
                    state = State::Code;
                    i += 1 + n as usize;
                } else {
                    i += utf8_len(b);
                }
            }
        }
    }
    lines
}

fn prev_is_ident(bytes: &[u8], i: usize) -> bool {
    i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_')
}

/// At `i` (just past an `r` / `br` prefix): `Some(n)` if `#`*n `"` starts a
/// raw string here.
fn raw_str_hashes(bytes: &[u8], i: usize) -> Option<u32> {
    let mut n = 0u32;
    let mut j = i;
    while j < bytes.len() && bytes[j] == b'#' {
        n += 1;
        j += 1;
    }
    (j < bytes.len() && bytes[j] == b'"').then_some(n)
}

fn hashes_after(bytes: &[u8], i: usize) -> u32 {
    let mut n = 0u32;
    let mut j = i;
    while j < bytes.len() && bytes[j] == b'#' {
        n += 1;
        j += 1;
    }
    n
}

/// Is the `'` at `i` the start of a char literal (vs a lifetime)?
fn is_char_literal(bytes: &[u8], i: usize) -> bool {
    let Some(&next) = bytes.get(i + 1) else {
        return false;
    };
    if next == b'\\' {
        return true; // '\n', '\'', '\u{..}'
    }
    if next == b'\'' {
        return false; // '' — not valid anyway
    }
    // one char (possibly multibyte) then a closing quote → char literal
    let step = if next < 0x80 {
        1
    } else if next >= 0xF0 {
        4
    } else if next >= 0xE0 {
        3
    } else {
        2
    };
    bytes.get(i + 1 + step) == Some(&b'\'')
}

/// Skip past the char literal whose opening `'` is at `i`; returns the
/// index just past the closing quote.
fn skip_char_literal(bytes: &[u8], i: usize) -> usize {
    let mut j = i + 1;
    while j < bytes.len() {
        match bytes[j] {
            b'\\' => j += 2,
            b'\'' => return j + 1,
            b'\n' => return j, // malformed; bail at line end
            b => {
                j += if b < 0x80 {
                    1
                } else if b >= 0xF0 {
                    4
                } else if b >= 0xE0 {
                    3
                } else {
                    2
                }
            }
        }
    }
    j
}

/// A parsed suppression comment.
enum Directive {
    Allow(&'static str),
    AllowFile(&'static str),
    Path(String),
    Malformed(String),
}

/// Parse an `aasvd-lint:` directive out of a line-comment body, if any.
fn parse_directive(comment: &str) -> Option<Directive> {
    let body = comment.trim();
    let rest = body.strip_prefix("aasvd-lint:")?.trim();
    if let Some(p) = rest.strip_prefix("path=") {
        let p = p.trim();
        if p.is_empty() {
            return Some(Directive::Malformed("empty path= directive".into()));
        }
        return Some(Directive::Path(p.to_string()));
    }
    for (prefix, file_wide) in [("allow-file(", true), ("allow(", false)] {
        if let Some(rest) = rest.strip_prefix(prefix) {
            let Some(close) = rest.find(')') else {
                return Some(Directive::Malformed("unclosed allow(...)".into()));
            };
            let rule = rest[..close].trim();
            let Some(known) = RULES.iter().find(|r| r.name == rule).map(|r| r.name) else {
                return Some(Directive::Malformed(format!(
                    "unknown rule '{rule}' in suppression"
                )));
            };
            let tail = rest[close + 1..].trim();
            let justification = tail.strip_prefix(':').map(str::trim).unwrap_or("");
            if justification.is_empty() {
                return Some(Directive::Malformed(format!(
                    "suppression of '{rule}' missing a justification \
                     (write `allow({rule}): <why>`)"
                )));
            }
            return Some(if file_wide {
                Directive::AllowFile(known)
            } else {
                Directive::Allow(known)
            });
        }
    }
    Some(Directive::Malformed(format!(
        "unrecognized aasvd-lint directive '{rest}'"
    )))
}

/// Scan one file's source text. `path` is used for reporting; the policy
/// path is derived from it unless the file carries a `path=` directive.
pub fn scan_source(path: &str, source: &str) -> Vec<Violation> {
    let display_path = path.replace('\\', "/");
    let lines = strip(source);

    // Pre-pass: file-wide directives (path=, allow-file) act from
    // anywhere in the file; malformed directives become violations here
    // so the main pass can treat them as inert.
    let mut policy_path = rules::policy_path(&display_path);
    let mut file_allows: Vec<&'static str> = Vec::new();
    let mut violations: Vec<Violation> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        match parse_directive(&line.comment) {
            Some(Directive::Path(p)) => policy_path = rules::policy_path(&p),
            Some(Directive::AllowFile(rule)) => file_allows.push(rule),
            Some(Directive::Malformed(detail)) => violations.push(Violation {
                rule: RULE_LINT_DIRECTIVE.to_string(),
                path: display_path.clone(),
                line: idx + 1,
                snippet: line.raw.trim().to_string(),
                detail,
            }),
            Some(Directive::Allow(_)) | None => {}
        }
    }

    // Main pass: cfg(test) tracking + line-level suppressions + rules.
    //
    // cfg(test) regions are tracked by brace depth: when `#[cfg(test)]`
    // is seen, the next `{` opens a region that closes when depth
    // returns to its pre-region value.
    let mut depth: i32 = 0;
    let mut test_region_floor: Option<i32> = None;
    let mut pending_test_attr = false;
    let mut pending_allows: Vec<&'static str> = Vec::new();

    for (idx, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        let has_code = !code.trim().is_empty();
        let in_test = test_region_floor.is_some();

        // Collect the suppressions that target this line: a trailing
        // directive on a code line, plus any pending standalone ones.
        let mut line_allows: Vec<&'static str> = Vec::new();
        if let Some(Directive::Allow(rule)) = parse_directive(&line.comment) {
            if has_code {
                line_allows.push(rule);
            } else {
                pending_allows.push(rule);
            }
        }
        if has_code {
            line_allows.append(&mut pending_allows);
        }

        if has_code {
            for rule in RULES {
                if !rules::applies(rule.name, &policy_path, in_test) {
                    continue;
                }
                if file_allows.contains(&rule.name) || line_allows.contains(&rule.name) {
                    continue;
                }
                if rule.patterns.iter().any(|p| code.contains(p)) {
                    violations.push(Violation {
                        rule: rule.name.to_string(),
                        path: display_path.clone(),
                        line: idx + 1,
                        snippet: line.raw.trim().to_string(),
                        detail: rule.summary.to_string(),
                    });
                }
            }
        }

        // Update cfg(test) tracking *after* matching: the line opening a
        // test region (`mod tests {`) is not itself exempt, its body is.
        if code.contains("#[cfg(test)]") {
            pending_test_attr = true;
        }
        for ch in code.chars() {
            match ch {
                '{' => {
                    if pending_test_attr {
                        if test_region_floor.is_none() {
                            test_region_floor = Some(depth);
                        }
                        pending_test_attr = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if test_region_floor == Some(depth) {
                        test_region_floor = None;
                    }
                }
                _ => {}
            }
        }
    }
    violations.sort_by(|a, b| (a.line, a.rule.as_str()).cmp(&(b.line, b.rule.as_str())));
    violations
}

/// Scan a file from disk.
pub fn scan_file(path: &Path) -> std::io::Result<Vec<Violation>> {
    let source = std::fs::read_to_string(path)?;
    Ok(scan_source(&path.to_string_lossy(), &source))
}

/// Directories never descended into: build output, and the known-bad
/// fixture corpus (which would otherwise fail the tree scan). Passing
/// the fixture dir itself as a root still scans it — that is how the
/// fixture tests and the "nonzero on the corpus" acceptance check run.
const SKIP_DIRS: &[&str] = &["target", "lint_fixtures", ".git"];

/// Recursively scan every `.rs` file under `root` (or `root` itself if
/// it is a file). Returns `(files_scanned, violations)`, both in a
/// deterministic (sorted) order.
pub fn scan_tree(root: &Path) -> std::io::Result<(usize, Vec<Violation>)> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut violations = Vec::new();
    for f in &files {
        violations.extend(scan_file(f)?);
    }
    Ok((files.len(), violations))
}

fn collect_rs_files(path: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    let meta = std::fs::metadata(path)?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<_> = std::fs::read_dir(path)?
        .collect::<std::io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for entry in entries {
        let name = entry
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        if entry.is_dir() {
            if SKIP_DIRS.contains(&name.as_str()) || name.starts_with('.') {
                continue;
            }
            collect_rs_files(&entry, out)?;
        } else if entry.extension().is_some_and(|e| e == "rs") {
            out.push(entry);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(path: &str, src: &str) -> Vec<String> {
        scan_source(path, src)
            .into_iter()
            .map(|v| v.rule)
            .collect()
    }

    #[test]
    fn strings_and_comments_do_not_fire() {
        let src = r###"
// HashMap in a comment is fine
/* Instant::now() in a block comment,
   /* nested */ still fine */
fn f() -> &'static str {
    let _lifetime: &'static str = "thread::spawn inside a string";
    let _raw = r#"partial_cmp in a raw "quoted" string"#;
    let _ch = '"'; // a quote char must not open a string
    "env::var"
}
"###;
        assert!(rules_fired("src/linalg/x.rs", src).is_empty());
    }

    #[test]
    fn patterns_fire_in_code() {
        let src = "fn f() { let _ = std::thread::spawn(|| {}); }\n";
        assert_eq!(rules_fired("src/model/x.rs", src), vec!["adhoc-parallelism"]);
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules_fired("src/refine/x.rs", src), vec!["hash-iter"]);
        // same file outside a restricted tree: no hash-iter violation
        assert!(rules_fired("src/util/x.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_regions_are_exempt_where_policy_says() {
        let src = "\
fn hot() -> f64 {
    0.0
}
#[cfg(test)]
mod tests {
    fn reference() -> f64 {
        [1.0f64].iter().sum::<f64>()
    }
}
";
        // float-reduce is test-exempt, so the test-mod sum is clean
        assert!(rules_fired("src/compress/x.rs", src).is_empty());
        // but the same sum in non-test code fires
        let src2 = "fn hot() -> f64 { [1.0f64].iter().sum::<f64>() }\n";
        assert_eq!(rules_fired("src/compress/x.rs", src2), vec!["float-reduce"]);
    }

    #[test]
    fn suppressions_target_the_next_code_line() {
        let src = "\
// aasvd-lint: allow(float-reduce): reference implementation for docs
fn f() -> f64 {
    [1.0f64].iter().sum::<f64>()
}
";
        // standalone suppression above `fn f` covers the fn line, NOT
        // the sum two lines below — the violation still fires
        assert_eq!(rules_fired("src/eval/x.rs", src), vec!["float-reduce"]);
        let src2 = "\
fn f() -> f64 {
    // aasvd-lint: allow(float-reduce): sequential, order-pinned by slice
    [1.0f64].iter().sum::<f64>()
}
";
        assert!(rules_fired("src/eval/x.rs", src2).is_empty());
    }

    #[test]
    fn trailing_suppression_covers_its_own_line() {
        let src =
            "fn f() -> f64 { [1.0f64].iter().sum::<f64>() } // aasvd-lint: allow(float-reduce): doc example\n";
        assert!(rules_fired("src/eval/x.rs", src).is_empty());
    }

    #[test]
    fn allow_file_covers_everything() {
        let src = "\
// aasvd-lint: allow-file(wallclock): operator-facing stage timings only
fn a() { let _ = std::time::Instant::now(); }
fn b() { let _ = std::time::Instant::now(); }
";
        assert!(rules_fired("src/compress/x.rs", src).is_empty());
    }

    #[test]
    fn malformed_suppressions_are_violations_and_inert() {
        // missing justification: directive violation AND the rule still fires
        let src = "\
fn f() {
    // aasvd-lint: allow(wallclock)
    let _ = std::time::Instant::now();
}
";
        let fired = rules_fired("src/linalg/x.rs", src);
        assert_eq!(fired, vec!["lint-directive", "wallclock"]);
        // unknown rule name
        let src2 = "// aasvd-lint: allow(no-such-rule): whatever\n";
        assert_eq!(rules_fired("src/linalg/x.rs", src2), vec!["lint-directive"]);
    }

    #[test]
    fn path_directive_reassigns_policy() {
        let src = "\
// aasvd-lint: path=src/serve/fake.rs
fn f() { let _ = Some(1).unwrap(); }
";
        assert_eq!(
            rules_fired("tests/lint_fixtures/x.rs", src),
            vec!["serve-unwrap"]
        );
    }

    #[test]
    fn scan_is_deterministic() {
        let src = "fn f() { let _ = Some(1).partial_cmp(&Some(2)); }\n";
        let a = scan_source("src/x.rs", src);
        let b = scan_source("src/x.rs", src);
        assert_eq!(a, b);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].line, 1);
    }
}
