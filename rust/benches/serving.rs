//! Serving perf: closed-loop throughput + batch-occupancy of the
//! continuous-batching engine on the tiny model (bench-speed), dense vs
//! compressed-with-exact-factors (isolates low-rank kernel cost).

use aasvd::bench::Bench;
use aasvd::model::init::init_params;
use aasvd::model::lowrank::exact_factors;
use aasvd::model::Config;
use aasvd::runtime::Engine;
use aasvd::serve::batcher::bench_prompts;
use aasvd::serve::{GenParams, ServedModel, Server};
use aasvd::util::rng::Rng;

fn main() {
    if Engine::new("artifacts")
        .map(|e| e.entry("tiny").is_err())
        .unwrap_or(true)
    {
        eprintln!("no artifacts — run `make artifacts` first");
        return;
    }
    let cfg = Config::builtin("tiny").unwrap();
    let params = init_params(&cfg, &mut Rng::new(1));
    let blocks: Vec<_> = (0..cfg.n_layers)
        .map(|i| exact_factors(&cfg, &params, i))
        .collect();
    let prompts = bench_prompts(16, 5);

    let mut b = Bench::new();
    b.min_iters = 3;
    b.max_iters = 6;
    let variants: Vec<(&str, Box<dyn Fn() -> ServedModel>)> = vec![
        (
            "dense",
            Box::new({
                let p = params.clone();
                move || ServedModel::Dense(p.clone())
            }),
        ),
        (
            "lowrank",
            Box::new({
                let p = params.clone();
                let bl = blocks.clone();
                move || ServedModel::Compressed(p.clone(), bl.clone())
            }),
        ),
    ];
    for (label, make_model) in variants {
        b.run(
            &format!("serve[{label}] 16 reqs x 8 toks (closed loop)"),
            Some(16.0 * 8.0),
            || {
                let server =
                    Server::start("artifacts".into(), cfg.clone(), make_model());
                let completions: Vec<_> = prompts
                    .iter()
                    .map(|p| {
                        server
                            .submit(
                                p,
                                GenParams {
                                    max_new_tokens: 8,
                                    temperature: 0.0,
                                    ..Default::default()
                                },
                            )
                            .expect("closed loop stays under max_queue")
                    })
                    .collect();
                for c in completions {
                    c.wait().unwrap();
                }
                let m = server.shutdown();
                std::hint::black_box(m);
            },
        );
    }
    b.save("serving");
}
