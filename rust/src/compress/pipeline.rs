//! Algorithm 2: end-to-end block-wise compression with local refinement.
//!
//! The coordinator walks the model block by block, maintaining two
//! activation streams over the calibration set:
//!   X  — inputs produced by the *original* dense network
//!   X' — inputs produced by the *partially compressed* network
//! Within a block, linears are compressed in topological groups sharing a
//! tap position (q/k/v → wo → gate/up → w_down; covariances shared within a
//! group, paper §B.1), re-collecting shifted taps after each group so X'_j
//! always reflects a valid partial compression state. After all linears,
//! block-level refinement (refine::driver) jointly tunes the factors
//! against the dense block's outputs on original inputs.

use super::cov::CovTriple;
use super::layer::{compress_layer, compress_layer_asvd, compress_layer_plain};
use super::objective::Objective;
use super::quant::quantize_factors_inplace;
use super::rank::{Allocation, RankScheme};
use crate::data::TokenBatch;
use crate::model::lowrank::{exact_factors, BlockFactors};
use crate::model::{Config, FlatStore};
#[cfg(test)]
use crate::model::BLOCK_LINEARS;
use crate::refine::{refine_block, RefineOptions, RefineReport};
use crate::runtime::{Engine, Value};
use anyhow::Result;
use std::time::Instant;

/// A named compression method (one table row). Knobs are private: build
/// one with a named constructor or [`Method::builder`].
#[derive(Clone, Debug)]
pub struct Method {
    pub name: String,
    objective: Objective,
    /// use ASVD-style diagonal scaling instead of the full whitening solve
    asvd_diag: bool,
    scheme: RankScheme,
    quant: bool,
    refine: Option<RefineOptions>,
}

/// Fluent constructor for [`Method`]; new knobs get a defaulted builder
/// setter instead of breaking every call site.
#[derive(Clone, Debug)]
pub struct MethodBuilder {
    method: Method,
}

impl MethodBuilder {
    pub fn objective(mut self, objective: Objective) -> Self {
        self.method.objective = objective;
        self
    }

    /// ASVD-style diagonal scaling instead of the full whitening solve.
    pub fn asvd_diag(mut self) -> Self {
        self.method.asvd_diag = true;
        self
    }

    pub fn scheme(mut self, scheme: RankScheme) -> Self {
        self.method.scheme = scheme;
        self
    }

    /// int8-quantize the factors after the solve.
    pub fn quant(mut self) -> Self {
        self.method.quant = true;
        self
    }

    /// block-level local refinement after the layer-wise solves.
    pub fn refine(mut self, options: RefineOptions) -> Self {
        self.method.refine = Some(options);
        self
    }

    pub fn build(self) -> Method {
        self.method
    }
}

impl Method {
    /// Start from the input-agnostic / standard-scheme baseline.
    pub fn builder(name: impl Into<String>) -> MethodBuilder {
        MethodBuilder {
            method: Method {
                name: name.into(),
                objective: Objective::InputAgnostic,
                asvd_diag: false,
                scheme: RankScheme::Standard,
                quant: false,
                refine: None,
            },
        }
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    pub fn asvd_diag(&self) -> bool {
        self.asvd_diag
    }

    pub fn scheme(&self) -> RankScheme {
        self.scheme
    }

    pub fn quantized(&self) -> bool {
        self.quant
    }

    pub fn refine_options(&self) -> Option<&RefineOptions> {
        self.refine.as_ref()
    }

    pub fn naive_svd() -> Method {
        Method::builder("naive_svd").build()
    }

    pub fn asvd() -> Method {
        Method::builder("asvd").objective(Objective::InputAware).asvd_diag().build()
    }

    pub fn svd_llm() -> Method {
        Method::builder("svd_llm").objective(Objective::InputAware).build()
    }

    /// Dobi-SVD-like: shift-aware objective (+remap/quant in `dobi_q`).
    pub fn dobi() -> Method {
        Method::builder("dobi").objective(Objective::ShiftAware).build()
    }

    pub fn dobi_q() -> Method {
        Method::builder("dobi_q")
            .objective(Objective::ShiftAware)
            .scheme(RankScheme::Remap)
            .quant()
            .build()
    }

    /// AA-SVD: input-aware init + block-level refinement (paper §4.3 pairing).
    pub fn aa_svd(refine: RefineOptions) -> Method {
        Method::builder("aa_svd").objective(Objective::InputAware).refine(refine).build()
    }

    /// AA-SVDᵠ: remapped ranks + int8 factors + refinement.
    pub fn aa_svd_q(refine: RefineOptions) -> Method {
        Method::builder("aa_svd_q")
            .objective(Objective::InputAware)
            .scheme(RankScheme::Remap)
            .quant()
            .refine(refine)
            .build()
    }

    /// Ablation constructor: any objective × refinement (Table 5 rows).
    pub fn ablation(objective: Objective, refine: Option<RefineOptions>) -> Method {
        let name = format!(
            "{}{}",
            objective.name(),
            if refine.is_some() { "+refine" } else { "" }
        );
        let builder = Method::builder(name).objective(objective);
        match refine {
            Some(options) => builder.refine(options).build(),
            None => builder.build(),
        }
    }

    /// Does this method ever need the shifted activation stream?
    fn needs_shift(&self) -> bool {
        self.objective.needs_shift() || self.refine.is_some() || self.quant
    }
}

/// Result of compressing a model.
pub struct CompressedModel {
    pub blocks: Vec<BlockFactors>,
    pub allocation: Allocation,
    pub report: CompressReport,
}

#[derive(Clone, Debug, Default)]
pub struct CompressReport {
    pub refine: Vec<RefineReport>,
    pub secs_collect: f64,
    pub secs_solve: f64,
    pub secs_refine: f64,
    pub quant_err: f64,
}

/// The tap groups: (tap index into collect outputs, linears fed by it).
/// Collect outputs are (y, a_in, o_in, m_in, d_in).
const GROUPS: [(usize, &[&str]); 4] = [
    (1, &["wq", "wk", "wv"]),
    (2, &["wo"]),
    (3, &["w_gate", "w_up"]),
    (4, &["w_down"]),
];

/// Pack block `i`'s dense params into the bare-name block layout used by
/// the block_fwd/block_collect artifacts.
pub fn pack_block_params(cfg: &Config, params: &FlatStore, i: usize) -> Vec<f32> {
    let lay = crate::model::params::block_param_layout(cfg);
    let mut bp = vec![0f32; lay.total];
    for e in &lay.entries {
        let src = params.view(&format!("blocks.{i}.{}", e.name));
        let size: usize = e.shape.iter().product();
        bp[e.offset..e.offset + size].copy_from_slice(src);
    }
    bp
}

/// Embed calibration tokens (Rust-side gather — step 1 of Algorithm 2).
pub fn embed_batches(cfg: &Config, params: &FlatStore, batches: &[TokenBatch]) -> Vec<Vec<f32>> {
    let d = cfg.d_model;
    let embed = params.view("embed");
    batches
        .iter()
        .map(|tb| {
            let mut x = vec![0f32; tb.tokens.len() * d];
            for (i, &tok) in tb.tokens.iter().enumerate() {
                let tok = tok as usize;
                x[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
            }
            x
        })
        .collect()
}

/// Dense-block taps over all calibration batches.
struct Taps {
    y: Vec<Vec<f32>>,
    per_tap: [Vec<Vec<f32>>; 4], // a_in, o_in, m_in, d_in
}

fn collect_dense(
    engine: &Engine,
    cfg: &Config,
    bp: &[f32],
    xs: &[Vec<f32>],
) -> Result<Taps> {
    let mut taps = Taps {
        y: Vec::new(),
        per_tap: [Vec::new(), Vec::new(), Vec::new(), Vec::new()],
    };
    for x in xs {
        let out = engine.run(
            &cfg.name,
            "block_collect",
            &[Value::F32(bp), Value::F32(x)],
        )?;
        taps.y.push(out[0].f32.clone());
        for t in 0..4 {
            taps.per_tap[t].push(out[t + 1].f32.clone());
        }
    }
    Ok(taps)
}

fn collect_lr_tap(
    engine: &Engine,
    cfg: &Config,
    bf: &BlockFactors,
    xs: &[Vec<f32>],
    tap: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut out_taps = Vec::new();
    for x in xs {
        let out = engine.run(
            &cfg.name,
            "block_lr_collect",
            &[
                Value::F32(&bf.factors.data),
                Value::F32(&bf.masks.data),
                Value::F32(x),
            ],
        )?;
        out_taps.push(out[tap + 1].f32.clone());
    }
    Ok(out_taps)
}

/// Compress one linear according to the method; returns padded (U, V)
/// written into `bf` with the mask set to rank k.
#[allow(clippy::too_many_arguments)]
fn compress_one(
    method: &Method,
    cfg: &Config,
    params: &FlatStore,
    block: usize,
    lin: &str,
    cov: &CovTriple,
    k: usize,
    bf: &mut BlockFactors,
) -> f64 {
    let (m, n) = cfg.linear_dims(lin);
    let w = params.view(&format!("blocks.{block}.{lin}"));
    let f = if method.asvd_diag {
        compress_layer_asvd(w, m, n, &cov.channel_scales(), 0.5, k)
    } else {
        match method.objective.assemble(cov) {
            None => compress_layer_plain(w, m, n, k),
            Some((c, s)) => compress_layer(w, m, n, &c, &s, k),
        }
    };
    let mut u = f.u;
    let mut v = f.v;
    let mut qerr = 0.0;
    if method.quant {
        let (eu, ev) = quantize_factors_inplace(&mut u, m, &mut v, n, f.k);
        qerr = 0.5 * (eu + ev);
    }
    // write into the padded buffers
    let kmax = cfg.kmax(lin);
    {
        let ub = bf.factors.view_mut(&format!("{lin}.u"));
        ub.fill(0.0);
        for i in 0..m {
            ub[i * kmax..i * kmax + f.k].copy_from_slice(&u[i * f.k..(i + 1) * f.k]);
        }
    }
    {
        let vb = bf.factors.view_mut(&format!("{lin}.v"));
        vb.fill(0.0);
        for i in 0..n {
            vb[i * kmax..i * kmax + f.k].copy_from_slice(&v[i * f.k..(i + 1) * f.k]);
        }
    }
    bf.set_rank(lin, f.k);
    qerr
}

/// Algorithm 2. `calib` batches must all be full (`real_rows == batch`).
pub fn compress_model(
    engine: &Engine,
    cfg: &Config,
    params: &FlatStore,
    calib: &[TokenBatch],
    method: &Method,
    ratio: f64,
) -> Result<CompressedModel> {
    assert!(
        calib.iter().all(|b| b.real_rows == cfg.batch),
        "calibration batches must be full"
    );
    let allocation = Allocation::uniform(cfg, ratio, method.scheme);
    let mut report = CompressReport::default();

    // step 1: X <- X' <- embedding of calibration data
    let mut xs = embed_batches(cfg, params, calib);
    let mut xs_shift: Vec<Vec<f32>> = if method.needs_shift() {
        xs.clone()
    } else {
        Vec::new()
    };

    let mut blocks: Vec<BlockFactors> = Vec::with_capacity(cfg.n_layers);
    let mut quant_errs: Vec<f64> = Vec::new();

    for i in 0..cfg.n_layers {
        let bp = pack_block_params(cfg, params, i);
        // dense taps on original inputs (X_j for every group, plus Y target)
        let t0 = Instant::now();
        let dense_taps = collect_dense(engine, cfg, &bp, &xs)?;
        report.secs_collect += t0.elapsed().as_secs_f64();

        // initialize L'_i <- L_i (exact full-rank factorization)
        let mut bf = exact_factors(cfg, params, i);

        for (tap_idx, linears) in GROUPS {
            // collect shifted tap from the *current* partial state of L'_i
            let t0 = Instant::now();
            let shift_tap: Option<Vec<Vec<f32>>> = if method.objective.needs_shift() {
                Some(collect_lr_tap(engine, cfg, &bf, &xs_shift, tap_idx - 1)?)
            } else {
                None
            };
            report.secs_collect += t0.elapsed().as_secs_f64();

            // accumulate covariances (shared by all linears in the group)
            let t0 = Instant::now();
            let dim = if tap_idx == 4 { cfg.d_ff } else { cfg.d_model };
            let mut cov = CovTriple::new(dim);
            match &shift_tap {
                Some(shift) => {
                    for (o, s) in dense_taps.per_tap[tap_idx - 1].iter().zip(shift) {
                        cov.add_chunk(o, s);
                    }
                }
                None => {
                    for o in &dense_taps.per_tap[tap_idx - 1] {
                        cov.add_chunk_same(o);
                    }
                    cov.mirror_same();
                }
            }

            for lin in linears {
                let k = allocation.rank_of(lin);
                let qerr =
                    compress_one(method, cfg, params, i, lin, &cov, k, &mut bf);
                if method.quant {
                    quant_errs.push(qerr);
                }
            }
            report.secs_solve += t0.elapsed().as_secs_f64();
        }

        // step 9: block-level local refinement
        if let Some(ropts) = &method.refine {
            let t0 = Instant::now();
            let x_shift_flat = concat_batches(&xs_shift);
            let y_flat = concat_batches(&dense_taps.y);
            let rep = refine_block(engine, cfg, &mut bf, &x_shift_flat, &y_flat, ropts)?;
            report.refine.push(rep);
            report.secs_refine += t0.elapsed().as_secs_f64();
        }

        // step 10: advance both streams
        if method.needs_shift() {
            let t0 = Instant::now();
            for x in xs_shift.iter_mut() {
                let out = engine.run(
                    &cfg.name,
                    "block_lr_fwd",
                    &[
                        Value::F32(&bf.factors.data),
                        Value::F32(&bf.masks.data),
                        Value::F32(x),
                    ],
                )?;
                *x = out[0].f32.clone();
            }
            report.secs_collect += t0.elapsed().as_secs_f64();
        }
        xs = dense_taps.y;
        blocks.push(bf);
    }

    report.quant_err = if quant_errs.is_empty() {
        0.0
    } else {
        quant_errs.iter().sum::<f64>() / quant_errs.len() as f64
    };
    Ok(CompressedModel {
        blocks,
        allocation,
        report,
    })
}

/// Chain dense block_collect across the whole model, accumulating
/// (a_in, m_in, d_in) covariance triples per block (same-input mode).
/// Used by the activation-aware pruning baselines.
pub fn collect_dense_taps_for_pruning(
    engine: &Engine,
    cfg: &Config,
    params: &FlatStore,
    mut xs: Vec<Vec<f32>>,
) -> Result<Vec<(CovTriple, CovTriple, CovTriple)>> {
    let mut out = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let bp = pack_block_params(cfg, params, i);
        let taps = collect_dense(engine, cfg, &bp, &xs)?;
        let mut a = CovTriple::new(cfg.d_model);
        let mut m = CovTriple::new(cfg.d_model);
        let mut d = CovTriple::new(cfg.d_ff);
        for batch in &taps.per_tap[0] {
            a.add_chunk_same(batch);
        }
        for batch in &taps.per_tap[2] {
            m.add_chunk_same(batch);
        }
        for batch in &taps.per_tap[3] {
            d.add_chunk_same(batch);
        }
        a.mirror_same();
        m.mirror_same();
        d.mirror_same();
        out.push((a, m, d));
        xs = taps.y;
    }
    Ok(out)
}

fn concat_batches(batches: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::with_capacity(batches.iter().map(|b| b.len()).sum());
    for b in batches {
        out.extend_from_slice(b);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_presets_are_consistent() {
        assert!(!Method::svd_llm().needs_shift());
        assert!(Method::dobi().needs_shift());
        assert!(Method::aa_svd(RefineOptions::default()).needs_shift());
        assert_eq!(Method::naive_svd().objective(), Objective::InputAgnostic);
        assert_eq!(Method::aa_svd_q(RefineOptions::default()).scheme(), RankScheme::Remap);
        assert!(Method::aa_svd_q(RefineOptions::default()).quantized());
    }

    #[test]
    fn builder_composes_knobs() {
        let m = Method::builder("custom")
            .objective(Objective::Anchored)
            .scheme(RankScheme::Remap)
            .quant()
            .refine(RefineOptions::default())
            .build();
        assert_eq!(m.name, "custom");
        assert_eq!(m.objective(), Objective::Anchored);
        assert_eq!(m.scheme(), RankScheme::Remap);
        assert!(m.quantized());
        assert!(m.refine_options().is_some());
        assert!(!m.asvd_diag());
        assert!(m.needs_shift());
        // baseline builder matches the plainest named constructor
        let n = Method::builder("naive_svd").build();
        assert_eq!(n.objective(), Method::naive_svd().objective());
        assert!(!n.needs_shift());
    }

    #[test]
    fn ablation_names() {
        let m = Method::ablation(Objective::Anchored, Some(RefineOptions::default()));
        assert_eq!(m.name, "anchored+refine");
        let m = Method::ablation(Objective::InputAgnostic, None);
        assert_eq!(m.name, "input_agnostic");
    }

    #[test]
    fn groups_cover_all_linears_once() {
        let mut seen: Vec<&str> = GROUPS.iter().flat_map(|(_, l)| l.iter().copied()).collect();
        seen.sort_unstable();
        let mut want = BLOCK_LINEARS.to_vec();
        want.sort_unstable();
        assert_eq!(seen, want);
    }

    /// End-to-end pipeline on the tiny config (skips without artifacts).
    /// This is the topological-order invariant test: compressing with the
    /// anchored objective must produce finite factors with the allocated
    /// ranks, and the compressed model must stay close to dense at high
    /// ratio.
    #[test]
    fn pipeline_end_to_end_tiny() {
        let Ok(engine) = Engine::new("artifacts") else { return };
        if engine.entry("tiny").is_err() {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let params = crate::model::init::init_params(
            &cfg,
            &mut crate::util::rng::Rng::new(3),
        );
        let corpus = crate::data::Corpus::generate(crate::data::Domain::Wiki, 30_000, 7);
        let batcher = crate::data::Batcher::new(cfg.batch, cfg.seq);
        let calib: Vec<_> = batcher
            .sequential(&corpus.train, 4)
            .into_iter()
            .filter(|b| b.real_rows == cfg.batch)
            .collect();
        assert!(calib.len() >= 2);

        let method = Method::ablation(Objective::Anchored, None);
        let cm = compress_model(&engine, &cfg, &params, &calib, &method, 0.9).unwrap();
        assert_eq!(cm.blocks.len(), cfg.n_layers);
        for bf in &cm.blocks {
            for lin in BLOCK_LINEARS {
                assert_eq!(bf.rank(lin), cm.allocation.rank_of(lin));
            }
            assert!(bf.factors.data.iter().all(|v| v.is_finite()));
        }
    }
}
