//! Load-generation helpers for serving benches: closed-loop and open-loop
//! arrival processes.

use crate::util::rng::Rng;

/// Poisson arrival schedule: returns cumulative arrival times (seconds) for
/// `n` requests at `rate` req/s.
pub fn poisson_arrivals(n: usize, rate: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            // exponential inter-arrival
            let u = 1.0 - rng.f64();
            t += -u.ln() / rate.max(1e-9);
            t
        })
        .collect()
}

/// Deterministic prompt set drawn from the synthetic language.
pub fn bench_prompts(n: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            crate::data::corpus::sentence(&mut rng, crate::data::Domain::Wiki)
                .split('.')
                .next()
                .unwrap_or("the cat")
                .to_string()
        })
        .collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_sorted_and_rate_scaled() {
        let a = poisson_arrivals(2000, 10.0, 1);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        let mean_gap = a.last().unwrap() / 2000.0;
        assert!((mean_gap - 0.1).abs() < 0.02, "gap {mean_gap}");
    }

    #[test]
    fn prompts_nonempty_and_deterministic() {
        let a = bench_prompts(5, 3);
        let b = bench_prompts(5, 3);
        assert_eq!(a, b);
        assert!(a.iter().all(|p| !p.is_empty()));
    }

    #[test]
    fn arrivals_monotone_at_every_rate() {
        for rate in [0.5, 4.0, 100.0] {
            let a = poisson_arrivals(500, rate, 17);
            assert!(a.iter().all(|&t| t > 0.0), "rate {rate}");
            assert!(
                a.windows(2).all(|w| w[0] <= w[1]),
                "non-monotone schedule at rate {rate}"
            );
        }
    }

    #[test]
    fn empirical_rate_tracks_requested_rate() {
        for rate in [2.0, 25.0, 80.0] {
            let n = 5000;
            let a = poisson_arrivals(n, rate, 23);
            let empirical = n as f64 / a.last().unwrap();
            let rel = (empirical - rate).abs() / rate;
            // exponential inter-arrivals: mean gap estimate has stderr
            // 1/sqrt(n) ≈ 1.4%; 6% is a > 4-sigma bound
            assert!(rel < 0.06, "rate {rate}: empirical {empirical} (rel {rel})");
        }
    }

    #[test]
    fn arrivals_differ_across_seeds() {
        let a = poisson_arrivals(50, 10.0, 1);
        let b = poisson_arrivals(50, 10.0, 2);
        assert_ne!(a, b);
        // same seed reproduces exactly
        assert_eq!(a, poisson_arrivals(50, 10.0, 1));
    }

    #[test]
    fn prompts_differ_across_seeds() {
        let a = bench_prompts(20, 3);
        let b = bench_prompts(20, 4);
        assert_ne!(a, b);
    }
}
