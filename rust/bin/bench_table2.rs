//! Table 2 (+ appendix Tables 6–10 with --full): SVD-LLM vs AA-SVD across
//! the model family at ratios {0.8, 0.6}.
//!
//! Paper: LLaMA-2-7B/13B, LLaMA-3-1B/8B, Qwen-2.5-7B. Here: the config
//! family small/base/compact/deep/alt playing those roles (DESIGN.md §3).

use aasvd::compress::{BlockOutcome, Method};
use aasvd::data::Domain;
use aasvd::eval::{display_ppl, Table};
use aasvd::experiments::{eval_compressed_method_observed, eval_dense, setup, Knobs};
use aasvd::util::cli::Args;
use anyhow::Result;

const FAMILY: [(&str, &str); 5] = [
    ("small", "LLaMA-2-7B"),
    ("base", "LLaMA-2-13B"),
    ("compact", "LLaMA-3-1B"),
    ("deep", "LLaMA-3-8B"),
    ("alt", "Qwen-2.5-7B"),
];

/// Paper Table 2 (wiki ppl, avg acc) for (model role, ratio, method).
const PAPER: [(&str, f64, &str, f64, f64); 20] = [
    ("LLaMA-2-7B", 0.8, "svd_llm", 8.41, 0.43),
    ("LLaMA-2-7B", 0.8, "aa_svd", 6.84, 0.50),
    ("LLaMA-2-7B", 0.6, "svd_llm", 16.47, 0.35),
    ("LLaMA-2-7B", 0.6, "aa_svd", 8.55, 0.44),
    ("LLaMA-2-13B", 0.8, "svd_llm", 6.65, 0.48),
    ("LLaMA-2-13B", 0.8, "aa_svd", 5.95, 0.53),
    ("LLaMA-2-13B", 0.6, "svd_llm", 10.79, 0.38),
    ("LLaMA-2-13B", 0.6, "aa_svd", 7.44, 0.46),
    ("LLaMA-3-1B", 0.8, "svd_llm", 45.62, 0.32),
    ("LLaMA-3-1B", 0.8, "aa_svd", 15.12, 0.39),
    ("LLaMA-3-1B", 0.6, "svd_llm", 402.76, 0.30),
    ("LLaMA-3-1B", 0.6, "aa_svd", 23.74, 0.35),
    ("LLaMA-3-8B", 0.8, "svd_llm", 14.16, 0.44),
    ("LLaMA-3-8B", 0.8, "aa_svd", 9.58, 0.50),
    ("LLaMA-3-8B", 0.6, "svd_llm", 76.31, 0.32),
    ("LLaMA-3-8B", 0.6, "aa_svd", 13.66, 0.41),
    ("Qwen-2.5-7B", 0.8, "svd_llm", 10.69, 0.47),
    ("Qwen-2.5-7B", 0.8, "aa_svd", 8.53, 0.53),
    ("Qwen-2.5-7B", 0.6, "svd_llm", 28.67, 0.33),
    ("Qwen-2.5-7B", 0.6, "aa_svd", 11.00, 0.44),
];

fn main() -> Result<()> {
    let args = Args::parse_env("Table 2: model-family generalization");
    let mut knobs = Knobs::parse(&args, "small");
    let full = args.flag("full", "emit per-task appendix breakdowns (Tables 6-10)");
    let models = args.list("models", "small,base,compact,deep,alt", "family configs");
    knobs.ratios = args
        .list("ratios", "0.8,0.6", "ratios")
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    args.finish_or_help();

    let mut table = Table::new(
        "Table 2 — model family (paper roles in brackets)",
        &["model", "ratio", "method", "ppl", "acc", "paper:ppl", "paper:acc"],
    );

    for cfg_name in &models {
        let role = FAMILY
            .iter()
            .find(|(c, _)| c == cfg_name)
            .map(|(_, r)| *r)
            .unwrap_or("-");
        knobs.config = cfg_name.clone();
        let ctx = setup(&knobs)?;
        let dense = eval_dense(&ctx)?;
        table.row(vec![
            format!("{cfg_name} [{role}]"),
            "1.0".into(),
            "dense".into(),
            display_ppl(dense.ppl_of(Domain::Wiki)),
            format!("{:.3}", dense.avg_acc),
            "-".into(),
            "-".into(),
        ]);
        for &ratio in &knobs.ratios {
            for method in [Method::svd_llm(), Method::aa_svd(knobs.refine())] {
                let (ev, _) = eval_compressed_method_observed(
                    &ctx,
                    &method,
                    ratio,
                    &mut |o: &BlockOutcome| {
                        eprintln!(
                            "[table2] {cfg_name} {} @ {ratio}: block {}/{} ({:.1}s)",
                            method.name,
                            o.index + 1,
                            o.total,
                            o.secs
                        );
                    },
                )?;
                let paper = PAPER
                    .iter()
                    .find(|(r, rr, m, ..)| *r == role && *rr == ratio && *m == method.name)
                    .map(|&(_, _, _, p, a)| (display_ppl(p), format!("{a:.2}")))
                    .unwrap_or(("-".into(), "-".into()));
                table.row(vec![
                    format!("{cfg_name} [{role}]"),
                    format!("{ratio}"),
                    ev.method.clone(),
                    display_ppl(ev.ppl_of(Domain::Wiki)),
                    format!("{:.3}", ev.avg_acc),
                    paper.0,
                    paper.1,
                ]);
                if full {
                    // appendix breakdown: per-task accuracy row
                    let mut t = Table::new(
                        &format!("Appendix — {cfg_name} {} @{ratio}", ev.method),
                        &["task", "acc"],
                    );
                    for (task, acc) in &ev.task_acc {
                        t.row(vec![task.name().into(), format!("{acc:.3}")]);
                    }
                    t.emit(&format!("table2_full_{cfg_name}_{}_{ratio}", ev.method))?;
                }
            }
        }
    }
    table.emit("table2")?;
    Ok(())
}
