//! AOT artifact manifest: what `make artifacts` produced and how to call it.
//!
//! Parsed from artifacts/manifest.json (written by python/compile/aot.py).
//! The manifest is the single source of truth for artifact signatures and
//! flat-tensor layouts; the Rust builtin configs are validated against it.

use crate::model::config::Config;
use crate::model::params::Layout;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            _ => bail!("unsupported dtype '{s}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub config: Config,
    pub cov_chunk: usize,
    pub param_layout: Layout,
    pub block_param_layout: Layout,
    pub factor_layout: Layout,
    pub mask_layout: Layout,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigEntry>,
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .context("expected spec array")?
        .iter()
        .map(|s| {
            Ok(TensorSpec {
                shape: s
                    .req("shape")
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_usize().unwrap())
                    .collect(),
                dtype: DType::parse(s.req("dtype").as_str().context("dtype")?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut configs = BTreeMap::new();
        for (name, entry) in j.req("configs").as_obj().context("configs")? {
            let dims = entry.req("dims");
            let config = Config::from_manifest(name, dims);
            // consistency: builtin config (if present) must agree
            if let Some(builtin) = Config::builtin(name) {
                if builtin != config {
                    bail!(
                        "config '{name}' in manifest disagrees with builtin; \
                         re-run `make artifacts`"
                    );
                }
            }
            let mut artifacts = BTreeMap::new();
            for (aname, a) in entry.req("artifacts").as_obj().context("artifacts")? {
                artifacts.insert(
                    aname.clone(),
                    ArtifactSpec {
                        name: aname.clone(),
                        file: dir.join(a.req("file").as_str().context("file")?),
                        inputs: parse_specs(a.req("inputs"))?,
                        outputs: parse_specs(a.req("outputs"))?,
                    },
                );
            }
            configs.insert(
                name.clone(),
                ConfigEntry {
                    cov_chunk: dims.req("cov_chunk").as_usize().unwrap(),
                    param_layout: Layout::from_manifest(entry.req("param_layout")),
                    // python emits block tensors as "blocks.0.<name>"; the
                    // rust block store uses bare names
                    block_param_layout: {
                        let lay = Layout::from_manifest(entry.req("block_param_layout"));
                        Layout::new(
                            lay.entries
                                .into_iter()
                                .map(|e| {
                                    let bare = e
                                        .name
                                        .strip_prefix("blocks.0.")
                                        .unwrap_or(&e.name)
                                        .to_string();
                                    (bare, e.shape)
                                })
                                .collect(),
                        )
                    },
                    factor_layout: Layout::from_manifest(entry.req("factor_layout")),
                    mask_layout: Layout::from_manifest(entry.req("mask_layout")),
                    config,
                    artifacts,
                },
            );
        }
        Ok(Manifest { dir, configs })
    }

    pub fn entry(&self, config: &str) -> Result<&ConfigEntry> {
        self.configs.get(config).with_context(|| {
            format!(
                "config '{config}' not in manifest (have: {:?}) — \
                 run `make artifacts CONFIGS={config}`",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }
}

impl ConfigEntry {
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!("artifact '{name}' missing for config '{}'", self.config.name)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests here run against the real artifacts when present (CI runs
    /// `make artifacts` first); otherwise they validate error paths.
    fn manifest() -> Option<Manifest> {
        Manifest::load("artifacts").ok()
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load("/nonexistent-path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(m) = manifest() else { return };
        let e = m.entry("tiny").unwrap();
        assert_eq!(e.config.d_model, 64);
        let a = e.artifact("model_fwd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(
            a.outputs[0].shape,
            vec![e.config.batch, e.config.seq, e.config.vocab]
        );
        assert!(a.file.exists());
    }

    #[test]
    fn layouts_match_rust_side() {
        let Some(m) = manifest() else { return };
        let e = m.entry("tiny").unwrap();
        assert_eq!(
            e.param_layout,
            crate::model::params::param_layout(&e.config)
        );
        assert_eq!(
            e.factor_layout,
            crate::model::params::factor_layout(&e.config)
        );
        assert_eq!(e.mask_layout, crate::model::params::mask_layout(&e.config));
        assert_eq!(
            e.block_param_layout,
            crate::model::params::block_param_layout(&e.config)
        );
    }

    #[test]
    fn unknown_names_error() {
        let Some(m) = manifest() else { return };
        assert!(m.entry("no-such-config").is_err());
        assert!(m.entry("tiny").unwrap().artifact("no-such").is_err());
    }
}
