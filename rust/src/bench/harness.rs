//! Micro/meso benchmark harness: warmup + timed iterations + robust stats,
//! used by every `cargo bench` target (`[[bench]] harness = false`).

use crate::util::stats::{mean, percentile, std_dev};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
    /// optional work metric (flops, tokens, bytes) per iteration
    pub work_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.mean_secs)
    }

    pub fn report(&self) -> String {
        let tp = match (self.work_per_iter, self.throughput()) {
            (Some(_), Some(tp)) if tp >= 1e9 => format!("  {:.2} G/s", tp / 1e9),
            (Some(_), Some(tp)) if tp >= 1e6 => format!("  {:.2} M/s", tp / 1e6),
            (Some(_), Some(tp)) => format!("  {tp:.1} /s"),
            _ => String::new(),
        };
        format!(
            "{:<44} {:>10.3} ms ±{:>7.3}  p95 {:>9.3} ms  ({} iters){}",
            self.name,
            self.mean_secs * 1e3,
            self.std_secs * 1e3,
            self.p95_secs * 1e3,
            self.iters,
            tp
        )
    }
}

pub struct Bench {
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_secs: f64,
    pub warmup: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            min_iters: 5,
            max_iters: 200,
            target_secs: 2.0,
            warmup: 2,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Bench {
        let mut b = Bench::default();
        // aasvd-lint: allow(env-var): bench wall-time budget knob; affects how long we measure, never what the kernels compute
        if let Ok(t) = std::env::var("BENCH_TARGET_SECS") {
            if let Ok(t) = t.parse() {
                b.target_secs = t;
            }
        }
        b
    }

    /// Time `f`, auto-scaling iteration count to `target_secs`.
    pub fn run<F: FnMut()>(&mut self, name: &str, work_per_iter: Option<f64>, mut f: F) {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.target_secs
                && times.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: times.len(),
            mean_secs: mean(&times),
            std_secs: std_dev(&times),
            p50_secs: percentile(&times, 50.0),
            p95_secs: percentile(&times, 95.0),
            work_per_iter,
        };
        println!("{}", result.report());
        self.results.push(result);
    }

    /// Dump all results to results/bench_<id>.json.
    pub fn save(&self, id: &str) {
        use crate::util::json::Json;
        let rows: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj()
                    .set("name", r.name.as_str())
                    .set("mean_ms", r.mean_secs * 1e3)
                    .set("p95_ms", r.p95_secs * 1e3)
                    .set("iters", r.iters)
                    // the raw work metric too, not just the rate: CI
                    // gates that compare *work* across rows (e.g. prefill
                    // tokens with the prefix cache on vs off) must not
                    // depend on wall time
                    .set("work_per_iter", r.work_per_iter.unwrap_or(0.0))
                    .set(
                        "throughput",
                        r.throughput().unwrap_or(0.0),
                    )
            })
            .collect();
        let _ = crate::util::io::write_text(
            format!("results/bench_{id}.json"),
            &Json::obj()
                .set("bench", id)
                // run context, so archived artifacts (CI bench-smoke's
                // BENCH_pr.json) are comparable across machines/modes
                .set("target_secs", self.target_secs)
                .set(
                    "host_threads",
                    std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1),
                )
                // process-lifetime high-water mark (VmHWM), so memory-bound
                // lanes can gate on it alongside the timing rows
                .set("peak_rss_mb", crate::util::mem::peak_rss_mb())
                .set("results", Json::Arr(rows))
                .to_string_pretty(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench {
            min_iters: 3,
            max_iters: 5,
            target_secs: 0.01,
            warmup: 1,
            results: Vec::new(),
        };
        let mut count = 0u64;
        b.run("noop", Some(1.0), || count += 1);
        assert!(count >= 4); // warmup + iters
        let r = &b.results[0];
        assert!(r.iters >= 3 && r.iters <= 5);
        assert!(r.report().contains("noop"));
        assert!(r.throughput().unwrap() > 0.0);
    }
}
