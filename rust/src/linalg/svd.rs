//! Truncated SVD via the Gram-matrix route.
//!
//! Step 5 of Algorithm 1 needs SVD_k(M) for M = W A B^T L_B^{-T}. In this
//! codebase M is [m × n] with min(m, n) = d_model (attention projections are
//! square and MLP projections are rectangular with the small side d_model),
//! so eigendecomposing the smaller Gram matrix (m×m or n×n) is both the
//! cheapest and a numerically adequate route for the *leading* singular
//! triples — the only ones truncation keeps.
//!
//! The `_with` variants take an explicit [`Pool`]: the Gram products, the
//! tridiagonal eigensolve (`linalg::eigh` / `linalg::tridiag`) and the
//! back-projection all run row-banded, bitwise identically for any worker
//! count. The plain names resolve [`Pool::auto`].

use super::eigh::{eigh_values_with, eigh_with};
use super::matrix::Matrix;
use crate::util::pool::Pool;

/// Result of a (possibly truncated) SVD: M ≈ U diag(s) V^T.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Matrix,      // [m × k]
    pub s: Vec<f64>,    // length k, descending, >= 0
    pub v: Matrix,      // [n × k]
}

/// Full SVD (k = min(m, n)).
pub fn svd(m: &Matrix) -> Svd {
    svd_k(m, m.rows.min(m.cols))
}

/// Truncated SVD keeping the top-k singular triples ([`Pool::auto`]).
pub fn svd_k(mat: &Matrix, k: usize) -> Svd {
    svd_k_with(mat, k, &Pool::auto())
}

/// Truncated SVD on an explicit worker pool: the Gram product, the
/// tridiagonal eigensolve and the back-projection all run row-banded on
/// `pool`, bitwise identically for any worker count.
pub fn svd_k_with(mat: &Matrix, k: usize, pool: &Pool) -> Svd {
    let (m, n) = (mat.rows, mat.cols);
    let k = k.min(m.min(n));
    if m <= n {
        // Gram = M M^T = U Λ U^T;  σ = sqrt(λ);  V = M^T U Σ^{-1}
        let gram = mat.matmul_bt_with(mat, pool); // [m × m]
        let (vals, q) = eigh_with(&gram, pool);
        let mut s = Vec::with_capacity(k);
        let mut u = Matrix::zeros(m, k);
        for j in 0..k {
            let sig = vals[j].max(0.0).sqrt();
            s.push(sig);
            for i in 0..m {
                u.set(i, j, q.get(i, j));
            }
        }
        // V = M^T U Σ^{-1}, columns with σ≈0 zeroed (they are truncated away
        // from any reconstruction anyway)
        let mtu = mat.matmul_at_with(&u, pool); // [n × k]
        let mut v = Matrix::zeros(n, k);
        let smax = s.first().copied().unwrap_or(0.0).max(1e-300);
        for j in 0..k {
            if s[j] > 1e-12 * smax {
                let inv = 1.0 / s[j];
                for i in 0..n {
                    v.set(i, j, mtu.get(i, j) * inv);
                }
            } else {
                // numerically zero direction: keep σ=0, zero column
            }
        }
        Svd { u, s, v }
    } else {
        // work on the transpose and swap factors
        let t = mat.transpose_with(pool);
        let r = svd_k_with(&t, k, pool);
        Svd {
            u: r.v,
            s: r.s,
            v: r.u,
        }
    }
}

/// Rank-k reconstruction U diag(s) V^T ([`Pool::auto`]).
pub fn reconstruct(svd: &Svd) -> Matrix {
    reconstruct_with(svd, &Pool::auto())
}

/// Rank-k reconstruction through the banded parallel kernels:
/// (U diag(s)) Vᵀ as a single `matmul_bt` over the factor columns instead
/// of a naive triple loop, so truncation-error probes at d_model-class
/// sizes pay the tiled, pool-scalable cost.
pub fn reconstruct_with(svd: &Svd, pool: &Pool) -> Matrix {
    let (m, k) = (svd.u.rows, svd.s.len());
    let mut us = Matrix::zeros(m, k);
    for i in 0..m {
        let row = us.row_mut(i);
        let urow = svd.u.row(i);
        for j in 0..k {
            row[j] = urow[j] * svd.s[j];
        }
    }
    us.matmul_bt_with(&svd.v, pool)
}

/// Squared Frobenius mass of the discarded tail: Σ_{i>k} σ_i².
/// (The Eckart–Young optimum value of ‖M − SVD_k(M)‖²_F.)
///
/// Computed as ‖M‖²_F − Σ_{i≤k} λ_i(Gram) through the eigenvalues-only
/// path — no U/V factors are ever formed, so the truncation-order probes
/// pay the cheap O(n²) QL core instead of a full SVD.
pub fn tail_energy(mat: &Matrix, k: usize) -> f64 {
    tail_energy_with(mat, k, &Pool::auto())
}

/// [`tail_energy`] on an explicit worker pool.
pub fn tail_energy_with(mat: &Matrix, k: usize, pool: &Pool) -> f64 {
    let (m, n) = (mat.rows, mat.cols);
    let k = k.min(m.min(n));
    // Gram of the smaller side; λ_i(Gram) = σ_i²
    let gram = if m <= n {
        mat.matmul_bt_with(mat, pool) // [m × m]
    } else {
        mat.matmul_at_with(mat, pool) // [n × n]
    };
    let vals = eigh_values_with(&gram, pool);
    let total: f64 = mat.data.iter().map(|x| x * x).sum();
    let kept: f64 = vals.iter().take(k).map(|&l| l.max(0.0)).sum();
    // clamp: cancellation can leave a tiny negative residual at full rank
    (total - kept).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn identity_svd() {
        let r = svd(&Matrix::identity(4));
        assert_close(&r.s, &[1.0; 4], 1e-10);
    }

    #[test]
    fn hand_rank1() {
        // M = [1,2;2,4] = rank 1, σ = 5
        let m = Matrix::from_vec(2, 2, vec![1., 2., 2., 4.]);
        let r = svd(&m);
        assert!((r.s[0] - 5.0).abs() < 1e-9);
        assert!(r.s[1].abs() < 1e-6);
        let rec = reconstruct(&Svd {
            u: r.u.cols_range(0, 1),
            s: vec![r.s[0]],
            v: r.v.cols_range(0, 1),
        });
        assert_close(&rec.data, &m.data, 1e-8);
    }

    #[test]
    fn full_reconstruction_square_and_rect() {
        let mut rng = Rng::new(11);
        for (m, n) in [(6, 6), (12, 5), (5, 12), (30, 8)] {
            let a = Matrix::random(m, n, &mut rng, 1.0);
            let r = svd(&a);
            let rec = reconstruct(&r);
            let rel = rec.sub(&a).frob_norm() / a.frob_norm();
            assert!(rel < 1e-7, "({m},{n}) rel={rel}");
        }
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        let mut rng = Rng::new(12);
        let a = Matrix::random(20, 9, &mut rng, 2.0);
        let r = svd(&a);
        for w in r.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
        assert!(r.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn factors_orthonormal() {
        let mut rng = Rng::new(13);
        let a = Matrix::random(10, 14, &mut rng, 1.0);
        let r = svd(&a);
        let utu = r.u.matmul_at(&r.u);
        let vtv = r.v.matmul_at(&r.v);
        let k = r.s.len();
        assert_close(&utu.data, &Matrix::identity(k).data, 1e-7);
        assert_close(&vtv.data, &Matrix::identity(k).data, 1e-7);
    }

    #[test]
    fn eckart_young_optimality() {
        // truncation error equals tail energy, and beats random rank-k
        let mut rng = Rng::new(14);
        let a = Matrix::random(12, 9, &mut rng, 1.0);
        let k = 3;
        let trunc = reconstruct(&svd_k(&a, k));
        let err = a.sub(&trunc).frob_norm().powi(2);
        let tail = tail_energy(&a, k);
        assert!((err - tail).abs() < 1e-6 * tail.max(1.0), "err={err} tail={tail}");
        // any random rank-k approx is worse
        for seed in 0..5 {
            let mut r2 = Rng::new(100 + seed);
            let u = Matrix::random(12, k, &mut r2, 1.0);
            let v = Matrix::random(9, k, &mut r2, 1.0);
            let approx = u.matmul(&v.transpose());
            let e2 = a.sub(&approx).frob_norm().powi(2);
            assert!(e2 >= err - 1e-9);
        }
    }

    #[test]
    fn truncated_matches_full_prefix() {
        let mut rng = Rng::new(15);
        let a = Matrix::random(8, 11, &mut rng, 1.0);
        let full = svd(&a);
        let part = svd_k(&a, 4);
        assert_close(&part.s, &full.s[..4], 1e-9);
    }

    #[test]
    fn known_singular_values() {
        // diag-like rectangular matrix
        let mut a = Matrix::zeros(3, 5);
        a.set(0, 0, 3.0);
        a.set(1, 1, -2.0); // sign goes into U/V
        a.set(2, 2, 1.0);
        let r = svd(&a);
        assert_close(&r.s, &[3.0, 2.0, 1.0], 1e-9);
    }

    #[test]
    fn tail_energy_matches_full_svd_tail() {
        // the eigenvalues-only formula ‖M‖²_F − Σ_{i≤k} λ_i must agree
        // with the discarded-σ² sum from a full factorization
        let mut rng = Rng::new(17);
        for (m, n) in [(10usize, 7usize), (7, 10), (9, 9)] {
            let a = Matrix::random(m, n, &mut rng, 1.0);
            let full = svd(&a);
            for k in 0..=m.min(n) {
                let want: f64 = full.s.iter().skip(k).map(|x| x * x).sum();
                let got = tail_energy(&a, k);
                assert!(
                    (got - want).abs() < 1e-8 * want.max(1.0),
                    "({m},{n}) k={k}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn tail_energy_of_rank_deficient_tail_is_zero() {
        // rank-2 matrix: everything past k=2 is numerically zero
        let mut rng = Rng::new(18);
        let u = Matrix::random(9, 2, &mut rng, 1.0);
        let v = Matrix::random(6, 2, &mut rng, 1.0);
        let a = u.matmul_bt(&v);
        let t = tail_energy(&a, 2);
        assert!(t >= 0.0 && t < 1e-9 * a.frob_norm().powi(2), "t={t}");
    }

    #[test]
    fn reconstruct_matches_naive_triple_loop() {
        let mut rng = Rng::new(19);
        let r = svd_k(&Matrix::random(12, 8, &mut rng, 1.0), 5);
        let got = reconstruct(&r);
        let (m, n, k) = (r.u.rows, r.v.rows, r.s.len());
        let mut want = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += r.u.get(i, p) * r.s[p] * r.v.get(j, p);
                }
                want.set(i, j, acc);
            }
        }
        assert_close(&got.data, &want.data, 1e-12);
    }

    #[test]
    fn transpose_swaps_factors() {
        let mut rng = Rng::new(16);
        let a = Matrix::random(7, 13, &mut rng, 1.0);
        let ra = svd(&a);
        let rt = svd(&a.transpose());
        assert_close(&ra.s, &rt.s, 1e-8);
    }
}
