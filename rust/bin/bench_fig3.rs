//! Figure 3: impact of calibration-set size.
//!
//! Paper: WikiText2/C4 perplexity + average zero-shot accuracy vs number of
//! calibration samples {~8..512} at ratios 0.8/0.6: PPL saturates by ~64
//! samples, accuracy keeps improving past 64.

use aasvd::compress::{BlockOutcome, Method};
use aasvd::data::Domain;
use aasvd::eval::{display_ppl, Table};
use aasvd::experiments::{eval_compressed_method_observed, setup, Knobs};
use aasvd::util::cli::Args;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env("Figure 3: calibration-size sweep");
    let mut knobs = Knobs::parse(&args, "small");
    let sizes: Vec<usize> = args
        .list("sizes", "8,16,32,64,128,256", "calibration sizes (sequences)")
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    knobs.ratios = args
        .list("ratios", "0.8,0.6", "ratios")
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    args.finish_or_help();

    let mut table = Table::new(
        "Fig 3 — calibration-size sweep (AA-SVD)",
        &["ratio", "calib_seqs", "wiki_ppl", "c4_ppl", "acc"],
    );
    for &n in &sizes {
        knobs.calib_seqs = n;
        let ctx = setup(&knobs)?;
        for &ratio in &knobs.ratios {
            let (ev, _) = eval_compressed_method_observed(
                &ctx,
                &Method::aa_svd(knobs.refine()),
                ratio,
                &mut |o: &BlockOutcome| {
                    eprintln!(
                        "[fig3] calib {n} @ {ratio}: block {}/{} ({:.1}s)",
                        o.index + 1,
                        o.total,
                        o.secs
                    );
                },
            )?;
            table.row(vec![
                format!("{ratio}"),
                format!("{n}"),
                display_ppl(ev.ppl_of(Domain::Wiki)),
                display_ppl(ev.ppl_of(Domain::C4)),
                format!("{:.3}", ev.avg_acc),
            ]);
        }
    }
    table.emit("fig3")?;
    Ok(())
}
