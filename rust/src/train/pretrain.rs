//! Pretraining driver: produces the "pretrained model" every compression
//! experiment starts from, by driving the fused AdamW `train_step` artifact
//! over the synthetic corpus. Rust owns the loop, batching, LR schedule,
//! checkpointing and the loss-curve log; Python never runs.

use crate::data::{Batcher, Corpus, Domain};
use crate::model::init::init_params;
use crate::model::{Config, FlatStore};
use crate::refine::CosineSchedule;
use crate::runtime::{Engine, Value};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::Result;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct PretrainOptions {
    pub steps: usize,
    pub base_lr: f64,
    pub warmup: usize,
    pub seed: u64,
    pub corpus_bytes: usize,
    pub log_every: usize,
}

impl Default for PretrainOptions {
    fn default() -> Self {
        PretrainOptions {
            steps: 300,
            base_lr: 3e-3,
            warmup: 30,
            seed: 42,
            corpus_bytes: 1_500_000,
            log_every: 20,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct PretrainResult {
    pub losses: Vec<(usize, f64)>, // (step, loss)
    pub final_loss: f64,
    pub secs: f64,
    pub tokens_seen: usize,
}

/// Train a fresh model on the wiki-domain corpus; returns trained params.
pub fn pretrain(
    engine: &Engine,
    cfg: &Config,
    opts: &PretrainOptions,
) -> Result<(FlatStore, PretrainResult)> {
    let corpus = Corpus::generate(Domain::Wiki, opts.corpus_bytes, opts.seed);
    // mix in some breadth so ptb/c4 eval is shifted-but-not-alien
    let c4 = Corpus::generate(Domain::C4, opts.corpus_bytes / 4, opts.seed + 1);
    let mut stream = corpus.train.clone();
    stream.extend_from_slice(&c4.train);

    let mut params = init_params(cfg, &mut Rng::new(opts.seed));
    let n = params.data.len();
    let mut m = vec![0f32; n];
    let mut v = vec![0f32; n];
    let sched = CosineSchedule::new(opts.base_lr, opts.warmup, opts.steps);
    let batcher = Batcher::new(cfg.train_batch, cfg.seq);
    let mut rng = Rng::new(opts.seed ^ 0xbeef);

    let mut result = PretrainResult::default();
    let t0 = Instant::now();
    for step in 0..opts.steps {
        let batch = &batcher.random(&stream, 1, &mut rng)[0];
        let out = engine.run(
            &cfg.name,
            "train_step",
            &[
                Value::F32(&params.data),
                Value::F32(&m),
                Value::F32(&v),
                Value::ScalarI32(step as i32),
                Value::ScalarF32(sched.lr(step) as f32),
                Value::I32(&batch.tokens),
                Value::I32(&batch.targets),
            ],
        )?;
        params.data.copy_from_slice(&out[0].f32);
        m.copy_from_slice(&out[1].f32);
        v.copy_from_slice(&out[2].f32);
        let loss = out[3].f32[0] as f64;
        result.tokens_seen += batch.tokens.len();
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            result.losses.push((step, loss));
            crate::log_info!(
                "pretrain[{}] step {step}/{} loss {loss:.4} lr {:.2e}",
                cfg.name,
                opts.steps,
                sched.lr(step)
            );
        }
        result.final_loss = loss;
    }
    result.secs = t0.elapsed().as_secs_f64();
    Ok((params, result))
}

/// Save the loss curve next to the checkpoint.
pub fn save_loss_curve(result: &PretrainResult, path: &str) -> Result<()> {
    let pts: Vec<Json> = result
        .losses
        .iter()
        .map(|&(s, l)| Json::obj().set("step", s).set("loss", l))
        .collect();
    let j = Json::obj()
        .set("final_loss", result.final_loss)
        .set("tokens", result.tokens_seen)
        .set("secs", result.secs)
        .set("curve", Json::Arr(pts));
    crate::util::io::write_text(path, &j.to_string_pretty())
}

/// Checkpoint path convention.
pub fn checkpoint_path(cfg: &Config) -> String {
    format!("checkpoints/{}.aat", cfg.name)
}

/// Load a checkpoint, or pretrain + save if absent.
pub fn load_or_pretrain(
    engine: &Engine,
    cfg: &Config,
    opts: &PretrainOptions,
) -> Result<FlatStore> {
    let path = checkpoint_path(cfg);
    if let Ok(store) =
        FlatStore::load(crate::model::params::param_layout(cfg), &path)
    {
        crate::log_info!("loaded checkpoint {path}");
        return Ok(store);
    }
    crate::log_info!("no checkpoint at {path}; pretraining {} steps", opts.steps);
    let (params, result) = pretrain(engine, cfg, opts)?;
    std::fs::create_dir_all("checkpoints")?;
    params.save(&path)?;
    save_loss_curve(&result, &format!("checkpoints/{}_loss.json", cfg.name))?;
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let o = PretrainOptions::default();
        assert!(o.steps > 0 && o.base_lr > 0.0 && o.warmup < o.steps);
    }

    #[test]
    fn short_pretrain_reduces_loss() {
        let Ok(engine) = Engine::new("artifacts") else { return };
        if engine.entry("tiny").is_err() {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let opts = PretrainOptions {
            steps: 30,
            corpus_bytes: 60_000,
            log_every: 10,
            ..Default::default()
        };
        let (_, result) = pretrain(&engine, &cfg, &opts).unwrap();
        let first = result.losses.first().unwrap().1;
        assert!(
            result.final_loss < first,
            "loss {first} -> {}",
            result.final_loss
        );
        // byte-level uniform is ln(256)=5.55; must at least beat that
        assert!(result.final_loss < 5.55);
    }
}
