//! Householder tridiagonalization + implicit-shift QL for symmetric
//! matrices — the classic dense symmetric eigensolver pipeline.
//!
//! The cyclic Jacobi solver this replaces on the hot path runs up to 60
//! full O(n³) sweeps of column-strided rotations. This pipeline does the
//! O(n³) work once, in three cache-friendly stages:
//!
//!   1. [`householder_tridiag_with`] — n−2 Householder reflections reduce
//!      S to a symmetric tridiagonal T (diagonal `d`, subdiagonal `e`).
//!      The per-step matvec and symmetric rank-2 update run row-banded on
//!      the PR-2 [`Pool`]; the optional back-transformation accumulates
//!      Q = H₀·H₁·…·H_{n−3} the same way.
//!   2. [`ql_implicit_shift`] — implicit-shift QL iteration deflates T one
//!      eigenvalue at a time. This is the cheap O(n²) serial core; with
//!      `rots` provided it records every Givens rotation instead of
//!      applying it, so the O(n³) eigenvector update is deferred.
//!   3. [`apply_rotations_with`] — replays the recorded rotation sequence
//!      against the columns of Q, row-banded on the pool. Rows are
//!      independent and each row applies the identical sequence in order,
//!      so the result is bitwise identical for any worker count.
//!
//! **Determinism contract** (see `tests/parallel_determinism.rs`): every
//! parallel region here is either elementwise (rank-2 update, rotation
//! replay) or accumulates per output element in ascending index order
//! regardless of how the row bands are cut (matvec, vᵀQ row products), so
//! eigenpairs are bitwise identical at 1 and N threads. The QL core is
//! serial and shared by the values-only and full paths, which is why
//! `eigh_values` returns bitwise the same spectrum as `eigh`.

use super::matrix::{run_banded, Matrix};
use crate::util::pool::Pool;

/// Symmetric tridiagonal form of S: `S = Q T Qᵀ` with `T = tridiag(e, d, e)`.
/// `q` is `None` when the caller asked for eigenvalues only (the
/// back-transformation is roughly half the tridiagonalization cost).
pub struct Tridiagonal {
    /// diagonal of T, length n
    pub d: Vec<f64>,
    /// subdiagonal of T (`e[i] = T[i+1][i]`), length n−1 (empty for n ≤ 1)
    pub e: Vec<f64>,
    /// orthogonal back-transformation, if requested
    pub q: Option<Matrix>,
}

/// One recorded Givens rotation of the QL iteration: acts on columns
/// `(col, col+1)` of the eigenvector matrix.
#[derive(Clone, Copy, Debug)]
pub struct Rotation {
    pub col: usize,
    pub c: f64,
    pub s: f64,
}

/// The QL iteration failed to deflate an eigenvalue within the sweep
/// budget (pathological input, e.g. non-finite entries). Callers fall
/// back to the Jacobi oracle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NoConverge;

/// Householder reduction S → T (Golub & Van Loan §8.3). `s` must be
/// square and is treated as symmetric (only its lower triangle drives the
/// reflections after the initial symmetrize by the caller).
pub fn householder_tridiag_with(s: &Matrix, want_q: bool, pool: &Pool) -> Tridiagonal {
    assert_eq!(s.rows, s.cols, "tridiagonalization needs a square matrix");
    let n = s.rows;
    if n == 0 {
        return Tridiagonal {
            d: Vec::new(),
            e: Vec::new(),
            q: want_q.then(|| Matrix::zeros(0, 0)),
        };
    }
    let mut a = s.clone();
    let mut e = vec![0.0; n.saturating_sub(1)];
    // Householder vectors (length n−1−k at step k) and their β = 2/‖v‖²,
    // kept for the reverse-order Q accumulation below.
    let mut vs: Vec<Vec<f64>> = Vec::new();
    let mut betas: Vec<f64> = Vec::new();

    for k in 0..n.saturating_sub(2) {
        let m = n - k - 1; // active trailing dimension
        // x = A[k+1.., k]
        let mut v: Vec<f64> = (0..m).map(|i| a.get(k + 1 + i, k)).collect();
        let off: f64 = v[1..].iter().map(|x| x * x).sum();
        if off == 0.0 {
            // column already tridiagonal — identity reflection
            e[k] = v[0];
            vs.push(Vec::new());
            betas.push(0.0);
            continue;
        }
        let norm = (v[0] * v[0] + off).sqrt();
        let alpha = if v[0] >= 0.0 { -norm } else { norm };
        v[0] -= alpha;
        let vtv: f64 = v.iter().map(|x| x * x).sum();
        let beta = 2.0 / vtv;
        e[k] = alpha;

        // p = β · A[k+1.., k+1..] · v — row-banded, each element a single
        // ascending-order dot product (bitwise band-split invariant)
        let mut p = vec![0.0; m];
        {
            let a_ref = &a;
            let v_ref = &v;
            run_banded(pool, m, 1, 2 * m * m, &mut p, |first, band| {
                for (bi, pr) in band.iter_mut().enumerate() {
                    let row = &a_ref.row(k + 1 + first + bi)[k + 1..];
                    let mut acc = 0.0;
                    for (x, y) in row.iter().zip(v_ref) {
                        acc += x * y;
                    }
                    *pr = beta * acc;
                }
            });
        }
        // w = p − (β vᵀp / 2) v;  A ← A − v wᵀ − w vᵀ
        let vtp: f64 = v.iter().zip(&p).map(|(x, y)| x * y).sum();
        let kk = 0.5 * beta * vtp;
        let w: Vec<f64> = p.iter().zip(&v).map(|(pi, vi)| pi - kk * vi).collect();
        {
            let ncols = a.cols;
            let v_ref = &v;
            let w_ref = &w;
            let trail = &mut a.data[(k + 1) * ncols..];
            run_banded(pool, m, ncols, 4 * m * m, trail, |first, band| {
                for (bi, row) in band.chunks_exact_mut(ncols).enumerate() {
                    let (vi, wi) = (v_ref[first + bi], w_ref[first + bi]);
                    for j in 0..m {
                        row[k + 1 + j] -= vi * w_ref[j] + wi * v_ref[j];
                    }
                }
            });
        }
        // zero the reduced column (bookkeeping only; d/e carry the result)
        a.set(k + 1, k, alpha);
        a.set(k, k + 1, alpha);
        for i in k + 2..n {
            a.set(i, k, 0.0);
            a.set(k, i, 0.0);
        }
        vs.push(v);
        betas.push(beta);
    }
    if n >= 2 {
        e[n - 2] = a.get(n - 1, n - 2);
    }
    let d: Vec<f64> = (0..n).map(|i| a.get(i, i)).collect();

    let q = want_q.then(|| {
        // Q = H₀·…·H_{n−3}, built in reverse so step k only touches the
        // trailing (n−1−k)² block: Q ← Q − β v (vᵀ Q).
        let mut q = Matrix::identity(n);
        for k in (0..vs.len()).rev() {
            let v = &vs[k];
            let beta = betas[k];
            if v.is_empty() {
                continue;
            }
            let m = n - k - 1;
            // t = vᵀ Q[k+1.., k+1..] — banded over output columns; each
            // t_j accumulates ascending over rows (band-split invariant)
            let mut t = vec![0.0; m];
            {
                let q_ref = &q;
                run_banded(pool, m, 1, 2 * m * m, &mut t, |first, band| {
                    for (bi, tj) in band.iter_mut().enumerate() {
                        let j = k + 1 + first + bi;
                        let mut acc = 0.0;
                        for (r, vr) in v.iter().enumerate() {
                            acc += vr * q_ref.get(k + 1 + r, j);
                        }
                        *tj = acc;
                    }
                });
            }
            let ncols = q.cols;
            let t_ref = &t;
            let trail = &mut q.data[(k + 1) * ncols..];
            run_banded(pool, m, ncols, 2 * m * m, trail, |first, band| {
                for (bi, row) in band.chunks_exact_mut(ncols).enumerate() {
                    let bv = beta * v[first + bi];
                    for j in 0..m {
                        row[k + 1 + j] -= bv * t_ref[j];
                    }
                }
            });
        }
        q
    });

    Tridiagonal { d, e, q }
}

/// Implicit-shift QL iteration on the tridiagonal (d, e) — the standard
/// `tqli` recurrence. `e` has length n−1 on entry (`e[i] = T[i+1][i]`).
/// On success `d` holds the eigenvalues (unsorted). When `rots` is
/// provided every Givens rotation is recorded in application order
/// instead of being applied to an eigenvector matrix inline; replay them
/// with [`apply_rotations_with`]. Values-only callers pass `None` and
/// skip the O(n³) eigenvector work entirely.
pub fn ql_implicit_shift(
    d: &mut [f64],
    e: &mut [f64],
    mut rots: Option<&mut Vec<Rotation>>,
) -> Result<(), NoConverge> {
    let n = d.len();
    if n <= 1 {
        return Ok(());
    }
    assert_eq!(e.len(), n - 1, "subdiagonal length must be n-1");
    // working subdiagonal with a trailing sentinel zero (NR convention)
    let mut ew = vec![0.0; n];
    ew[..n - 1].copy_from_slice(e);

    const MAX_ITERS: usize = 50;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find the first negligible subdiagonal at or after l
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if ew[m].abs() + dd == dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > MAX_ITERS || !d[l].is_finite() || !ew[l].is_finite() {
                return Err(NoConverge);
            }
            // Wilkinson-style shift from the leading 2×2
            let mut g = (d[l + 1] - d[l]) / (2.0 * ew[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + ew[l] / (g + sign_r);
            let mut s = 1.0;
            let mut c = 1.0;
            let mut p = 0.0;
            let mut underflow = false;
            for i in (l..m).rev() {
                let f = s * ew[i];
                let b = c * ew[i];
                r = f.hypot(g);
                ew[i + 1] = r;
                if r == 0.0 {
                    // recover: annihilated off-diagonal mid-sweep
                    d[i + 1] -= p;
                    ew[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // eigenvector rotation on columns (i, i+1), deferred
                if let Some(out) = rots.as_deref_mut() {
                    out.push(Rotation { col: i, c, s });
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            ew[l] = g;
            ew[m] = 0.0;
        }
    }
    e.copy_from_slice(&ew[..n - 1]);
    Ok(())
}

/// Replay a recorded QL rotation sequence against the columns of `q`,
/// row-banded on the pool. Each row applies the identical sequence in
/// order and rows never interact, so the result is bitwise identical for
/// any worker count.
pub fn apply_rotations_with(q: &mut Matrix, rots: &[Rotation], pool: &Pool) {
    if rots.is_empty() || q.rows == 0 {
        return;
    }
    let n = q.cols;
    // 6 flops per rotation per row
    let work = 6usize.saturating_mul(rots.len()).saturating_mul(q.rows);
    let rows = q.rows;
    run_banded(pool, rows, n, work, &mut q.data, |_, band| {
        for row in band.chunks_exact_mut(n) {
            for rot in rots {
                let f = row[rot.col + 1];
                row[rot.col + 1] = rot.s * row[rot.col] + rot.c * f;
                row[rot.col] = rot.c * row[rot.col] - rot.s * f;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;
    use crate::util::rng::Rng;

    fn tridiag_dense(d: &[f64], e: &[f64]) -> Matrix {
        let n = d.len();
        let mut t = Matrix::zeros(n, n);
        for i in 0..n {
            t.set(i, i, d[i]);
        }
        for (i, &x) in e.iter().enumerate() {
            t.set(i + 1, i, x);
            t.set(i, i + 1, x);
        }
        t
    }

    #[test]
    fn householder_preserves_similarity() {
        let mut rng = Rng::new(41);
        for n in [1usize, 2, 3, 8, 23] {
            let s = Matrix::random_spd(n, &mut rng);
            let tri = householder_tridiag_with(&s, true, &Pool::exact(1));
            let q = tri.q.unwrap();
            // Q orthogonal
            let qtq = q.matmul_at(&q);
            assert_close(&qtq.data, &Matrix::identity(n).data, 1e-10);
            // Q T Qᵀ == S
            let t = tridiag_dense(&tri.d, &tri.e);
            let rec = q.matmul(&t).matmul_bt(&q);
            let rel = rec.sub(&s).frob_norm() / s.frob_norm().max(1e-300);
            assert!(rel < 1e-12, "n={n} rel={rel}");
        }
    }

    /// n = 384 keeps the early steps' matvec / vᵀQ work (2·(n−1)²) above
    /// the banding threshold (2^18) so the 4-thread run genuinely splits
    /// every parallel region — smaller sizes would compare two
    /// single-band executions and prove nothing.
    #[test]
    fn householder_band_split_bitwise_invariant() {
        let mut rng = Rng::new(42);
        let s = Matrix::random_spd(384, &mut rng);
        let t1 = householder_tridiag_with(&s, true, &Pool::exact(1));
        let t4 = householder_tridiag_with(&s, true, &Pool::exact(4));
        assert_eq!(t1.d, t4.d);
        assert_eq!(t1.e, t4.e);
        assert_eq!(t1.q.unwrap().data, t4.q.unwrap().data);
    }

    #[test]
    fn ql_solves_known_tridiagonal() {
        // T = tridiag(1, 2, 1) of size n has λ_k = 2 + 2 cos(kπ/(n+1))
        let n = 12;
        let mut d = vec![2.0; n];
        let mut e = vec![1.0; n - 1];
        ql_implicit_shift(&mut d, &mut e, None).unwrap();
        d.sort_by(|a, b| b.total_cmp(a));
        let want: Vec<f64> = (1..=n)
            .map(|k| 2.0 + 2.0 * (k as f64 * std::f64::consts::PI / (n as f64 + 1.0)).cos())
            .collect();
        assert_close(&d, &want, 1e-12);
    }

    #[test]
    fn ql_rejects_non_finite_input() {
        let mut d = vec![f64::NAN, 1.0, 2.0];
        let mut e = vec![1.0, 0.5];
        assert_eq!(ql_implicit_shift(&mut d, &mut e, None), Err(NoConverge));
    }

    #[test]
    fn recorded_rotations_reproduce_eigenvectors() {
        let mut rng = Rng::new(43);
        let n = 15;
        let s = Matrix::random_spd(n, &mut rng);
        let tri = householder_tridiag_with(&s, true, &Pool::exact(1));
        let mut d = tri.d.clone();
        let mut e = tri.e.clone();
        let mut rots = Vec::new();
        ql_implicit_shift(&mut d, &mut e, Some(&mut rots)).unwrap();
        let mut z = tri.q.unwrap();
        apply_rotations_with(&mut z, &rots, &Pool::exact(1));
        // S z_j == λ_j z_j for every column
        let sz = s.matmul(&z);
        for j in 0..n {
            for i in 0..n {
                let diff = (sz.get(i, j) - d[j] * z.get(i, j)).abs();
                assert!(diff < 1e-8, "col {j} row {i}: residual {diff}");
            }
        }
    }

    #[test]
    fn rotation_replay_band_split_bitwise_invariant() {
        let mut rng = Rng::new(44);
        let n = 64;
        let s = Matrix::random_spd(n, &mut rng);
        let tri = householder_tridiag_with(&s, true, &Pool::exact(1));
        let mut d = tri.d.clone();
        let mut e = tri.e.clone();
        let mut rots = Vec::new();
        ql_implicit_shift(&mut d, &mut e, Some(&mut rots)).unwrap();
        let base = tri.q.unwrap();
        let mut z1 = base.clone();
        let mut z4 = base.clone();
        apply_rotations_with(&mut z1, &rots, &Pool::exact(1));
        apply_rotations_with(&mut z4, &rots, &Pool::exact(4));
        assert_eq!(z1.data, z4.data);
    }
}
