// aasvd-lint: path=src/linalg/fixture.rs
// aasvd-lint: allow-file(hash-iter): fixture justification — keys are sorted before every iteration in this imaginary module

use std::collections::HashMap;

pub fn cov_by_name() -> HashMap<String, f64> {
    HashMap::new()
}
