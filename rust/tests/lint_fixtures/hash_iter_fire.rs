// aasvd-lint: path=src/linalg/fixture.rs

use std::collections::HashMap;

pub fn cov_by_name() -> HashMap<String, f64> {
    HashMap::new()
}
