//! Binary tensor archive: the on-disk format for model weights, optimizer
//! state and cached activations ("`.aat`" — AA-SVD tensors).
//!
//! Layout (little-endian):
//!   magic  b"AAT1"
//!   u32    n_tensors
//!   per tensor:
//!     u32        name_len, name bytes (utf-8)
//!     u32        n_dims,  u64 dims[n_dims]
//!     u64        data_len (f32 count), f32 data[data_len]

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let n = dims.iter().product();
        Tensor {
            dims,
            data: vec![0.0; n],
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TensorArchive {
    pub tensors: BTreeMap<String, Tensor>,
}

impl TensorArchive {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    /// Serialize to the on-disk byte layout — the exact bytes [`save`]
    /// writes (tensors in name order).
    ///
    /// [`save`]: TensorArchive::save
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"AAT1");
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            tensor_bytes_into(&mut buf, name, t);
        }
        buf
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        write_bytes_atomic(path, &self.to_bytes())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TensorArchive> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?
            .read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }

    /// Decode the [`to_bytes`] layout (the checkpoint protocol hashes
    /// file bytes before decoding, so it reads then parses).
    ///
    /// [`to_bytes`]: TensorArchive::to_bytes
    pub fn from_bytes(buf: &[u8]) -> Result<TensorArchive> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated tensor archive");
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != b"AAT1" {
            bail!("bad magic: not a tensor archive");
        }
        let n_tensors = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut arch = TensorArchive::new();
        for _ in 0..n_tensors {
            let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
            let n_dims = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let mut dims = Vec::with_capacity(n_dims);
            for _ in 0..n_dims {
                dims.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize);
            }
            let len = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
            let bytes = take(&mut pos, len * 4)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            if dims.iter().product::<usize>() != data.len() {
                bail!("tensor '{name}' dims/data mismatch");
            }
            arch.tensors.insert(name, Tensor { dims, data });
        }
        Ok(arch)
    }
}

/// Serialize one named tensor record (the per-tensor wire layout).
fn tensor_bytes_into(buf: &mut Vec<u8>, name: &str, t: &Tensor) {
    buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
    buf.extend_from_slice(name.as_bytes());
    buf.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
    for &d in &t.dims {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
    for &x in &t.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Atomically replace `path` with `bytes`: write a sibling `.tmp` file,
/// fsync, rename. A crash at any instant (kill -9 included) leaves
/// either the old file or the complete new one, never a torn write —
/// the durability primitive under the compress-run checkpoint protocol.
pub fn write_bytes_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    f.sync_all()
        .with_context(|| format!("syncing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// Streaming `.aat` writer: appends tensors one at a time, so a
/// whole-model artifact can be assembled from per-block shards without
/// ever holding more than one tensor in memory. Bytes go to `<path>.tmp`
/// and land at `path` atomically on [`finish`], which also returns the
/// FNV-1a 64 of everything written (the hash the run manifest records).
/// Output is byte-identical to [`TensorArchive::save`] when tensors are
/// appended in name order.
///
/// [`finish`]: ArchiveWriter::finish
pub struct ArchiveWriter {
    path: std::path::PathBuf,
    tmp: std::path::PathBuf,
    file: std::io::BufWriter<std::fs::File>,
    declared: usize,
    written: usize,
    hash: crate::util::hash::Fnv64,
}

impl ArchiveWriter {
    /// Start an archive that will hold exactly `n_tensors` tensors.
    pub fn create(path: impl AsRef<Path>, n_tensors: usize) -> Result<ArchiveWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let tmp = path.with_extension("tmp");
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = ArchiveWriter {
            path,
            tmp,
            file: std::io::BufWriter::new(file),
            declared: n_tensors,
            written: 0,
            hash: crate::util::hash::Fnv64::new(),
        };
        let mut header = Vec::with_capacity(8);
        header.extend_from_slice(b"AAT1");
        header.extend_from_slice(&(n_tensors as u32).to_le_bytes());
        w.emit(&header)?;
        Ok(w)
    }

    fn emit(&mut self, bytes: &[u8]) -> Result<()> {
        self.hash.update(bytes);
        self.file
            .write_all(bytes)
            .with_context(|| format!("writing {}", self.tmp.display()))
    }

    /// Append the next tensor. Order is the caller's contract — readers
    /// index by name, but byte-level reproducibility needs a fixed order.
    pub fn append(&mut self, name: &str, t: &Tensor) -> Result<()> {
        anyhow::ensure!(
            self.written < self.declared,
            "archive {} declared {} tensors, '{name}' would be one more",
            self.path.display(),
            self.declared
        );
        let mut rec = Vec::new();
        tensor_bytes_into(&mut rec, name, t);
        self.emit(&rec)?;
        self.written += 1;
        Ok(())
    }

    /// Flush, fsync, rename into place; returns the content hash.
    pub fn finish(mut self) -> Result<u64> {
        anyhow::ensure!(
            self.written == self.declared,
            "archive {} declared {} tensors but only {} were appended",
            self.path.display(),
            self.declared,
            self.written
        );
        self.file
            .flush()
            .with_context(|| format!("flushing {}", self.tmp.display()))?;
        self.file
            .get_ref()
            .sync_all()
            .with_context(|| format!("syncing {}", self.tmp.display()))?;
        std::fs::rename(&self.tmp, &self.path).with_context(|| {
            format!("renaming {} -> {}", self.tmp.display(), self.path.display())
        })?;
        Ok(self.hash.finish())
    }
}

/// Write a string to a file, creating parent directories.
pub fn write_text(path: impl AsRef<Path>, text: &str) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path.as_ref(), text)
        .with_context(|| format!("writing {}", path.as_ref().display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("aasvd-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn archive_roundtrip() {
        let mut a = TensorArchive::new();
        a.insert("w", Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        a.insert("b", Tensor::new(vec![4], vec![0.5; 4]));
        let p = tmpfile("roundtrip.aat");
        a.save(&p).unwrap();
        let b = TensorArchive::load(&p).unwrap();
        assert_eq!(a.tensors, b.tensors);
    }

    #[test]
    fn empty_archive_roundtrip() {
        let a = TensorArchive::new();
        let p = tmpfile("empty.aat");
        a.save(&p).unwrap();
        assert_eq!(TensorArchive::load(&p).unwrap().tensors.len(), 0);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmpfile("garbage.aat");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(TensorArchive::load(&p).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut a = TensorArchive::new();
        a.insert("w", Tensor::new(vec![8], vec![1.0; 8]));
        let p = tmpfile("trunc.aat");
        a.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(TensorArchive::load(&p).is_err());
    }

    #[test]
    #[should_panic]
    fn tensor_dims_must_match_data() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }
}
