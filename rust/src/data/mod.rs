//! Synthetic data substrate: shared language, corpora (wiki/ptb/c4 roles),
//! zero-shot tasks, and batch assembly.

pub mod batcher;
pub mod corpus;
pub mod lang;
pub mod tasks;

pub use batcher::{Batcher, TokenBatch};
pub use corpus::{Corpus, Domain};
pub use tasks::{Task, TaskInstance, ALL_TASKS};
