//! The determinism rule set and the per-path policy table.
//!
//! Each rule is a small set of textual patterns matched against
//! comment- and string-stripped source lines (see `scan.rs`), gated by a
//! policy that maps source trees to the constructs they are allowed to
//! use. The rules encode the repo's central correctness contract: every
//! parallel kernel is bitwise thread-count invariant, and the serving /
//! compression stack is built on that guarantee (see README
//! "Correctness tooling").

/// One lint rule: a stable kebab-case name, the code patterns that fire
/// it, and a one-line rationale shown in reports.
pub struct RuleDef {
    pub name: &'static str,
    pub patterns: &'static [&'static str],
    pub summary: &'static str,
}

/// Rule names (kebab-case, used in reports and suppression comments).
pub const RULE_ADHOC_PARALLELISM: &str = "adhoc-parallelism";
pub const RULE_HASH_ITER: &str = "hash-iter";
pub const RULE_FLOAT_REDUCE: &str = "float-reduce";
pub const RULE_FLOAT_CMP: &str = "float-cmp";
pub const RULE_ENV_VAR: &str = "env-var";
pub const RULE_WALLCLOCK: &str = "wallclock";
pub const RULE_SERVE_UNWRAP: &str = "serve-unwrap";
/// Pseudo-rule for malformed suppression comments (unknown rule name,
/// missing justification). Always active, never suppressible.
pub const RULE_LINT_DIRECTIVE: &str = "lint-directive";

/// The seven determinism/robustness rules, in report order.
pub const RULES: &[RuleDef] = &[
    RuleDef {
        name: RULE_ADHOC_PARALLELISM,
        patterns: &["thread::spawn", "thread::Builder", "thread::scope", "rayon"],
        summary: "ad-hoc parallelism outside util/pool.rs — all parallel fan-out \
                  must go through Pool so results merge in submission order",
    },
    RuleDef {
        name: RULE_HASH_ITER,
        patterns: &["HashMap", "HashSet"],
        summary: "hash collections in a numeric/artifact tree — iteration order \
                  is nondeterministic; use BTreeMap/BTreeSet or Vec",
    },
    RuleDef {
        name: RULE_FLOAT_REDUCE,
        patterns: &[".sum::<f32>", ".sum::<f64>", ".fold(0.", ".fold(0f"],
        summary: "float reduction outside the sanctioned banded-kernel files — \
                  route accumulations through the deterministic kernels",
    },
    RuleDef {
        name: RULE_FLOAT_CMP,
        patterns: &["partial_cmp"],
        summary: "partial_cmp on floats — NaN breaks the ordering (the eigh.rs \
                  bug class); use f32::total_cmp / f64::total_cmp",
    },
    RuleDef {
        name: RULE_ENV_VAR,
        patterns: &["env::var", "env::set_var", "env::remove_var", "env::vars"],
        summary: "environment read outside util/pool.rs, util/cli.rs or the \
                  experiment setup — hidden knobs make runs irreproducible",
    },
    RuleDef {
        name: RULE_WALLCLOCK,
        patterns: &["Instant::now", "SystemTime"],
        summary: "wall-clock read in a compute path — timing must never feed \
                  numeric results",
    },
    RuleDef {
        name: RULE_SERVE_UNWRAP,
        patterns: &[".unwrap()", ".expect("],
        summary: "unwrap/expect on the serving hot path or the checkpoint \
                  persistence surface — route failures through typed errors \
                  (CancelReason::Backend on the serve side, anyhow context on \
                  the resume side) instead of panicking",
    },
];

/// Files where ordered float reductions are the whole point: the
/// row-banded kernels whose accumulation order *defines* the repo's
/// bitwise thread-count-invariance contract.
const FLOAT_KERNEL_FILES: &[&str] = &[
    "src/linalg/matrix.rs",
    "src/linalg/tridiag.rs",
    "src/model/forward.rs",
    "src/model/lowrank.rs",
    "src/model/quant_lowrank.rs",
];

/// Files allowed to read the environment: the pool's thread-count
/// resolution, the CLI surface, and the experiment setup path.
const ENV_FILES: &[&str] = &["src/util/pool.rs", "src/util/cli.rs", "src/experiments.rs"];

/// Trees where hash-iteration order would leak into numeric results or
/// compression artifacts. `serve/kv_pool.rs` is included because the
/// prefix trie's iteration order decides LRU eviction ties — a HashMap
/// there would make block eviction (and thus 429s under pressure)
/// nondeterministic across runs.
const HASH_ITER_TREES: &[&str] = &[
    "src/linalg/",
    "src/model/",
    "src/compress/",
    "src/refine/",
    "src/serve/kv_pool.rs",
];

/// The checkpoint persistence surface, held to the serve-side unwrap
/// standard: a panic in the run-manifest or streaming-pipeline code can
/// strand a half-written run directory in a state that `--resume` then
/// misreads, so every failure must surface as a typed error with enough
/// context to act on (which file, what to remove). The quantized
/// block (de)serialization lives on the same surface — it decodes the
/// int8 artifacts the run writer persists, and it sits on the serving
/// boot path, where a panic kills every in-flight request at once.
const PERSIST_FILES: &[&str] = &[
    "src/runtime/manifest.rs",
    "src/compress/run.rs",
    "src/model/quant_lowrank.rs",
];

/// Trees whose compute paths must not read wall clocks. The HTTP front
/// door is held to the same rule: its legitimate clock reads (read
/// deadlines, TTFT samples) are latency *measurement*, and each site
/// must carry a justified suppression saying so — anything else is a
/// wall clock leaking toward token sampling.
const WALLCLOCK_TREES: &[&str] = &[
    "src/linalg/",
    "src/model/",
    "src/compress/",
    "src/serve/http/",
];

pub fn is_known_rule(name: &str) -> bool {
    RULES.iter().any(|r| r.name == name)
}

pub fn rule_summary(name: &str) -> &'static str {
    RULES
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.summary)
        .unwrap_or("malformed aasvd-lint suppression comment")
}

/// Normalize a filesystem path to the policy's key space: the suffix
/// starting at the first `src` / `tests` / `benches` / `bin` component,
/// with forward slashes (so `rust/src/serve/engine.rs` and
/// `./src/serve/engine.rs` both resolve to `src/serve/engine.rs`).
pub fn policy_path(path: &str) -> String {
    let norm = path.replace('\\', "/");
    let parts: Vec<&str> = norm.split('/').filter(|s| !s.is_empty() && *s != ".").collect();
    for (i, p) in parts.iter().enumerate() {
        if matches!(*p, "src" | "tests" | "benches" | "bin") {
            return parts[i..].join("/");
        }
    }
    parts.join("/")
}

/// The policy table: does `rule` apply to (normalized) `path`, given
/// whether the current line sits inside `#[cfg(test)]` code?
///
/// - `adhoc-parallelism`: everywhere except `util/pool.rs` (the one
///   sanctioned parallelism substrate), test code included.
/// - `hash-iter`: the numeric/artifact trees (`linalg/`, `model/`,
///   `compress/`, `refine/`) plus the prefix-cache trie
///   (`serve/kv_pool.rs`), test code included — artifact equality
///   tests are exactly where ordering bugs hide.
/// - `float-reduce`: all of `src/` outside the five banded-kernel files;
///   test code exempt (tests legitimately compute reference sums to
///   compare against the kernels).
/// - `float-cmp`: everywhere, test code included (the NaN bug class does
///   not care where it runs).
/// - `env-var`: all of `src/` outside the pool/CLI/setup allowlist; test
///   code exempt (tests may pin env knobs).
/// - `wallclock`: non-test code in `linalg/`, `model/`, `compress/`, and
///   `serve/http/` (where only justified latency-measurement sites may
///   suppress it).
/// - `serve-unwrap`: non-test code in `src/serve/`, plus the checkpoint
///   persistence surface (`runtime/manifest.rs`, `compress/run.rs`,
///   `model/quant_lowrank.rs`) where a panic strands a run directory
///   mid-checkpoint or kills serving at artifact-load time.
pub fn applies(rule: &str, path: &str, in_test: bool) -> bool {
    match rule {
        RULE_ADHOC_PARALLELISM => path != "src/util/pool.rs",
        RULE_HASH_ITER => HASH_ITER_TREES.iter().any(|t| path.starts_with(t)),
        RULE_FLOAT_REDUCE => {
            !in_test && path.starts_with("src/") && !FLOAT_KERNEL_FILES.contains(&path)
        }
        RULE_FLOAT_CMP => true,
        RULE_ENV_VAR => !in_test && path.starts_with("src/") && !ENV_FILES.contains(&path),
        RULE_WALLCLOCK => {
            !in_test && WALLCLOCK_TREES.iter().any(|t| path.starts_with(t))
        }
        RULE_SERVE_UNWRAP => {
            !in_test && (path.starts_with("src/serve/") || PERSIST_FILES.contains(&path))
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_paths_normalize() {
        assert_eq!(policy_path("rust/src/serve/engine.rs"), "src/serve/engine.rs");
        assert_eq!(policy_path("./src/util/pool.rs"), "src/util/pool.rs");
        assert_eq!(policy_path("src\\linalg\\eigh.rs"), "src/linalg/eigh.rs");
        assert_eq!(
            policy_path("/abs/checkout/rust/tests/kv_cache.rs"),
            "tests/kv_cache.rs"
        );
        assert_eq!(policy_path("rust/bin/lint.rs"), "bin/lint.rs");
    }

    #[test]
    fn pool_is_the_only_parallelism_site() {
        assert!(!applies(RULE_ADHOC_PARALLELISM, "src/util/pool.rs", false));
        assert!(applies(RULE_ADHOC_PARALLELISM, "src/serve/engine.rs", false));
        assert!(applies(RULE_ADHOC_PARALLELISM, "tests/engine_fuzz.rs", true));
    }

    #[test]
    fn float_reduce_sanctions_the_kernel_files() {
        assert!(!applies(RULE_FLOAT_REDUCE, "src/linalg/matrix.rs", false));
        assert!(!applies(RULE_FLOAT_REDUCE, "src/model/forward.rs", false));
        // the fused int8 kernels pin accumulation order like the f32 ones
        assert!(!applies(RULE_FLOAT_REDUCE, "src/model/quant_lowrank.rs", false));
        assert!(applies(RULE_FLOAT_REDUCE, "src/linalg/eigh.rs", false));
        // tests and non-src trees are exempt
        assert!(!applies(RULE_FLOAT_REDUCE, "src/linalg/eigh.rs", true));
        assert!(!applies(RULE_FLOAT_REDUCE, "benches/linalg.rs", false));
    }

    #[test]
    fn serve_unwrap_scopes_to_serve_non_test() {
        assert!(applies(RULE_SERVE_UNWRAP, "src/serve/engine.rs", false));
        assert!(!applies(RULE_SERVE_UNWRAP, "src/serve/engine.rs", true));
        assert!(!applies(RULE_SERVE_UNWRAP, "src/linalg/eigh.rs", false));
        // the HTTP front door sits inside src/serve/, so it inherits the rule
        assert!(applies(RULE_SERVE_UNWRAP, "src/serve/http/server.rs", false));
    }

    #[test]
    fn persistence_surface_is_unwrap_hardened() {
        // the checkpoint files are held to the serve-side unwrap standard
        assert!(applies(RULE_SERVE_UNWRAP, "src/runtime/manifest.rs", false));
        assert!(applies(RULE_SERVE_UNWRAP, "src/compress/run.rs", false));
        // ...as is the int8 artifact (de)serialization + serving kernels
        assert!(applies(RULE_SERVE_UNWRAP, "src/model/quant_lowrank.rs", false));
        // test code in those files keeps its unwraps
        assert!(!applies(RULE_SERVE_UNWRAP, "src/runtime/manifest.rs", true));
        assert!(!applies(RULE_SERVE_UNWRAP, "src/compress/run.rs", true));
        assert!(!applies(RULE_SERVE_UNWRAP, "src/model/quant_lowrank.rs", true));
        // the rest of runtime/ is not swept in
        assert!(!applies(RULE_SERVE_UNWRAP, "src/runtime/engine.rs", false));
        // and the streaming pipeline inherits the compress-tree rules too
        assert!(applies(RULE_WALLCLOCK, "src/compress/run.rs", false));
        assert!(applies(RULE_HASH_ITER, "src/compress/run.rs", false));
        assert!(applies(RULE_ENV_VAR, "src/compress/run.rs", false));
        assert!(applies(RULE_ENV_VAR, "src/runtime/manifest.rs", false));
    }

    #[test]
    fn wallclock_covers_the_http_front_door() {
        assert!(applies(RULE_WALLCLOCK, "src/serve/http/server.rs", false));
        assert!(applies(RULE_WALLCLOCK, "src/serve/http/sse.rs", false));
        // test code and the rest of serve/ stay exempt (the engine's
        // deadline bookkeeping is policed by review, not this rule)
        assert!(!applies(RULE_WALLCLOCK, "src/serve/http/server.rs", true));
        assert!(!applies(RULE_WALLCLOCK, "src/serve/engine.rs", false));
        assert!(applies(RULE_WALLCLOCK, "src/compress/svd.rs", false));
    }

    #[test]
    fn hash_iter_covers_the_prefix_trie() {
        assert!(applies(RULE_HASH_ITER, "src/serve/kv_pool.rs", false));
        assert!(applies(RULE_HASH_ITER, "src/serve/kv_pool.rs", true));
        assert!(applies(RULE_HASH_ITER, "src/model/paged_kv.rs", false));
        // the rest of serve/ stays out of hash-iter scope
        assert!(!applies(RULE_HASH_ITER, "src/serve/engine.rs", false));
    }

    #[test]
    fn unknown_rules_apply_nowhere() {
        assert!(!applies("no-such-rule", "src/serve/engine.rs", false));
        assert!(!is_known_rule("no-such-rule"));
        assert!(is_known_rule(RULE_HASH_ITER));
    }
}
