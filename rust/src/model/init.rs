//! Parameter initialization (GPT-2-style scaled normal).

use super::config::Config;
use super::params::{param_layout, FlatStore};
use crate::util::rng::Rng;

/// Initialize dense parameters: N(0, 0.02) for embeddings and projections,
/// residual-output projections (wo, w_down) scaled by 1/sqrt(2L), norm
/// gains at 1.0.
pub fn init_params(cfg: &Config, rng: &mut Rng) -> FlatStore {
    let mut store = FlatStore::zeros(param_layout(cfg));
    let resid_scale = 0.02 / ((2 * cfg.n_layers) as f32).sqrt();
    for e in store.layout.entries.clone() {
        let scale = if e.name.ends_with("norm") {
            // gains start at identity
            for v in store.view_mut(&e.name) {
                *v = 1.0;
            }
            continue;
        } else if e.name.ends_with(".wo") || e.name.ends_with(".w_down") {
            resid_scale
        } else {
            0.02
        };
        for v in store.view_mut(&e.name) {
            *v = rng.normal() * scale;
        }
    }
    store
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_are_ones() {
        let cfg = Config::builtin("tiny").unwrap();
        let p = init_params(&cfg, &mut Rng::new(0));
        assert!(p.view("final_norm").iter().all(|&v| v == 1.0));
        assert!(p.view("blocks.0.attn_norm").iter().all(|&v| v == 1.0));
    }

    #[test]
    fn weights_have_expected_scale() {
        let cfg = Config::builtin("base").unwrap();
        let p = init_params(&cfg, &mut Rng::new(1));
        let wq = p.view("blocks.0.wq");
        let std = (wq.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / wq.len() as f64)
            .sqrt();
        assert!((std - 0.02).abs() < 0.002, "std={std}");
        let wo = p.view("blocks.0.wo");
        let std_o = (wo.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / wo.len() as f64)
            .sqrt();
        assert!(std_o < std, "residual projections should be smaller");
    }

    #[test]
    fn deterministic_from_seed() {
        let cfg = Config::builtin("tiny").unwrap();
        let a = init_params(&cfg, &mut Rng::new(7));
        let b = init_params(&cfg, &mut Rng::new(7));
        assert_eq!(a.data, b.data);
    }
}
