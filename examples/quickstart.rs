//! Quickstart: compress a pretrained model with AA-SVD and measure what it
//! costs you — in ~40 lines of library use.
//!
//!   make artifacts            # once: AOT-lower the JAX/Pallas layer
//!   cargo run --release --example quickstart -- --threads 4
//!
//! Uses the `small` config so the whole thing (pretrain if no checkpoint,
//! compress @ ratio 0.6, evaluate) runs in a few minutes. The compression
//! math (collection, covariances, closed-form solves) scales with
//! `--threads` (or the `AA_SVD_THREADS` env var); artifacts are identical
//! at any worker count.

use aasvd::compress::Method;
use aasvd::data::Domain;
use aasvd::eval::display_ppl;
use aasvd::experiments::{eval_compressed_method, eval_dense, setup, Knobs};
use aasvd::util::cli::Args;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env("quickstart: compress with AA-SVD, report cost");
    let knobs = Knobs::parse(&args, "small");
    args.finish_or_help();

    // 1. engine + pretrained model + calibration/eval data
    let ctx = setup(&knobs)?;
    println!(
        "model '{}': {} params, {} calibration sequences",
        ctx.cfg.name,
        ctx.params.data.len(),
        ctx.calib.len() * ctx.cfg.batch
    );

    // 2. dense baseline
    let dense = eval_dense(&ctx)?;
    println!(
        "dense:   wiki ppl {}  avg zero-shot acc {:.3}",
        display_ppl(dense.ppl_of(Domain::Wiki)),
        dense.avg_acc
    );

    // 3. AA-SVD at 60% parameter budget
    let (ev, cm) = eval_compressed_method(&ctx, &Method::aa_svd(knobs.refine()), 0.6)?;
    println!(
        "aa_svd@0.6: wiki ppl {}  avg acc {:.3}  (drop {:.1}%)",
        display_ppl(ev.ppl_of(Domain::Wiki)),
        ev.avg_acc,
        100.0 * (dense.avg_acc - ev.avg_acc) / dense.avg_acc
    );
    println!(
        "achieved parameter ratio {:.3}; per-linear ranks {:?}",
        cm.allocation.achieved_ratio(&ctx.cfg),
        cm.allocation.ranks
    );
    println!(
        "pipeline time on {} threads: collect {:.1}s, closed-form solve {:.1}s, refine {:.1}s",
        aasvd::util::pool::auto_threads(),
        cm.report.secs_collect,
        cm.report.secs_solve,
        cm.report.secs_refine
    );
    Ok(())
}
