//! Table 4: perplexity under fixed memory budgets.
//!
//! Paper: LLaMA-7B at 10/9/8/7 GB (≈ 0.77/0.69/0.61/0.54 of dense bytes)
//! vs LLM-Pruner / SliceGPT / BlockPruner / SAES-SVD. Here: the same
//! budget fractions applied to our model; each method is driven to the
//! largest configuration that fits the budget.

use aasvd::compress::{
    prune_model, ratio_for_budget, BlockOutcome, Method, PruneMethod, RankScheme,
};
use aasvd::data::Domain;
use aasvd::eval::{dense_ppl, display_ppl, Table};
use aasvd::experiments::{eval_compressed_method_observed, setup, Knobs};
use aasvd::util::cli::Args;
use anyhow::Result;

/// (budget label, fraction of dense bytes, paper row: llm_pruner,
///  slicegpt, blockpruner, aa_svd)
const BUDGETS: [(&str, f64, [f64; 4]); 4] = [
    ("10GB", 0.77, [9.88, 8.78, 9.40, 6.89]),
    ("9GB", 0.69, [12.21, 12.73, 12.76, 7.14]),
    ("8GB", 0.61, [18.94, 16.39, 19.78, 7.84]),
    ("7GB", 0.54, [21.68, 27.41, 43.05, 8.35]),
];

fn main() -> Result<()> {
    let args = Args::parse_env("Table 4: perplexity under memory budgets");
    let knobs = Knobs::parse(&args, "small");
    args.finish_or_help();
    let ctx = setup(&knobs)?;

    let mut table = Table::new(
        "Table 4 — WikiText-role PPL under memory budgets",
        &[
            "budget", "frac", "llm_pruner", "slicegpt", "blockpruner",
            "aa_svd", "paper:aa_svd",
        ],
    );

    for (label, frac, paper) in BUDGETS {
        let mut cells = vec![label.to_string(), format!("{frac:.2}")];
        // pruning baselines evaluated at the budget's parameter ratio
        for pruner in [
            PruneMethod::Magnitude,
            PruneMethod::SliceGpt,
            PruneMethod::BlockDrop,
        ] {
            let pm = prune_model(&ctx.engine, &ctx.cfg, &ctx.params, &ctx.calib, pruner, frac)?;
            let wiki = &ctx.eval.iter().find(|(d, _)| *d == Domain::Wiki).unwrap().1;
            let ppl = dense_ppl(&ctx.engine, &ctx.cfg, &pm.params, wiki)?;
            cells.push(display_ppl(ppl));
        }
        // AA-SVD at the ratio that fits the budget
        let rho = ratio_for_budget(&ctx.cfg, frac, RankScheme::Standard);
        let (ev, _) = eval_compressed_method_observed(
            &ctx,
            &Method::aa_svd(knobs.refine()),
            rho,
            &mut |o: &BlockOutcome| {
                eprintln!(
                    "[table4] {label} aa_svd @ {rho:.3}: block {}/{} ({:.1}s)",
                    o.index + 1,
                    o.total,
                    o.secs
                );
            },
        )?;
        cells.push(display_ppl(ev.ppl_of(Domain::Wiki)));
        cells.push(display_ppl(paper[3]));
        table.row(cells);
    }
    table.emit("table4")?;
    Ok(())
}
