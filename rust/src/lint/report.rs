//! Human-readable and JSON rendering of lint findings.

use super::rules::RULES;
use super::scan::Violation;
use crate::util::json::Json;

/// Sort findings into report order: path, then line, then rule name.
pub fn sort_violations(violations: &mut [Violation]) {
    violations.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule.as_str()).cmp(&(b.path.as_str(), b.line, b.rule.as_str()))
    });
}

/// Human report: findings grouped by file, then a per-rule tally and a
/// one-line verdict.
pub fn render_human(violations: &[Violation], files_scanned: usize) -> String {
    let mut out = String::new();
    let mut last_path = "";
    for v in violations {
        if v.path != last_path {
            if !last_path.is_empty() {
                out.push('\n');
            }
            out.push_str(&format!("{}\n", v.path));
            last_path = &v.path;
        }
        out.push_str(&format!("  {}: [{}] {}\n", v.line, v.rule, v.detail));
        out.push_str(&format!("      {}\n", v.snippet));
    }
    if !violations.is_empty() {
        out.push('\n');
        for rule in RULES {
            let n = violations.iter().filter(|v| v.rule == rule.name).count();
            if n > 0 {
                out.push_str(&format!("  {:>4}  {}\n", n, rule.name));
            }
        }
        let directives = violations
            .iter()
            .filter(|v| !RULES.iter().any(|r| r.name == v.rule))
            .count();
        if directives > 0 {
            out.push_str(&format!("  {directives:>4}  lint-directive\n"));
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "aasvd-lint: {} file{} scanned, {} violation{}\n",
        files_scanned,
        if files_scanned == 1 { "" } else { "s" },
        violations.len(),
        if violations.len() == 1 { "" } else { "s" },
    ));
    out
}

/// JSON report:
/// `{"files_scanned": N, "violations": [{rule, path, line, snippet, detail}, ...], "clean": bool}`
pub fn render_json(violations: &[Violation], files_scanned: usize) -> Json {
    let items: Vec<Json> = violations
        .iter()
        .map(|v| {
            Json::obj()
                .set("rule", v.rule.as_str())
                .set("path", v.path.as_str())
                .set("line", v.line)
                .set("snippet", v.snippet.as_str())
                .set("detail", v.detail.as_str())
        })
        .collect();
    Json::obj()
        .set("files_scanned", files_scanned)
        .set("violations", Json::Arr(items))
        .set("clean", violations.is_empty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Violation> {
        vec![
            Violation {
                rule: "wallclock".to_string(),
                path: "src/linalg/x.rs".to_string(),
                line: 7,
                snippet: "let t = Instant::now();".to_string(),
                detail: "wall-clock read in a compute path".to_string(),
            },
            Violation {
                rule: "float-cmp".to_string(),
                path: "src/eval/y.rs".to_string(),
                line: 3,
                snippet: "a.partial_cmp(b)".to_string(),
                detail: "partial_cmp on floats".to_string(),
            },
        ]
    }

    #[test]
    fn sorting_is_by_path_then_line() {
        let mut vs = sample();
        sort_violations(&mut vs);
        assert_eq!(vs[0].path, "src/eval/y.rs");
        assert_eq!(vs[1].path, "src/linalg/x.rs");
    }

    #[test]
    fn human_report_mentions_every_finding() {
        let report = render_human(&sample(), 12);
        assert!(report.contains("src/linalg/x.rs"));
        assert!(report.contains("[float-cmp]"));
        assert!(report.contains("12 files scanned, 2 violations"));
        let clean = render_human(&[], 3);
        assert!(clean.contains("3 files scanned, 0 violations"));
    }

    #[test]
    fn json_report_round_trips() {
        let j = render_json(&sample(), 12);
        let parsed = Json::parse(&j.to_string_pretty()).expect("valid json");
        assert_eq!(parsed.req("files_scanned").as_usize(), Some(12));
        assert_eq!(parsed.req("clean").as_bool(), Some(false));
        let vs = parsed.req("violations").as_arr().expect("array");
        assert_eq!(vs.len(), 2);
        assert_eq!(vs[0].req("rule").as_str(), Some("wallclock"));
        assert_eq!(vs[0].req("line").as_usize(), Some(7));
    }
}
