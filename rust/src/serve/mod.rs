//! Serving layer: continuous-batching decode over the compressed model.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;

pub use engine::{ServedModel, Server};
pub use metrics::ServeMetrics;
pub use request::{GenParams, GenRequest, GenResponse};
