//! Dense row-major matrix over f64.
//!
//! The compression closed form (Theorem 3.2) runs entirely in f64: the
//! whitening step inverts Cholesky factors of activation covariances whose
//! condition numbers grow with calibration size, and f32 loses the tail
//! singular values that decide truncation order. Weights arrive as f32 and
//! the factors are cast back to f32 at the end.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn random(rows: usize, cols: usize, rng: &mut Rng, scale: f64) -> Matrix {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols)
                .map(|_| rng.normal() as f64 * scale)
                .collect(),
        }
    }

    /// Random symmetric positive-definite matrix (for tests/benches).
    pub fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        let a = Matrix::random(n, n, rng, 1.0);
        let mut s = a.matmul_bt(&a); // A A^T, PSD
        for i in 0..n {
            s.data[i * n + i] += n as f64 * 0.1; // well-conditioned
        }
        s
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// C = A * B (blocked i-k-j loop; B rows stream through cache).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        const KB: usize = 64;
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..m {
                let crow = &mut c.data[i * n..(i + 1) * n];
                for p in kb..kend {
                    let a = self.data[i * k + p];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &b.data[p * n..(p + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += a * bv;
                    }
                }
            }
        }
        c
    }

    /// C = A * B^T without materializing the transpose (dot-product form).
    pub fn matmul_bt(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_bt dim mismatch");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0;
                for (&x, &y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                c.data[i * n + j] = acc;
            }
        }
        c
    }

    /// C = A^T * B (i.e., Gram-style product over the row axis).
    pub fn matmul_at(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows, "matmul_at dim mismatch");
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        for p in 0..k {
            let arow = &self.data[p * m..(p + 1) * m];
            let brow = &b.data[p * n..(p + 1) * n];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += a * bv;
                }
            }
        }
        c
    }

    pub fn add(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
        }
    }

    pub fn sub(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&b.data).map(|(x, y)| x - y).collect(),
        }
    }

    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |a, &x| a.max(x.abs()))
    }

    /// Column slice [.., j0..j1) as a new matrix.
    pub fn cols_range(&self, j0: usize, j1: usize) -> Matrix {
        assert!(j0 <= j1 && j1 <= self.cols);
        let mut m = Matrix::zeros(self.rows, j1 - j0);
        for i in 0..self.rows {
            m.row_mut(i)
                .copy_from_slice(&self.row(i)[j0..j1]);
        }
        m
    }

    /// Symmetrize in place: (A + A^T)/2 — cleans accumulation asymmetry.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self.get(i, j) + self.get(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;

    #[test]
    fn matmul_hand_example() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_bt_matches_explicit_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(13, 7, &mut rng, 1.0);
        let b = Matrix::random(9, 7, &mut rng, 1.0);
        let got = a.matmul_bt(&b);
        let want = a.matmul(&b.transpose());
        assert_close(&got.data, &want.data, 1e-12);
    }

    #[test]
    fn matmul_at_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Matrix::random(11, 5, &mut rng, 1.0);
        let b = Matrix::random(11, 8, &mut rng, 1.0);
        let got = a.matmul_at(&b);
        let want = a.transpose().matmul(&b);
        assert_close(&got.data, &want.data, 1e-12);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Matrix::random(6, 6, &mut rng, 1.0);
        let i = Matrix::identity(6);
        assert_close(&a.matmul(&i).data, &a.data, 1e-15);
        assert_close(&i.matmul(&a).data, &a.data, 1e-15);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let a = Matrix::random(17, 33, &mut rng, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn f32_roundtrip() {
        let data: Vec<f32> = vec![0.5, -1.25, 3.75, 2.0];
        let m = Matrix::from_f32(2, 2, &data);
        assert_eq!(m.to_f32(), data);
    }

    #[test]
    fn frob_norm_example() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn cols_range_extracts() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let c = a.cols_range(1, 3);
        assert_eq!(c.data, vec![2., 3., 5., 6.]);
    }

    #[test]
    fn random_spd_is_symmetric() {
        let mut rng = Rng::new(5);
        let s = Matrix::random_spd(12, &mut rng);
        let d = s.sub(&s.transpose()).max_abs();
        assert!(d < 1e-9);
    }
}
