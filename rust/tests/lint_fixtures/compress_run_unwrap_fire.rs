// aasvd-lint: path=src/compress/run.rs

pub fn first_shard(shards: &[String]) -> &str {
    shards.first().expect("at least one shard").as_str()
}
