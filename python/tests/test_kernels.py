"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Shape/seed sweeps in the spirit of hypothesis: every parametrized case is a
distinct (shape, seed) draw; tolerances are f32 matmul-accumulation level.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import attention, cov, lowrank, ref


def rs(seed):
    return np.random.RandomState(seed)


TOL = dict(rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("d,l,seed", [
    (16, 32, 0), (64, 256, 1), (96, 64, 2), (128, 512, 3),
    (176, 256, 4), (256, 256, 5), (352, 512, 6),
])
def test_cov_accum_matches_ref(d, l, seed):
    r = rs(seed)
    c = r.randn(d, d).astype(np.float32)
    x = r.randn(l, d).astype(np.float32)
    got = cov.cov_accum(jnp.asarray(c), jnp.asarray(x))
    want = ref.cov_accum(c, x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("da,db,l,seed", [
    (16, 16, 32, 0), (64, 96, 256, 1), (128, 64, 128, 2),
    (176, 176, 256, 3), (96, 352, 256, 4),
])
def test_cross_cov_accum_matches_ref(da, db, l, seed):
    r = rs(seed)
    c = r.randn(da, db).astype(np.float32)
    a = r.randn(l, da).astype(np.float32)
    b = r.randn(l, db).astype(np.float32)
    got = cov.cross_cov_accum(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, ref.cross_cov_accum(c, a, b),
                               rtol=1e-3, atol=1e-3)


def test_cov_accum_zero_rows_are_noops():
    """Zero-padding the token axis must not change the accumulator —
    the Rust coordinator relies on this to pad final partial chunks."""
    r = rs(7)
    d = 64
    c = r.randn(d, d).astype(np.float32)
    x = np.zeros((256, d), np.float32)
    x[:100] = r.randn(100, d)
    got = cov.cov_accum(jnp.asarray(c), jnp.asarray(x))
    want = ref.cov_accum(c, x[:100])
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_cov_accum_is_streamable():
    """Accumulating two chunks == one covariance over the concatenation."""
    r = rs(8)
    d, l = 96, 128
    x1 = r.randn(l, d).astype(np.float32)
    x2 = r.randn(l, d).astype(np.float32)
    c0 = np.zeros((d, d), np.float32)
    step = cov.cov_accum(cov.cov_accum(jnp.asarray(c0), jnp.asarray(x1)),
                         jnp.asarray(x2))
    whole = ref.cov_accum(c0, np.concatenate([x1, x2]))
    np.testing.assert_allclose(step, whole, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("m,n,k,l,seed", [
    (32, 32, 8, 64, 0), (192, 128, 32, 256, 1), (128, 352, 64, 128, 2),
    (352, 128, 128, 256, 3), (64, 64, 64, 64, 4),  # full rank
    (256, 256, 16, 512, 5),
])
def test_lowrank_apply_matches_ref(m, n, k, l, seed):
    r = rs(seed)
    u = r.randn(m, k).astype(np.float32)
    v = r.randn(n, k).astype(np.float32)
    x = r.randn(l, n).astype(np.float32)
    got = lowrank.lowrank_apply(jnp.asarray(u), jnp.asarray(v), jnp.asarray(x))
    np.testing.assert_allclose(got, ref.lowrank_apply(u, v, x),
                               rtol=3e-3, atol=3e-3)


def test_lowrank_apply_rank_zero_mask_equivalent():
    """Zeroed trailing factor columns = lower-rank product (padding trick)."""
    r = rs(9)
    m = n = 64
    k, k_eff, l = 32, 8, 64
    u = r.randn(m, k).astype(np.float32)
    v = r.randn(n, k).astype(np.float32)
    u[:, k_eff:] = 0.0
    x = r.randn(l, n).astype(np.float32)
    got = lowrank.lowrank_apply(jnp.asarray(u), jnp.asarray(v), jnp.asarray(x))
    want = ref.lowrank_apply(u[:, :k_eff], v[:, :k_eff], x)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("t,hd,seed", [
    (16, 16, 0), (64, 32, 1), (128, 64, 2), (64, 48, 3), (256, 32, 4),
])
def test_attention_head_matches_ref(t, hd, seed):
    r = rs(seed)
    q = r.randn(t, hd).astype(np.float32)
    k = r.randn(t, hd).astype(np.float32)
    v = r.randn(t, hd).astype(np.float32)
    scale = 1.0 / np.sqrt(hd)
    got = attention.attention_head(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), scale)
    np.testing.assert_allclose(got, ref.attention_head(q, k, v, scale),
                               rtol=3e-3, atol=3e-3)


def test_attention_head_is_causal():
    """Perturbing future keys/values must not change earlier outputs."""
    r = rs(10)
    t, hd = 64, 32
    q = r.randn(t, hd).astype(np.float32)
    k = r.randn(t, hd).astype(np.float32)
    v = r.randn(t, hd).astype(np.float32)
    scale = 1.0 / np.sqrt(hd)
    base = np.asarray(attention.attention_head(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), scale))
    k2, v2 = k.copy(), v.copy()
    k2[40:] += 100.0
    v2[40:] -= 100.0
    pert = np.asarray(attention.attention_head(
        jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), scale))
    np.testing.assert_allclose(base[:40], pert[:40], rtol=1e-5, atol=1e-5)
    assert np.abs(base[41:] - pert[41:]).max() > 1e-3


@pytest.mark.parametrize("block_l,block_m", [(32, 32), (64, 128), (128, 64)])
def test_lowrank_apply_block_shape_invariance(block_l, block_m):
    """Result must not depend on the VMEM tiling schedule."""
    r = rs(11)
    m, n, k, l = 128, 128, 32, 128
    u = r.randn(m, k).astype(np.float32)
    v = r.randn(n, k).astype(np.float32)
    x = r.randn(l, n).astype(np.float32)
    got = lowrank.lowrank_apply(jnp.asarray(u), jnp.asarray(v), jnp.asarray(x),
                                block_l=block_l, block_m=block_m)
    np.testing.assert_allclose(got, ref.lowrank_apply(u, v, x),
                               rtol=3e-3, atol=3e-3)
