//! Process peak-memory probe for the pipeline's bounded-memory claims.
//!
//! The streaming compression run promises peak memory bounded by one
//! block's working set plus the activation streams, independent of model
//! depth. The bench harness and `aasvd compress --json` record the
//! process high-water mark so CI's compress-resume lane can gate on it.

/// Peak resident set size of this process in bytes (Linux `VmHWM` from
/// /proc/self/status). `None` on platforms without procfs or when the
/// field is absent.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Peak RSS in MiB, 0.0 when unavailable — shaped for JSON reports
/// (absence folds to a value gates can still compare against).
pub fn peak_rss_mb() -> f64 {
    peak_rss_bytes()
        .map(|b| b as f64 / (1024.0 * 1024.0))
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_is_sane() {
        // on Linux the probe must parse; elsewhere None is the contract
        if let Some(bytes) = peak_rss_bytes() {
            assert!(bytes > 0);
            assert!(peak_rss_mb() > 0.0);
            // a live test process has touched more than a page
            assert!(bytes >= 4096);
        } else {
            assert_eq!(peak_rss_mb(), 0.0);
        }
    }

    #[test]
    fn high_water_mark_never_decreases() {
        let Some(before) = peak_rss_bytes() else { return };
        let buf = vec![1u8; 1 << 20];
        std::hint::black_box(&buf);
        let after = peak_rss_bytes().expect("probe disappeared mid-test");
        assert!(after >= before);
    }
}
