// aasvd-lint: path=src/serve/http/fixture.rs

pub fn sample_ttft() -> f64 {
    // aasvd-lint: allow(wallclock): fixture justification — socket-side latency measurement feeding metrics only
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
