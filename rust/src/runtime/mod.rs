//! Runtime: PJRT client wrapper that loads and executes the AOT artifacts.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, HostTensor, Value};
pub use manifest::{ArtifactSpec, ConfigEntry, DType, Manifest, TensorSpec};
