//! Dense row-major matrix over f64.
//!
//! The compression closed form (Theorem 3.2) runs entirely in f64: the
//! whitening step inverts Cholesky factors of activation covariances whose
//! condition numbers grow with calibration size, and f32 loses the tail
//! singular values that decide truncation order. Weights arrive as f32 and
//! the factors are cast back to f32 at the end.
//!
//! The products (`matmul`, `matmul_bt`, `matmul_at`, `transpose`) split
//! their *output* into row bands solved in parallel on a
//! [`crate::util::pool::Pool`]. Every output element accumulates over the
//! contraction axis in ascending order no matter how the bands are cut,
//! so results are **bitwise identical for any worker count** — the
//! `_with` variants take an explicit pool, the plain names resolve
//! [`Pool::auto`].

use crate::util::pool::Pool;
use crate::util::rng::Rng;

/// Below this many flops (or moved elements, for transpose) a product
/// stays single-threaded: band handout costs more than it saves.
const PAR_MIN_WORK: usize = 1 << 18;

/// Row bands to cut `rows` of output into; 1 when threading won't pay.
fn bands_for(pool: &Pool, rows: usize, work: usize) -> usize {
    if pool.threads() <= 1 || work < PAR_MIN_WORK || rows == 0 {
        1
    } else {
        pool.threads().min(rows)
    }
}

/// Split `out` (`rows` × `row_elems`, row-major) into contiguous row bands
/// and run `body(first_row, band)` for each on the pool. Shared scaffolding
/// for every banded kernel below and for the Householder/QL eigensolver in
/// `linalg::tridiag`; `body` must write each output element with the same
/// accumulation order regardless of how the bands are cut — that is what
/// keeps results bitwise identical at any worker count.
pub(crate) fn run_banded<F>(
    pool: &Pool,
    rows: usize,
    row_elems: usize,
    work: usize,
    out: &mut [f64],
    body: F,
)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    if rows == 0 || row_elems == 0 {
        return;
    }
    let bands = bands_for(pool, rows, work);
    let rows_per = rows.div_ceil(bands);
    let body = &body;
    let jobs: Vec<_> = out
        .chunks_mut(rows_per * row_elems)
        .enumerate()
        .map(|(bi, band)| move || body(bi * rows_per, band))
        .collect();
    pool.run(jobs);
}

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert!(
            data.len() == rows * cols,
            "Matrix::from_vec: got {} elements for a {rows}x{cols} matrix (want {})",
            data.len(),
            rows * cols
        );
        Matrix { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert!(
            data.len() == rows * cols,
            "Matrix::from_f32: got {} elements for a {rows}x{cols} matrix (want {})",
            data.len(),
            rows * cols
        );
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn random(rows: usize, cols: usize, rng: &mut Rng, scale: f64) -> Matrix {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols)
                .map(|_| rng.normal() as f64 * scale)
                .collect(),
        }
    }

    /// Random symmetric positive-definite matrix (for tests/benches).
    pub fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        let a = Matrix::random(n, n, rng, 1.0);
        let mut s = a.matmul_bt(&a); // A A^T, PSD
        for i in 0..n {
            s.data[i * n + i] += n as f64 * 0.1; // well-conditioned
        }
        s
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        self.transpose_with(&Pool::auto())
    }

    /// Blocked transpose; output row bands (source columns) in parallel.
    /// A pure permutation — trivially identical for any worker count.
    pub fn transpose_with(&self, pool: &Pool) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        const B: usize = 32;
        let work = self.rows * self.cols;
        run_banded(pool, self.cols, self.rows, work, &mut t.data, |j0, tband| {
            for ib in (0..self.rows).step_by(B) {
                let iend = (ib + B).min(self.rows);
                for (cj, trow) in tband.chunks_exact_mut(self.rows).enumerate() {
                    let j = j0 + cj;
                    for i in ib..iend {
                        trow[i] = self.data[i * self.cols + j];
                    }
                }
            }
        });
        t
    }

    /// C = A * B (blocked over k; B rows stream through cache).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        self.matmul_with(b, &Pool::auto())
    }

    /// C = A * B with row bands of C solved in parallel. Each output
    /// element accumulates over k in ascending order regardless of the
    /// band split, so results are bitwise identical for any worker count.
    pub fn matmul_with(&self, b: &Matrix, pool: &Pool) -> Matrix {
        assert!(
            self.cols == b.rows,
            "matmul dim mismatch: [{}x{}] * [{}x{}]",
            self.rows,
            self.cols,
            b.rows,
            b.cols
        );
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        const KB: usize = 64;
        run_banded(pool, m, n, 2 * m * k * n, &mut c.data, |i0, cband| {
            for kb in (0..k).step_by(KB) {
                let kend = (kb + KB).min(k);
                for (ci, crow) in cband.chunks_exact_mut(n).enumerate() {
                    let arow = &self.data[(i0 + ci) * k..(i0 + ci + 1) * k];
                    for p in kb..kend {
                        let a = arow[p];
                        if a == 0.0 {
                            continue;
                        }
                        let brow = &b.data[p * n..(p + 1) * n];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += a * bv;
                        }
                    }
                }
            }
        });
        c
    }

    /// C = A * B^T without materializing the transpose (dot-product form).
    pub fn matmul_bt(&self, b: &Matrix) -> Matrix {
        self.matmul_bt_with(b, &Pool::auto())
    }

    /// Row-banded parallel A * B^T; per-element dot products accumulate
    /// in the same order as the sequential kernel (bitwise stable).
    pub fn matmul_bt_with(&self, b: &Matrix, pool: &Pool) -> Matrix {
        assert!(
            self.cols == b.cols,
            "matmul_bt dim mismatch: [{}x{}] * [{}x{}]^T",
            self.rows,
            self.cols,
            b.rows,
            b.cols
        );
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut c = Matrix::zeros(m, n);
        run_banded(pool, m, n, 2 * m * k * n, &mut c.data, |i0, cband| {
            for (ci, crow) in cband.chunks_exact_mut(n).enumerate() {
                let arow = &self.data[(i0 + ci) * k..(i0 + ci + 1) * k];
                for (j, cv) in crow.iter_mut().enumerate() {
                    let brow = &b.data[j * k..(j + 1) * k];
                    let mut acc = 0.0;
                    for (&x, &y) in arow.iter().zip(brow) {
                        acc += x * y;
                    }
                    *cv = acc;
                }
            }
        });
        c
    }

    /// C = A^T * B (i.e., Gram-style product over the row axis).
    pub fn matmul_at(&self, b: &Matrix) -> Matrix {
        self.matmul_at_with(b, &Pool::auto())
    }

    /// Row-banded parallel A^T * B: every band scans p = 0..k in order
    /// and updates only its own C rows, so per-element accumulation order
    /// matches the sequential kernel (bitwise stable).
    pub fn matmul_at_with(&self, b: &Matrix, pool: &Pool) -> Matrix {
        assert!(
            self.rows == b.rows,
            "matmul_at dim mismatch: [{}x{}]^T * [{}x{}]",
            self.rows,
            self.cols,
            b.rows,
            b.cols
        );
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        run_banded(pool, m, n, 2 * m * k * n, &mut c.data, |i0, cband| {
            for p in 0..k {
                let arow = &self.data[p * m..(p + 1) * m];
                let brow = &b.data[p * n..(p + 1) * n];
                for (ci, crow) in cband.chunks_exact_mut(n).enumerate() {
                    let a = arow[i0 + ci];
                    if a == 0.0 {
                        continue;
                    }
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += a * bv;
                    }
                }
            }
        });
        c
    }

    pub fn add(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
        }
    }

    pub fn sub(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&b.data).map(|(x, y)| x - y).collect(),
        }
    }

    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |a, &x| a.max(x.abs()))
    }

    /// Column slice [.., j0..j1) as a new matrix.
    pub fn cols_range(&self, j0: usize, j1: usize) -> Matrix {
        assert!(j0 <= j1 && j1 <= self.cols);
        let mut m = Matrix::zeros(self.rows, j1 - j0);
        for i in 0..self.rows {
            m.row_mut(i)
                .copy_from_slice(&self.row(i)[j0..j1]);
        }
        m
    }

    /// Symmetrize in place: (A + A^T)/2 — cleans accumulation asymmetry.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self.get(i, j) + self.get(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;

    #[test]
    fn matmul_hand_example() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_bt_matches_explicit_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(13, 7, &mut rng, 1.0);
        let b = Matrix::random(9, 7, &mut rng, 1.0);
        let got = a.matmul_bt(&b);
        let want = a.matmul(&b.transpose());
        assert_close(&got.data, &want.data, 1e-12);
    }

    #[test]
    fn matmul_at_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Matrix::random(11, 5, &mut rng, 1.0);
        let b = Matrix::random(11, 8, &mut rng, 1.0);
        let got = a.matmul_at(&b);
        let want = a.transpose().matmul(&b);
        assert_close(&got.data, &want.data, 1e-12);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Matrix::random(6, 6, &mut rng, 1.0);
        let i = Matrix::identity(6);
        assert_close(&a.matmul(&i).data, &a.data, 1e-15);
        assert_close(&i.matmul(&a).data, &a.data, 1e-15);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let a = Matrix::random(17, 33, &mut rng, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn f32_roundtrip() {
        let data: Vec<f32> = vec![0.5, -1.25, 3.75, 2.0];
        let m = Matrix::from_f32(2, 2, &data);
        assert_eq!(m.to_f32(), data);
    }

    #[test]
    fn frob_norm_example() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn cols_range_extracts() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let c = a.cols_range(1, 3);
        assert_eq!(c.data, vec![2., 3., 5., 6.]);
    }

    #[test]
    fn random_spd_is_symmetric() {
        let mut rng = Rng::new(5);
        let s = Matrix::random_spd(12, &mut rng);
        let d = s.sub(&s.transpose()).max_abs();
        assert!(d < 1e-9);
    }

    #[test]
    #[should_panic(expected = "2x3")]
    fn from_vec_reports_shape_on_mismatch() {
        let _ = Matrix::from_vec(2, 3, vec![1.0; 5]);
    }

    /// Sizes above PAR_MIN_WORK so Pool::exact(4) genuinely multi-bands.
    #[test]
    fn parallel_products_bitwise_match_single_thread() {
        let mut rng = Rng::new(21);
        let a = Matrix::random(97, 211, &mut rng, 1.0);
        let b = Matrix::random(211, 53, &mut rng, 1.0);
        let p1 = Pool::exact(1);
        for threads in [2usize, 4, 7] {
            let pn = Pool::exact(threads);
            assert_eq!(
                a.matmul_with(&b, &p1).data,
                a.matmul_with(&b, &pn).data,
                "matmul diverged at {threads} threads"
            );
            let bt = b.transpose();
            assert_eq!(
                a.matmul_bt_with(&bt, &p1).data,
                a.matmul_bt_with(&bt, &pn).data,
                "matmul_bt diverged at {threads} threads"
            );
            let g = Matrix::random(211, 97, &mut rng, 1.0);
            assert_eq!(
                g.matmul_at_with(&b, &p1).data,
                g.matmul_at_with(&b, &pn).data,
                "matmul_at diverged at {threads} threads"
            );
            assert_eq!(
                a.transpose_with(&p1).data,
                a.transpose_with(&pn).data,
                "transpose diverged at {threads} threads"
            );
        }
    }

    /// The banded kernels must agree bitwise with a naive triple loop:
    /// both accumulate each output element over k in ascending order.
    #[test]
    fn parallel_matmul_bitwise_matches_naive_reference() {
        let mut rng = Rng::new(22);
        let (m, k, n) = (71, 130, 41);
        let a = Matrix::random(m, k, &mut rng, 1.0);
        let b = Matrix::random(k, n, &mut rng, 1.0);
        let mut want = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a.get(i, p) * b.get(p, j);
                }
                want.set(i, j, acc);
            }
        }
        let got = a.matmul_with(&b, &Pool::exact(4));
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn parallel_band_split_handles_tiny_and_odd_rows() {
        let mut rng = Rng::new(23);
        let pool = Pool::exact(8); // more workers than rows
        for (m, k, n) in [(1usize, 9usize, 7usize), (3, 4, 2), (5, 1, 5)] {
            let a = Matrix::random(m, k, &mut rng, 1.0);
            let b = Matrix::random(k, n, &mut rng, 1.0);
            let got = a.matmul_with(&b, &pool);
            let want = a.matmul_with(&b, &Pool::exact(1));
            assert_eq!(got.data, want.data);
        }
    }
}
