//! `aasvd` — the leader CLI: pretrain, compress, evaluate and serve models
//! through the three-layer runtime.
//!
//! Subcommands:
//!   pretrain  --config base [--steps N]            train + checkpoint
//!   compress  --config base --method aa_svd --ratio 0.6 [--out path]
//!   eval      --config base [--compressed path]    PPL + zero-shot battery
//!   generate  --config base --prompt "..."         decode via the server
//!   info                                           manifest + configs

use aasvd::compress::{compress_model, Method};
use aasvd::eval::{all_tasks_accuracy, compressed_ppl, dense_ppl, display_ppl, ModelRef, Table};
use aasvd::experiments::{setup, Knobs};
use aasvd::model::lowrank::{load_blocks, save_blocks};
use aasvd::refine::RefineOptions;
use aasvd::runtime::Engine;
use aasvd::serve::{Event, GenParams, ServedModel, Server};
use aasvd::util::cli::Args;
use anyhow::{bail, Result};
use std::io::Write;

fn main() -> Result<()> {
    let args = Args::parse_env(
        "AA-SVD coordinator: anchored & adaptive SVD compression of LLMs",
    );
    let cmd = args.positional.first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "compress" => cmd_compress(&args),
        "eval" => cmd_eval(&args),
        "generate" => cmd_generate(&args),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: aasvd <pretrain|compress|eval|generate|info> [flags]\n\
                 run with --help after a subcommand for flags"
            );
            Ok(())
        }
    }
}

pub fn method_by_name(name: &str, refine: RefineOptions) -> Result<Method> {
    Ok(match name {
        "naive_svd" => Method::naive_svd(),
        "asvd" => Method::asvd(),
        "svd_llm" => Method::svd_llm(),
        "dobi" => Method::dobi(),
        "dobi_q" => Method::dobi_q(),
        "aa_svd" => Method::aa_svd(refine),
        "aa_svd_q" => Method::aa_svd_q(refine),
        other => match aasvd::compress::Objective::from_name(other) {
            Some(o) => Method::ablation(o, Some(refine)),
            None => bail!("unknown method '{other}'"),
        },
    })
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let knobs = Knobs::parse(args, "base");
    let steps = args.usize("steps", knobs.pretrain_steps, "training steps");
    args.finish_or_help();
    let engine = Engine::new("artifacts")?;
    let cfg = engine.entry(&knobs.config)?.config.clone();
    let (params, result) = aasvd::train::pretrain(
        &engine,
        &cfg,
        &aasvd::train::PretrainOptions {
            steps,
            ..Default::default()
        },
    )?;
    std::fs::create_dir_all("checkpoints")?;
    let path = aasvd::train::pretrain::checkpoint_path(&cfg);
    params.save(&path)?;
    aasvd::train::pretrain::save_loss_curve(
        &result,
        &format!("checkpoints/{}_loss.json", cfg.name),
    )?;
    println!(
        "pretrained '{}' for {steps} steps: loss {:.3} -> {:.3} ({:.0}s, {} tokens) -> {path}",
        cfg.name,
        result.losses.first().map(|x| x.1).unwrap_or(0.0),
        result.final_loss,
        result.secs,
        result.tokens_seen
    );
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let knobs = Knobs::parse(args, "base");
    let method_name = args.str("method", "aa_svd", "compression method");
    let ratio = args.f64("ratio", 0.6, "parameter ratio");
    let out = args.str(
        "out",
        &format!("checkpoints/{}_{}_{}.aat", knobs.config, method_name, ratio),
        "output path",
    );
    args.finish_or_help();
    let ctx = setup(&knobs)?;
    let method = method_by_name(&method_name, knobs.refine())?;
    let t0 = std::time::Instant::now();
    let cm = compress_model(&ctx.engine, &ctx.cfg, &ctx.params, &ctx.calib, &method, ratio)?;
    save_blocks(&cm.blocks, &out)?;
    println!(
        "compressed '{}' with {method_name} @ {ratio} in {:.1}s on {} threads \
         (collect {:.1}s, solve {:.1}s, refine {:.1}s) -> {out}",
        knobs.config,
        t0.elapsed().as_secs_f64(),
        aasvd::util::pool::auto_threads(),
        cm.report.secs_collect,
        cm.report.secs_solve,
        cm.report.secs_refine,
    );
    println!(
        "achieved parameter ratio: {:.3} (per-linear ranks: {:?})",
        cm.allocation.achieved_ratio(&ctx.cfg),
        cm.allocation.ranks
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let knobs = Knobs::parse(args, "base");
    let compressed = args.str("compressed", "", "path to compressed blocks (.aat)");
    args.finish_or_help();
    let ctx = setup(&knobs)?;
    let blocks = if compressed.is_empty() {
        None
    } else {
        Some(load_blocks(&ctx.cfg, &compressed)?)
    };
    let mut table = Table::new(
        &format!(
            "eval — {} {}",
            knobs.config,
            if blocks.is_some() { "(compressed)" } else { "(dense)" }
        ),
        &["metric", "value"],
    );
    for (domain, batches) in &ctx.eval {
        let ppl = match &blocks {
            None => dense_ppl(&ctx.engine, &ctx.cfg, &ctx.params, batches)?,
            Some(b) => compressed_ppl(&ctx.engine, &ctx.cfg, &ctx.params, b, batches)?,
        };
        table.row(vec![format!("ppl/{}", domain.name()), display_ppl(ppl)]);
    }
    let model_ref = match &blocks {
        None => ModelRef::Dense(&ctx.params),
        Some(b) => ModelRef::Compressed(&ctx.params, b),
    };
    let (per_task, avg) = all_tasks_accuracy(
        &ctx.engine,
        &ctx.cfg,
        &model_ref,
        ctx.n_task_instances,
        ctx.task_seed,
    )?;
    for (task, acc) in per_task {
        table.row(vec![format!("acc/{}", task.name()), format!("{acc:.3}")]);
    }
    table.row(vec!["acc/avg".into(), format!("{avg:.3}")]);
    println!("{}", table.render());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let knobs = Knobs::parse(args, "base");
    let prompt = args.str("prompt", "the cat", "prompt text");
    let max_new = args.usize("max-new", 48, "tokens to generate");
    let temp = args.f64("temperature", 0.0, "sampling temperature") as f32;
    let compressed = args.str("compressed", "", "compressed blocks (.aat)");
    args.finish_or_help();
    let ctx = setup(&knobs)?;
    let model = if compressed.is_empty() {
        ServedModel::Dense(ctx.params.clone())
    } else {
        ServedModel::Compressed(ctx.params.clone(), load_blocks(&ctx.cfg, &compressed)?)
    };
    let server = Server::start(ctx.cfg.clone(), model);
    let completion = server
        .submit(
            &prompt,
            GenParams {
                max_new_tokens: max_new,
                temperature: temp,
                ..Default::default()
            },
        )
        .map_err(|e| anyhow::anyhow!("submit failed: {e}"))?;
    print!("{prompt}│");
    std::io::stdout().flush()?;
    let resp = loop {
        match completion.next_event() {
            Some(Event::Token(t)) => {
                print!("{}", t.ch);
                std::io::stdout().flush()?;
            }
            Some(Event::Done(resp)) => break resp,
            Some(Event::Cancelled { reason, .. }) => {
                println!();
                bail!("request retired: {reason}");
            }
            None => bail!("serve worker went away mid-request"),
        }
    };
    println!();
    println!(
        "[{} tokens, ttft {:.0} ms, total {:.0} ms]",
        resp.tokens_generated,
        resp.ttft * 1e3,
        resp.latency * 1e3
    );
    drop(completion);
    server.shutdown();
    Ok(())
}

fn cmd_info() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    println!("artifact dir: {}", engine.manifest.dir.display());
    for (name, entry) in &engine.manifest.configs {
        println!(
            "config '{name}': d={} heads={} layers={} ff={} vocab={} \
             params={} artifacts={}",
            entry.config.d_model,
            entry.config.n_heads,
            entry.config.n_layers,
            entry.config.d_ff,
            entry.config.vocab,
            entry.param_layout.total,
            entry.artifacts.len()
        );
    }
    Ok(())
}
