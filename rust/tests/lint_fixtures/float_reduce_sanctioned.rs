// aasvd-lint: path=src/linalg/matrix.rs

// In a sanctioned banded-kernel file the same reduction is the whole
// point: this is where accumulation order is pinned. No violation.
pub fn band_dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>()
}
