// aasvd-lint: path=src/eval/fixture.rs

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        // aasvd-lint: allow(float-cmp): fixture justification — inputs proven finite one line above (they are not)
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}
