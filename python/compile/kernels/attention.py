"""Layer-1 Pallas kernel: flash-style causal attention (single head).

Used by the runtime integration test + kernel benches; the full-model HLO
artifacts use the jnp attention (XLA fuses it well on CPU), but this kernel
demonstrates the paper-relevant point that the compressed models' attention
remains a standard dense kernel — factorization only touches the
projections.

Hardware adaptation of GPU flash attention: the (q_tiles, kv_tiles) grid
streams K/V tiles through VMEM while the running max / normalizer / output
accumulator stay resident in VMEM scratch across the kv axis (kv fastest).
Causality is handled per-tile via global index comparison, skipping nothing
(no masking shortcut) to keep the schedule static.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .cov import pick_block

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, bq, bkv):
    qi = pl.program_id(0)
    ki = pl.program_id(1)
    nkv = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # causal mask on global indices
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _finalize():
        o_ref[...] = acc_ref[...] / l_ref[...]


def attention_head(q, k, v, scale, *, block_q: int | None = None,
                   block_kv: int | None = None, interpret: bool = True):
    """Causal single-head attention. q,k,v: [t, hd] -> [t, hd]."""
    t, hd = q.shape
    bq = block_q or pick_block(t, 64)
    bkv = block_kv or pick_block(t, 64)
    grid = (t // bq, t // bkv)
    import functools
    kern = functools.partial(_flash_kernel, scale=scale, bq=bq, bkv=bkv)
    scratch = [
        pltpu.VMEM((bq, 1), jnp.float32),   # running max
        pltpu.VMEM((bq, 1), jnp.float32),   # running normalizer
        pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, hd), lambda i, j: (i, 0)),
            pl.BlockSpec((bkv, hd), lambda i, j: (j, 0)),
            pl.BlockSpec((bkv, hd), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, hd), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, hd), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
