//! Block-level local refinement (Algorithm 2, step 9), invoked per
//! block by the streaming compression session (`compress::run`).

pub mod driver;
pub mod schedule;

pub use driver::{refine_block, RefineOptions, RefineReport};
pub use schedule::CosineSchedule;
