//! FNV-1a 64-bit content hashing for checkpoint integrity.
//!
//! The compress-run checkpoint protocol (`compress/run.rs`,
//! `runtime/manifest.rs`) fingerprints run inputs and verifies shard /
//! stream-snapshot files with a streaming FNV-1a 64 hash:
//! dependency-free, byte-order stable, and fast enough to hash
//! activation snapshots without showing up in profiles. Not
//! cryptographic — it guards against truncation and accidental edits,
//! not adversaries.

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Streaming FNV-1a 64 hasher.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64 { state: FNV_OFFSET }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    /// Hash a `u64` in little-endian byte order.
    pub fn update_u64(&mut self, v: u64) {
        self.update(&v.to_le_bytes());
    }

    /// Hash an f32 slice by bit pattern (little-endian), so hashes are
    /// exact under the repo's bitwise-equality contract.
    pub fn update_f32s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.update(&x.to_bits().to_le_bytes());
        }
    }

    pub fn update_i32s(&mut self, xs: &[i32]) {
        for &x in xs {
            self.update(&x.to_le_bytes());
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot FNV-1a 64 of a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

/// Fixed-width lowercase hex of a hash. Hashes cross into JSON as hex
/// strings, never numbers: the repo's JSON numbers are f64 and cannot
/// hold a u64 exactly.
pub fn to_hex(v: u64) -> String {
    format!("{v:016x}")
}

/// Parse a hash serialized by [`to_hex`].
pub fn from_hex(s: &str) -> Option<u64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let mut h = Fnv64::new();
        h.update(b"foo");
        h.update(b"bar");
        assert_eq!(h.finish(), fnv1a64(b"foobar"));
    }

    #[test]
    fn order_and_content_sensitive() {
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
        let mut a = Fnv64::new();
        a.update_f32s(&[1.0, 2.0]);
        let mut b = Fnv64::new();
        b.update_f32s(&[2.0, 1.0]);
        assert_ne!(a.finish(), b.finish());
        // -0.0 and 0.0 hash differently: bit-pattern, not value
        let mut c = Fnv64::new();
        c.update_f32s(&[0.0]);
        let mut d = Fnv64::new();
        d.update_f32s(&[-0.0]);
        assert_ne!(c.finish(), d.finish());
    }

    #[test]
    fn hex_roundtrip() {
        for v in [0u64, 1, 0xdeadbeef, u64::MAX, fnv1a64(b"x")] {
            let s = to_hex(v);
            assert_eq!(s.len(), 16);
            assert_eq!(from_hex(&s), Some(v));
        }
        assert_eq!(from_hex("xyz"), None);
        assert_eq!(from_hex("00"), None);
        assert_eq!(from_hex("zzzzzzzzzzzzzzzz"), None);
    }
}
