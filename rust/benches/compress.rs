//! Compression-path perf: covariance accumulation (Rust f64 vs the Pallas
//! cov_accum artifact through PJRT) and the CompressLayer closed form at
//! `base` shapes. These are the hot loops of Algorithm 1/2.

use aasvd::bench::Bench;
use aasvd::compress::{compress_layer, CovTriple};
use aasvd::runtime::{Engine, Value};
use aasvd::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(2);
    let d = 256usize;
    let chunk = 512usize;

    let x: Vec<f32> = (0..chunk * d).map(|_| rng.normal()).collect();
    let y: Vec<f32> = (0..chunk * d).map(|_| rng.normal()).collect();
    let flops = 3.0 * 2.0 * (chunk * d * d) as f64; // three accumulators

    b.run(
        &format!("cov_triple rust f64 d={d} chunk={chunk}"),
        Some(flops),
        || {
            let mut cov = CovTriple::new(d);
            cov.add_chunk(&x, &y);
            std::hint::black_box(cov);
        },
    );
    b.run(
        &format!("cov same-path rust f64 d={d} chunk={chunk}"),
        Some(flops / 3.0),
        || {
            let mut cov = CovTriple::new(d);
            cov.add_chunk_same(&x);
            std::hint::black_box(cov);
        },
    );

    // Pallas kernel through PJRT (includes literal transfer per call)
    if let Ok(engine) = Engine::new("artifacts") {
        if engine.entry("base").is_ok() {
            let chunk_k = engine.entry("base").unwrap().cov_chunk;
            let xk: Vec<f32> = (0..chunk_k * d).map(|_| rng.normal()).collect();
            let c = vec![0f32; d * d];
            engine.warmup("base", &["cov_accum_d"]).unwrap();
            b.run(
                &format!("cov pallas/pjrt d={d} chunk={chunk_k}"),
                Some(2.0 * (chunk_k * d * d) as f64),
                || {
                    std::hint::black_box(
                        engine
                            .run("base", "cov_accum_d", &[Value::F32(&c), Value::F32(&xk)])
                            .unwrap(),
                    );
                },
            );
        }
    }

    // full CompressLayer closed form at base attention / MLP shapes
    for (m, n, k) in [(256usize, 256usize, 85usize), (704, 256, 128)] {
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal() * 0.02).collect();
        let a: Vec<f32> = (0..4 * n * n).map(|_| rng.normal()).collect();
        let mut cov = CovTriple::new(n);
        cov.add_chunk_same(&a);
        cov.mirror_same();
        let (c, s) = aasvd::compress::Objective::Anchored.assemble(&cov).unwrap();
        b.run(&format!("compress_layer {m}x{n} k={k}"), None, || {
            std::hint::black_box(compress_layer(&w, m, n, &c, &s, k));
        });
    }
    b.save("compress");
}
