//! AA-SVD: Anchored and Adaptive SVD for LLM compression.
//!
//! Three-layer reproduction of the paper: a Rust coordinator (this crate)
//! drives AOT-compiled JAX/Pallas artifacts through PJRT. Python never runs
//! on the request path. See DESIGN.md for the architecture and experiment
//! index, EXPERIMENTS.md for measured results.

pub mod bench;
pub mod compress;
pub mod data;
pub mod experiments;
pub mod eval;

pub mod linalg;
pub mod lint;
pub mod model;
pub mod refine;
pub mod runtime;
pub mod serve;
pub mod testkit;
pub mod train;
pub mod util;
