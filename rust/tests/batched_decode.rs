//! Batched-decode exactness: `decode_batch` must return, for every row,
//! the **bitwise identical** logits its session would get from a
//! per-session `decode_step` (and therefore from the full-prefix
//! `oracle_logits` recompute) — for every backend, batch size, worker
//! count, and batch composition, including compositions that change
//! between ticks as sessions are admitted and retired. Per-row failures
//! must be isolated: a bad row errors without advancing its session or
//! disturbing its neighbors. Artifact-free: runs everywhere.

use aasvd::model::init::init_params;
use aasvd::model::lowrank::{exact_factors, BlockFactors};
use aasvd::model::{Config, FlatStore};
use aasvd::serve::{
    CompressedBackend, DecodeMode, DenseBackend, GenParams, ModelBackend, PagedKvOptions,
    Prefill, ServedModel, Server, ServerOptions, Session, SyntheticBackend,
};
use aasvd::util::pool::Pool;
use aasvd::util::rng::Rng;

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: logit {i} differs: {x} vs {y}"
        );
    }
}

fn tiny() -> Config {
    Config::builtin("tiny").unwrap()
}

fn truncated_blocks(cfg: &Config, params: &FlatStore) -> Vec<BlockFactors> {
    let mut blocks: Vec<BlockFactors> = (0..cfg.n_layers)
        .map(|i| exact_factors(cfg, params, i))
        .collect();
    for bf in blocks.iter_mut() {
        bf.set_rank("wq", 5);
        bf.set_rank("w_up", 8);
    }
    blocks
}

type BackendFactory = Box<dyn Fn() -> Box<dyn ModelBackend>>;

/// The three built-in backends as boxed factories over shared weights.
fn backend_factories() -> Vec<(&'static str, BackendFactory)> {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(71));
    let blocks = truncated_blocks(&cfg, &params);
    vec![
        ("dense", {
            let (cfg, params) = (cfg.clone(), params.clone());
            Box::new(move || {
                Box::new(DenseBackend::new(cfg.clone(), params.clone()))
                    as Box<dyn ModelBackend>
            })
        }),
        ("compressed", {
            let (cfg, params, blocks) = (cfg.clone(), params.clone(), blocks);
            Box::new(move || {
                Box::new(
                    CompressedBackend::new(cfg.clone(), params.clone(), blocks.clone())
                        .unwrap(),
                ) as Box<dyn ModelBackend>
            })
        }),
        ("synthetic", {
            let cfg = cfg.clone();
            Box::new(move || {
                Box::new(SyntheticBackend::new(cfg.clone())) as Box<dyn ModelBackend>
            })
        }),
    ]
}

/// Drive one backend at one batch size under one pool width: every
/// batched row must match a sequential `decode_step` twin and the
/// full-prefix oracle, bitwise, at every step.
fn check_batched_rows(
    label: &str,
    make: &dyn Fn() -> Box<dyn ModelBackend>,
    b: usize,
    threads: usize,
) {
    let mut batched = make();
    let mut seq = make();
    let mut prefixes: Vec<Vec<i32>> = (0..b)
        .map(|r| format!("req {r} says").bytes().map(|x| x as i32).collect())
        .collect();
    let mut sessions_a: Vec<Session> = Vec::with_capacity(b);
    let mut sessions_b: Vec<Session> = Vec::with_capacity(b);
    for p in &prefixes {
        let Prefill { session, logits, .. } = batched.prefill(p).unwrap();
        let twin = seq.prefill(p).unwrap();
        assert_bits_eq(&logits, &twin.logits, &format!("{label}: prefill"));
        sessions_a.push(session);
        sessions_b.push(twin.session);
    }
    for step in 0..6usize {
        let toks: Vec<i32> = (0..b)
            .map(|r| ((r * 37 + step * 13 + 7) % 256) as i32)
            .collect();
        let rows = Pool::exact(threads).install(|| {
            let mut refs: Vec<&mut Session> = sessions_a.iter_mut().collect();
            batched.decode_batch(&mut refs, &toks)
        });
        assert_eq!(rows.len(), b, "{label}: one result row per session");
        for (r, row) in rows.into_iter().enumerate() {
            let what = format!("{label} B={b} t={threads} row {r} step {step}");
            let row = row.unwrap_or_else(|e| panic!("{what}: {e}"));
            let want = seq.decode_step(&mut sessions_b[r], toks[r]).unwrap();
            assert_bits_eq(&row, &want, &what);
            prefixes[r].push(toks[r]);
            let oracle = batched.oracle_logits(&prefixes[r]).unwrap();
            assert_bits_eq(&row, &oracle, &format!("{what} vs oracle"));
        }
    }
    for (r, s) in sessions_a.iter().enumerate() {
        assert_eq!(s.len(), prefixes[r].len(), "{label}: session length");
    }
}

#[test]
fn decode_batch_matches_decode_step_and_oracle_bitwise() {
    for (label, make) in backend_factories() {
        for b in [1usize, 2, 8] {
            for threads in [1usize, 4] {
                check_batched_rows(label, make.as_ref(), b, threads);
            }
        }
    }
}

/// Paged twin of `check_batched_rows`: sessions live in a paged block
/// pool with a shared block-aligned prompt prefix (rows past the first
/// adopt cached blocks instead of recomputing), and every batched row
/// must still match an *unpaged* per-session `decode_step` twin bitwise.
fn check_paged_batched_rows(
    label: &str,
    make: &dyn Fn() -> Box<dyn ModelBackend>,
    b: usize,
    threads: usize,
) {
    let mut paged = make();
    assert!(
        paged.configure_paged(&PagedKvOptions {
            blocks: 256,
            block_tokens: 4,
            prefix_cache: true,
        }),
        "{label}: backend must accept paging"
    );
    let mut seq = make(); // unpaged twin
    let mut sessions_a: Vec<Session> = Vec::with_capacity(b);
    let mut sessions_b: Vec<Session> = Vec::with_capacity(b);
    for r in 0..b {
        // 24-byte shared span = 6 full blocks, then a distinct tail
        let prefix: Vec<i32> = format!("shared paged span prompt {r}")
            .bytes()
            .map(|x| x as i32)
            .collect();
        let pf = paged.prefill(&prefix).unwrap();
        let twin = seq.prefill(&prefix).unwrap();
        assert_bits_eq(&pf.logits, &twin.logits, &format!("{label}: paged prefill {r}"));
        if r == 0 {
            assert_eq!(pf.reused, 0, "{label}: row 0 is a cold prefill");
        } else {
            assert!(pf.reused >= 24, "{label}: row {r} reused {} tokens", pf.reused);
        }
        sessions_a.push(pf.session);
        sessions_b.push(twin.session);
    }
    for step in 0..6usize {
        let toks: Vec<i32> = (0..b)
            .map(|r| ((r * 37 + step * 13 + 7) % 256) as i32)
            .collect();
        let rows = Pool::exact(threads).install(|| {
            let mut refs: Vec<&mut Session> = sessions_a.iter_mut().collect();
            paged.decode_batch(&mut refs, &toks)
        });
        assert_eq!(rows.len(), b, "{label}: one result row per session");
        for (r, row) in rows.into_iter().enumerate() {
            let what = format!("{label} paged B={b} t={threads} row {r} step {step}");
            let row = row.unwrap_or_else(|e| panic!("{what}: {e}"));
            let want = seq.decode_step(&mut sessions_b[r], toks[r]).unwrap();
            assert_bits_eq(&row, &want, &what);
        }
    }
    // every block returns to the pool once sessions drop + trie resets
    drop(sessions_a);
    paged.kv_reset();
    let stats = paged.kv_pool_stats().unwrap();
    assert_eq!(stats.in_use, 0, "{label}: blocks leaked after drain");
    assert!(stats.peak <= stats.capacity, "{label}: pool overran its budget");
}

#[test]
fn paged_decode_batch_matches_unpaged_decode_step_bitwise() {
    for (label, make) in backend_factories() {
        if label == "synthetic" {
            continue; // declines paging (no KV cache to page)
        }
        for b in [1usize, 2, 8] {
            for threads in [1usize, 4] {
                check_paged_batched_rows(label, make.as_ref(), b, threads);
            }
        }
    }
}

/// Batch composition changes between ticks — staggered admits (fresh
/// prefills joining mid-stream) and retires (sessions dropped) — and
/// every surviving row still matches the oracle over its own prefix.
#[test]
fn changing_batch_composition_stays_bitwise_exact() {
    for (label, make) in backend_factories() {
        let mut be = make();
        // (prefix, session) pairs; composition is edited between ticks
        let mut live: Vec<(Vec<i32>, Session)> = Vec::new();
        let admit = |be: &mut dyn ModelBackend,
                     live: &mut Vec<(Vec<i32>, Session)>,
                     tag: usize| {
            let prefix: Vec<i32> =
                format!("late {tag}").bytes().map(|x| x as i32).collect();
            let pf = be.prefill(&prefix).unwrap();
            live.push((prefix, pf.session));
        };
        admit(be.as_mut(), &mut live, 0);
        admit(be.as_mut(), &mut live, 1);
        for tick in 0..8usize {
            match tick {
                2 => admit(be.as_mut(), &mut live, 2), // grow 2 -> 3
                4 => {
                    live.remove(0); // shrink mid-stream
                }
                5 => {
                    admit(be.as_mut(), &mut live, 3); // churn both ways
                    admit(be.as_mut(), &mut live, 4);
                    live.swap_remove(1);
                }
                _ => {}
            }
            let toks: Vec<i32> = (0..live.len())
                .map(|r| ((r * 41 + tick * 17 + 3) % 256) as i32)
                .collect();
            let rows = {
                let mut refs: Vec<&mut Session> =
                    live.iter_mut().map(|(_, s)| s).collect();
                be.decode_batch(&mut refs, &toks)
            };
            assert_eq!(rows.len(), live.len());
            for (r, row) in rows.into_iter().enumerate() {
                live[r].0.push(toks[r]);
                let oracle = be.oracle_logits(&live[r].0).unwrap();
                assert_bits_eq(
                    &row.unwrap(),
                    &oracle,
                    &format!("{label} tick {tick} row {r}"),
                );
            }
        }
    }
}

/// A foreign session mixed into a batch fails its own row only; the
/// healthy rows advance and stay bitwise equal to their oracle.
#[test]
fn per_row_failures_leave_neighbors_bitwise_exact() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(72));
    let mut dense = DenseBackend::new(cfg.clone(), params);
    let mut synth = SyntheticBackend::new(cfg);
    let mut pre_a: Vec<i32> = "alpha".bytes().map(|x| x as i32).collect();
    let mut pre_b: Vec<i32> = "beta".bytes().map(|x| x as i32).collect();
    let mut a = dense.prefill(&pre_a).unwrap().session;
    let mut foreign = synth.prefill(&[b'!' as i32]).unwrap().session;
    let mut b = dense.prefill(&pre_b).unwrap().session;
    for step in 0..3i32 {
        let toks = [step + 40, step + 50, step + 60];
        let rows = {
            let mut refs: Vec<&mut Session> = vec![&mut a, &mut foreign, &mut b];
            dense.decode_batch(&mut refs, &toks)
        };
        assert!(rows[1].is_err(), "foreign row must keep failing");
        pre_a.push(toks[0]);
        pre_b.push(toks[2]);
        let oracle_a = dense.oracle_logits(&pre_a).unwrap();
        let oracle_b = dense.oracle_logits(&pre_b).unwrap();
        assert_bits_eq(rows[0].as_ref().unwrap(), &oracle_a, "row 0");
        assert_bits_eq(rows[2].as_ref().unwrap(), &oracle_b, "row 2");
    }
    // the foreign session was never advanced
    assert_eq!(foreign.len(), 1);
    assert_eq!(a.len(), pre_a.len());
    assert_eq!(b.len(), pre_b.len());
}

/// A third-party backend that only implements the session API inherits a
/// working `decode_batch` from the trait's default implementation.
struct MinimalBackend(SyntheticBackend);

impl ModelBackend for MinimalBackend {
    fn artifact(&self) -> &'static str {
        "minimal"
    }
    fn prefill(&mut self, tokens: &[i32]) -> anyhow::Result<Prefill> {
        self.0.prefill(tokens)
    }
    fn decode_step(&mut self, session: &mut Session, token: i32) -> anyhow::Result<Vec<f32>> {
        self.0.decode_step(session, token)
    }
    fn oracle_logits(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        self.0.oracle_logits(tokens)
    }
}

#[test]
fn default_decode_batch_loops_decode_step() {
    let cfg = tiny();
    let mut be = MinimalBackend(SyntheticBackend::new(cfg.clone()));
    let mut twin = SyntheticBackend::new(cfg);
    let mut s0 = be.prefill(&[b'a' as i32]).unwrap().session;
    let mut s1 = be.prefill(&[b'k' as i32]).unwrap().session;
    let mut t0 = twin.prefill(&[b'a' as i32]).unwrap().session;
    let mut t1 = twin.prefill(&[b'k' as i32]).unwrap().session;
    let toks = [b'b' as i32, b'l' as i32];
    let rows = {
        let mut refs: Vec<&mut Session> = vec![&mut s0, &mut s1];
        be.decode_batch(&mut refs, &toks)
    };
    assert_eq!(rows.len(), 2);
    assert_bits_eq(
        rows[0].as_ref().unwrap(),
        &twin.decode_step(&mut t0, toks[0]).unwrap(),
        "default impl row 0",
    );
    assert_bits_eq(
        rows[1].as_ref().unwrap(),
        &twin.decode_step(&mut t1, toks[1]).unwrap(),
        "default impl row 1",
    );
    assert_eq!(s0.len(), 2);
    assert_eq!(s1.len(), 2);
    // empty batches are a no-op through the default impl too
    assert!(be.decode_batch(&mut [], &[]).is_empty());
}

/// Run a staggered multi-request batch through the engine and return the
/// completed texts plus final metrics.
fn engine_texts(
    cfg: &Config,
    model: ServedModel,
    mode: DecodeMode,
) -> (Vec<String>, aasvd::serve::ServeMetrics) {
    let server = Server::start_with(
        cfg.clone(),
        model,
        ServerOptions {
            max_batch: 3,
            decode: mode,
            ..Default::default()
        },
    );
    let completions: Vec<_> = (0..6)
        .map(|i| {
            server
                .submit(
                    &format!("prompt {i}"),
                    GenParams {
                        max_new_tokens: 4 + i,
                        temperature: if i % 2 == 0 { 0.0 } else { 0.8 },
                        top_k: if i % 2 == 0 { None } else { Some(12) },
                        seed: Some(900 + i as u64),
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    let texts = completions
        .into_iter()
        .map(|c| c.wait().expect("request completes").text)
        .collect();
    (texts, server.shutdown())
}

/// Engine level: the batched cached path generates the same tokens as the
/// sequential full-prefix recompute oracle for a staggered continuous
/// batch, and the occupancy metrics account for every batched call.
#[test]
fn engine_batched_decode_matches_recompute_oracle() {
    let cfg = tiny();
    let params = init_params(&cfg, &mut Rng::new(73));
    let blocks = truncated_blocks(&cfg, &params);

    for (label, cached_model, oracle_model) in [
        (
            "dense",
            ServedModel::Dense(params.clone()),
            ServedModel::Dense(params.clone()),
        ),
        (
            "compressed",
            ServedModel::Compressed(params.clone(), blocks.clone()),
            ServedModel::Compressed(params.clone(), blocks.clone()),
        ),
    ] {
        let (batched, m) = engine_texts(&cfg, cached_model, DecodeMode::Cached);
        let (oracle, m_oracle) = engine_texts(&cfg, oracle_model, DecodeMode::Recompute);
        assert_eq!(batched, oracle, "{label}: batched vs recompute texts");
        // batched-call accounting: every advanced row came through a
        // batched call, occupancy stays within the slot budget
        assert!(m.decode_batches > 0, "{label}");
        assert_eq!(m.decode_batches, m.decode_batch_rows.len(), "{label}");
        assert_eq!(
            m.decode_batch_rows.iter().sum::<f64>() as usize,
            m.decode_tokens,
            "{label}"
        );
        assert!(
            m.decode_batch_rows.iter().all(|&r| (1.0..=3.0).contains(&r)),
            "{label}: occupancy out of range: {:?}",
            m.decode_batch_rows
        );
        assert!(!m.decode_batch_histogram().is_empty(), "{label}");
        // the recompute oracle never issues batched calls
        assert_eq!(m_oracle.decode_batches, 0, "{label}");
        assert!(m_oracle.decode_batch_rows.is_empty(), "{label}");
    }
}
