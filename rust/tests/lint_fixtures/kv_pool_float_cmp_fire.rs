// aasvd-lint: path=src/serve/kv_pool.rs

pub fn colder(a: f64, b: f64) -> bool {
    matches!(a.partial_cmp(&b), Some(std::cmp::Ordering::Less))
}
