//! Ablation example: the four layer-wise objectives head-to-head on one
//! block, measuring the *layer-local* objective values the paper's Figure 2
//! taxonomy is about — before any refinement, without full-model eval.
//!
//! Demonstrates the library's lower-level API: covariance accumulation,
//! objective assembly, the Theorem 3.2 closed form, and objective_value.

use aasvd::compress::layer::objective_value;
use aasvd::compress::{compress_model, CovTriple, Method, Objective, ALL_OBJECTIVES};
use aasvd::eval::Table;
use aasvd::experiments::{setup, Knobs};
use aasvd::util::cli::Args;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::parse_env("per-layer objective ablation on one block");
    let knobs = Knobs::parse(&args, "small");
    let ratio = args.f64("ratio", 0.6, "compression ratio");
    args.finish_or_help();
    let ctx = setup(&knobs)?;

    // compress with the anchored objective so upstream blocks shift the
    // inputs of the block we analyze
    let method = Method::ablation(Objective::Anchored, None);
    let cm = compress_model(&ctx.engine, &ctx.cfg, &ctx.params, &ctx.calib, &method, ratio)?;

    // rebuild the covariance state of the *last* block's q/k/v tap by
    // replaying calibration data through dense vs compressed streams
    let last = ctx.cfg.n_layers - 1;
    let mut xs = aasvd::compress::pipeline::embed_batches(&ctx.cfg, &ctx.params, &ctx.calib);
    let mut xs_shift = xs.clone();
    for i in 0..last {
        let bp = aasvd::compress::pipeline::pack_block_params(&ctx.cfg, &ctx.params, i);
        for x in xs.iter_mut() {
            let out = ctx.engine.run(
                &ctx.cfg.name,
                "block_fwd",
                &[aasvd::runtime::Value::F32(&bp), aasvd::runtime::Value::F32(x)],
            )?;
            *x = out[0].f32.clone();
        }
        for x in xs_shift.iter_mut() {
            let out = ctx.engine.run(
                &ctx.cfg.name,
                "block_lr_fwd",
                &[
                    aasvd::runtime::Value::F32(&cm.blocks[i].factors.data),
                    aasvd::runtime::Value::F32(&cm.blocks[i].masks.data),
                    aasvd::runtime::Value::F32(x),
                ],
            )?;
            *x = out[0].f32.clone();
        }
    }
    // a_in taps of the last block on both streams
    let bp = aasvd::compress::pipeline::pack_block_params(&ctx.cfg, &ctx.params, last);
    let mut cov = CovTriple::new(ctx.cfg.d_model);
    for (x, xsft) in xs.iter().zip(&xs_shift) {
        let dense = ctx.engine.run(
            &ctx.cfg.name,
            "block_collect",
            &[aasvd::runtime::Value::F32(&bp), aasvd::runtime::Value::F32(x)],
        )?;
        let comp = ctx.engine.run(
            &ctx.cfg.name,
            "block_lr_collect",
            &[
                aasvd::runtime::Value::F32(&cm.blocks[last].factors.data),
                aasvd::runtime::Value::F32(&cm.blocks[last].masks.data),
                aasvd::runtime::Value::F32(xsft),
            ],
        )?;
        cov.add_chunk(&dense[1].f32, &comp[1].f32);
    }

    // solve wq under each objective; report the ANCHORED metric
    // ‖W X − W' X'‖² for all of them (the quantity that matters downstream)
    let (m, n) = ctx.cfg.linear_dims("wq");
    let w = ctx.params.view(&format!("blocks.{last}.wq"));
    let k = cm.allocation.rank_of("wq");
    let mut table = Table::new(
        &format!("objective ablation — block {last} wq, rank {k}"),
        &["objective", "‖WX−W'X'‖²", "vs best"],
    );
    let mut rows: Vec<(String, f64)> = Vec::new();
    for obj in ALL_OBJECTIVES {
        let factors = match obj.assemble(&cov) {
            None => aasvd::compress::compress_layer_plain(w, m, n, k),
            Some((c, s)) => aasvd::compress::compress_layer(w, m, n, &c, &s, k),
        };
        let err = objective_value(
            w,
            &factors.dense(),
            m,
            n,
            &cov.s_orig,
            &cov.c_cross,
            &cov.s_shift,
        );
        rows.push((obj.name().to_string(), err));
    }
    let best = rows.iter().map(|(_, e)| *e).fold(f64::INFINITY, f64::min);
    for (name, err) in rows {
        table.row(vec![
            name,
            format!("{err:.4e}"),
            format!("{:.2}x", err / best),
        ]);
    }
    table.emit("ablation_objectives")?;
    println!(
        "(anchored solves exactly the reported metric, so it is optimal by \
         Theorem 3.2 — the gap quantifies what ②/③ lose to distribution shift)"
    );
    Ok(())
}
