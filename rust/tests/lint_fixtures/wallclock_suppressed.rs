// aasvd-lint: path=src/linalg/fixture.rs

pub fn timed_solve() -> f64 {
    // aasvd-lint: allow(wallclock): fixture justification — timing feeds a report field, not a numeric result
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
