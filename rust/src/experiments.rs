//! Shared experiment harness: model setup, method evaluation, and the
//! paper's reference numbers — used by every bench_table*/bench_fig*
//! binary (DESIGN.md §5 experiment index).

use crate::compress::{BlockOutcome, CompressRun, CompressedModel, Method, RunOptions};
use crate::data::{Batcher, Corpus, Domain, TokenBatch, ALL_TASKS};
use crate::eval::{all_tasks_accuracy, compressed_ppl, dense_ppl, ModelRef};
use crate::model::{Config, FlatStore};
use crate::refine::RefineOptions;
use crate::runtime::Engine;
use crate::train::{load_or_pretrain, PretrainOptions};
use crate::util::cli::Args;
use anyhow::Result;

/// Everything a harness needs.
pub struct Ctx {
    pub engine: Engine,
    pub cfg: Config,
    pub params: FlatStore,
    /// calibration batches (wiki train, all full)
    pub calib: Vec<TokenBatch>,
    /// eval batches per domain (wiki/ptb/c4 test splits)
    pub eval: Vec<(Domain, Vec<TokenBatch>)>,
    pub n_task_instances: usize,
    pub task_seed: u64,
}

/// Standard experiment knobs, parsed uniformly across harnesses.
pub struct Knobs {
    pub config: String,
    pub calib_seqs: usize,
    pub eval_batches: usize,
    pub n_task_instances: usize,
    pub pretrain_steps: usize,
    pub refine_epochs: usize,
    pub refine_lr: f64,
    pub ratios: Vec<f64>,
    /// worker threads for the compression math (0 = auto-detect;
    /// the AA_SVD_THREADS env var overrides this flag)
    pub threads: usize,
}

impl Knobs {
    pub fn parse(args: &Args, default_cfg: &str) -> Knobs {
        Knobs {
            config: args.str("config", default_cfg, "model config name"),
            calib_seqs: args.usize("calib", 128, "calibration sequences"),
            eval_batches: args.usize("eval-batches", 10, "eval batches per domain"),
            n_task_instances: args.usize("task-n", 40, "instances per zero-shot task"),
            pretrain_steps: args.usize("pretrain-steps", 220, "pretraining steps"),
            refine_epochs: args.usize("refine-epochs", 8, "refinement epochs"),
            refine_lr: args.f64("refine-lr", 3e-5, "refinement base lr"),
            ratios: args
                .list("ratios", "0.8,0.6,0.4", "compression ratios")
                .iter()
                .map(|s| s.parse().expect("ratio"))
                .collect(),
            threads: args.usize(
                "threads",
                0,
                "worker threads for compression math (0 = auto; AA_SVD_THREADS overrides)",
            ),
        }
    }

    pub fn refine(&self) -> RefineOptions {
        RefineOptions {
            epochs: self.refine_epochs,
            base_lr: self.refine_lr,
            ..Default::default()
        }
    }
}

pub fn setup(knobs: &Knobs) -> Result<Ctx> {
    // every compression Pool::auto() downstream picks this up (unless the
    // AA_SVD_THREADS env var overrides it)
    crate::util::pool::set_global_threads(knobs.threads);
    let engine = Engine::new("artifacts")?;
    let cfg = engine.entry(&knobs.config)?.config.clone();
    let params = load_or_pretrain(
        &engine,
        &cfg,
        &PretrainOptions {
            steps: knobs.pretrain_steps,
            ..Default::default()
        },
    )?;
    let batcher = Batcher::new(cfg.batch, cfg.seq);
    let n_calib_batches = knobs.calib_seqs.div_ceil(cfg.batch);
    let wiki = Corpus::generate(Domain::Wiki, 1_500_000, 42);
    let calib: Vec<TokenBatch> = batcher
        .sequential(&wiki.train, n_calib_batches)
        .into_iter()
        .filter(|b| b.real_rows == cfg.batch)
        .collect();
    let mut eval = Vec::new();
    for domain in [Domain::Wiki, Domain::Ptb, Domain::C4] {
        let corpus = if domain == Domain::Wiki {
            wiki.test.clone()
        } else {
            Corpus::generate(domain, 400_000, 42).test
        };
        eval.push((domain, batcher.sequential(&corpus, knobs.eval_batches)));
    }
    Ok(Ctx {
        engine,
        cfg,
        params,
        calib,
        eval,
        n_task_instances: knobs.n_task_instances,
        task_seed: 2026,
    })
}

/// One evaluated table row.
#[derive(Clone, Debug)]
pub struct MethodEval {
    pub method: String,
    pub ratio: f64,
    pub ppl: Vec<(Domain, f64)>,
    pub task_acc: Vec<(crate::data::Task, f64)>,
    pub avg_acc: f64,
    pub secs: f64,
}

impl MethodEval {
    pub fn ppl_of(&self, d: Domain) -> f64 {
        self.ppl.iter().find(|(dd, _)| *dd == d).unwrap().1
    }
}

/// Evaluate the dense model (the "Dense / ratio 1.0" row).
pub fn eval_dense(ctx: &Ctx) -> Result<MethodEval> {
    let t0 = std::time::Instant::now();
    let mut ppl = Vec::new();
    for (domain, batches) in &ctx.eval {
        ppl.push((*domain, dense_ppl(&ctx.engine, &ctx.cfg, &ctx.params, batches)?));
    }
    let (task_acc, avg_acc) = all_tasks_accuracy(
        &ctx.engine,
        &ctx.cfg,
        &ModelRef::Dense(&ctx.params),
        ctx.n_task_instances,
        ctx.task_seed,
    )?;
    Ok(MethodEval {
        method: "dense".into(),
        ratio: 1.0,
        ppl,
        task_acc,
        avg_acc,
        secs: t0.elapsed().as_secs_f64(),
    })
}

/// Compress with `method` at `ratio`, then evaluate PPL + all tasks.
/// Per-block progress goes to the default observer (the shared log).
pub fn eval_compressed_method(
    ctx: &Ctx,
    method: &Method,
    ratio: f64,
) -> Result<(MethodEval, CompressedModel)> {
    eval_compressed_method_observed(ctx, method, ratio, &mut |o: &BlockOutcome| {
        crate::log_info!(
            "{} @ {ratio}: block {}/{} in {:.1}s",
            method.name,
            o.index + 1,
            o.total,
            o.secs
        );
    })
}

/// [`eval_compressed_method`] with an explicit per-block observer: the
/// harness sees each block as it completes (the streaming pipeline's
/// pacing hook) instead of waiting out the whole model silently.
pub fn eval_compressed_method_observed(
    ctx: &Ctx,
    method: &Method,
    ratio: f64,
    on_block: &mut dyn FnMut(&BlockOutcome),
) -> Result<(MethodEval, CompressedModel)> {
    let t0 = std::time::Instant::now();
    let mut run = CompressRun::new(
        &ctx.engine,
        &ctx.cfg,
        &ctx.params,
        &ctx.calib,
        method,
        ratio,
        RunOptions::in_memory(),
    )?;
    while let Some(outcome) = run.next_block()? {
        on_block(&outcome);
    }
    let cm = run.into_model()?;
    let mut ppl = Vec::new();
    for (domain, batches) in &ctx.eval {
        ppl.push((
            *domain,
            compressed_ppl(&ctx.engine, &ctx.cfg, &ctx.params, &cm.blocks, batches)?,
        ));
    }
    let (task_acc, avg_acc) = all_tasks_accuracy(
        &ctx.engine,
        &ctx.cfg,
        &ModelRef::Compressed(&ctx.params, &cm.blocks),
        ctx.n_task_instances,
        ctx.task_seed,
    )?;
    crate::log_info!(
        "{} @ {ratio}: wiki ppl {:.2}, avg acc {:.3} ({:.0}s)",
        method.name,
        ppl[0].1,
        avg_acc,
        t0.elapsed().as_secs_f64()
    );
    Ok((
        MethodEval {
            method: method.name.clone(),
            ratio,
            ppl,
            task_acc,
            avg_acc,
            secs: t0.elapsed().as_secs_f64(),
        },
        cm,
    ))
}

/// Task names in column order (paper Table 1 column set).
pub fn task_columns() -> Vec<&'static str> {
    ALL_TASKS.iter().map(|t| t.name()).collect()
}

/// Paper reference rows (LLaMA-7B, Table 1) for side-by-side display:
/// (ratio, method, wiki2 ppl, ptb ppl, c4 ppl, avg acc, drop %).
pub const PAPER_TABLE1: &[(f64, &str, f64, f64, f64, f64, f64)] = &[
    (1.0, "dense", 5.68, 8.34, 7.34, 0.55, 0.0),
    (0.8, "asvd", 11.14, 16.55, 15.93, 0.43, 21.1),
    (0.8, "svd_llm", 7.94, 16.22, 15.84, 0.44, 19.6),
    (0.8, "dobi", 8.54, 14.83, 10.01, 0.46, 16.7),
    (0.8, "aa_svd", 6.89, 12.30, 12.04, 0.50, 8.9),
    (0.8, "dobi_q", 6.08, 15.39, 7.83, 0.51, 7.3),
    (0.8, "aa_svd_q", 6.01, 8.97, 8.37, 0.53, 3.4),
    (0.6, "asvd", 1407.0, 3292.0, 1109.0, 0.30, 44.9),
    (0.6, "svd_llm", 13.11, 63.75, 49.83, 0.37, 32.6),
    (0.6, "dobi", 13.54, 46.38, 23.54, 0.38, 30.5),
    (0.6, "aa_svd", 8.35, 24.94, 18.97, 0.44, 19.1),
    (0.6, "dobi_q", 8.12, 43.85, 12.63, 0.47, 14.1),
    (0.6, "aa_svd_q", 7.09, 11.07, 11.25, 0.50, 8.9),
    (0.4, "asvd", 57057.0, 45218.0, 43036.0, 0.29, 46.5),
    (0.4, "svd_llm", 53.74, 438.58, 383.07, 0.31, 43.3),
    (0.4, "dobi", 46.18, 238.91, 190.62, 0.32, 42.0),
    (0.4, "aa_svd", 13.67, 74.64, 46.14, 0.37, 33.2),
    (0.4, "dobi_q", 9.95, 67.62, 17.94, 0.40, 26.6),
    (0.4, "aa_svd_q", 8.61, 24.44, 19.69, 0.44, 20.4),
];

pub fn paper_ref_table1(ratio: f64, method: &str) -> Option<(f64, f64)> {
    PAPER_TABLE1
        .iter()
        .find(|(r, m, ..)| *r == ratio && *m == method)
        .map(|&(_, _, wiki, _, _, acc, _)| (wiki, acc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_lookup() {
        let (wiki, acc) = paper_ref_table1(0.8, "aa_svd").unwrap();
        assert_eq!(wiki, 6.89);
        assert_eq!(acc, 0.50);
        assert!(paper_ref_table1(0.9, "aa_svd").is_none());
    }

    #[test]
    fn knobs_defaults() {
        let args = Args::parse(&["prog".to_string()], "");
        let k = Knobs::parse(&args, "small");
        assert_eq!(k.config, "small");
        assert_eq!(k.ratios, vec![0.8, 0.6, 0.4]);
    }
}
