//! Zero-shot multiple-choice evaluation (the accuracy columns).
//!
//! lm-eval-harness protocol: for each instance, score every choice as the
//! length-normalized NLL of the choice tokens given the context; the lowest
//! NLL wins. Choices are packed into full [B, T] batches across instances
//! to amortize artifact dispatch.

use crate::data::tasks::{Task, TaskInstance};
use crate::model::lowrank::{concat_factors, BlockFactors};
use crate::model::{Config, FlatStore};
use crate::runtime::{Engine, Value};
use anyhow::Result;

/// A scored choice: which (instance, choice) a batch row belongs to and
/// which token positions carry the choice continuation.
struct RowMeta {
    instance: usize,
    choice: usize,
    // NLL positions: predicting token t+1 from position t; the choice spans
    // [start, end) in token coordinates, so rows [start-1, end-1) of the
    // per-token NLL matrix score it.
    lo: usize,
    hi: usize,
}

/// Model-under-test: dense params or compressed blocks.
pub enum ModelRef<'a> {
    Dense(&'a FlatStore),
    Compressed(&'a FlatStore, &'a [BlockFactors]),
}

/// Accuracy of the model on `instances`.
pub fn task_accuracy(
    engine: &Engine,
    cfg: &Config,
    model: &ModelRef,
    instances: &[TaskInstance],
) -> Result<f64> {
    let t = cfg.seq;
    let b = cfg.batch;
    // build one row per (instance, choice)
    let mut rows: Vec<(Vec<i32>, RowMeta)> = Vec::new();
    for (ii, inst) in instances.iter().enumerate() {
        for (ci, choice) in inst.choices.iter().enumerate() {
            let ctx_bytes: Vec<i32> =
                inst.context.bytes().map(|x| x as i32).collect();
            let full: Vec<i32> = format!("{} {}", inst.context, choice)
                .bytes()
                .map(|x| x as i32)
                .collect();
            let start = ctx_bytes.len() + 1; // choice starts after the space
            let mut toks = full.clone();
            toks.truncate(t);
            let hi = toks.len();
            toks.resize(t, b' ' as i32);
            rows.push((
                toks,
                RowMeta {
                    instance: ii,
                    choice: ci,
                    lo: start.saturating_sub(1),
                    hi: hi.saturating_sub(1).max(start.saturating_sub(1)),
                },
            ));
        }
    }

    // score rows in batches
    let mut scores: Vec<Vec<f64>> = instances
        .iter()
        .map(|i| vec![f64::INFINITY; i.choices.len()])
        .collect();
    let precomputed = match model {
        ModelRef::Dense(_) => None,
        ModelRef::Compressed(_, blocks) => Some(concat_factors(blocks)),
    };

    for chunk in rows.chunks(b) {
        let mut tokens = Vec::with_capacity(b * t);
        let mut targets = Vec::with_capacity(b * t);
        for row in 0..b {
            let (toks, _) = &chunk[row.min(chunk.len() - 1)];
            tokens.extend_from_slice(toks);
            // next-token targets (shift left, last target arbitrary)
            targets.extend_from_slice(&toks[1..]);
            targets.push(b' ' as i32);
        }
        let nll = match (model, &precomputed) {
            (ModelRef::Dense(params), _) => engine.run(
                &cfg.name,
                "model_nll",
                &[
                    Value::F32(&params.data),
                    Value::I32(&tokens),
                    Value::I32(&targets),
                ],
            )?,
            (ModelRef::Compressed(params, _), Some((fs, ms))) => engine.run(
                &cfg.name,
                "model_lr_nll",
                &[
                    Value::F32(&params.data),
                    Value::F32(fs),
                    Value::F32(ms),
                    Value::I32(&tokens),
                    Value::I32(&targets),
                ],
            )?,
            _ => unreachable!(),
        };
        for (row, (_, meta)) in chunk.iter().enumerate() {
            let span = &nll[0].f32[row * t + meta.lo..row * t + meta.hi];
            let len = (meta.hi - meta.lo).max(1) as f64;
            // aasvd-lint: allow(float-reduce): sequential mean over one answer span in token order; scoring only, upstream NLLs come from the deterministic forward
            let s = span.iter().map(|&x| x as f64).sum::<f64>() / len;
            scores[meta.instance][meta.choice] = s;
        }
    }

    let correct = instances
        .iter()
        .enumerate()
        .filter(|(ii, inst)| {
            let best = scores[*ii]
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap();
            best == inst.answer
        })
        .count();
    Ok(correct as f64 / instances.len().max(1) as f64)
}

/// Evaluate all seven tasks; returns (per-task accuracy, mean).
pub fn all_tasks_accuracy(
    engine: &Engine,
    cfg: &Config,
    model: &ModelRef,
    n_per_task: usize,
    seed: u64,
) -> Result<(Vec<(Task, f64)>, f64)> {
    let mut per = Vec::new();
    let mut sum = 0.0;
    for task in crate::data::ALL_TASKS {
        let insts = task.dataset(n_per_task, seed);
        let acc = task_accuracy(engine, cfg, model, &insts)?;
        sum += acc;
        per.push((task, acc));
    }
    Ok((per, sum / crate::data::ALL_TASKS.len() as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Task;
    use crate::model::init::init_params;
    use crate::util::rng::Rng;

    #[test]
    fn untrained_model_scores_near_chance() {
        let Ok(engine) = Engine::new("artifacts") else { return };
        if engine.entry("tiny").is_err() {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(3));
        let insts = Task::Openb.dataset(40, 11);
        let acc = task_accuracy(&engine, &cfg, &ModelRef::Dense(&params), &insts).unwrap();
        // untrained: near chance (0.25), broad tolerance for small n
        assert!((0.0..=0.6).contains(&acc), "acc={acc}");
    }

    #[test]
    fn choice_spans_inside_sequence() {
        // context+choice strings of every task must fit tiny's seq len
        // budget for the scoring span math to hold in larger configs
        for task in crate::data::ALL_TASKS {
            for inst in task.dataset(20, 3) {
                for c in &inst.choices {
                    let total = inst.context.len() + 1 + c.len();
                    assert!(total <= 64, "{}: '{} {}'", task.name(), inst.context, c);
                }
            }
        }
    }
}
