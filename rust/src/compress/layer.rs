//! CompressLayer (Algorithm 1): the closed-form rank-k solution of
//! min ‖W A − W' B‖²_F from Theorem 3.2, plus the input-agnostic and
//! ASVD-style baselines.
//!
//! Steps (with C = A Bᵀ and S = B Bᵀ accumulated by cov.rs):
//!   3. S = R Rᵀ           (jittered Cholesky — Appendix A rank-deficient remark)
//!   4. M = W C S⁻¹ R = (W C) R⁻ᵀ      (identity S⁻¹R = R⁻ᵀ)
//!   5. [U_k, Σ_k, V_k] = SVD_k(M)
//!   6. U = U_k Σ_k,  V = R⁻ᵀ V_k      so  W' = U Vᵀ

use crate::linalg::{cholesky_jittered, right_mul_inv_rt, solve_upper_t, svd_k_with, Matrix};
use crate::util::pool::Pool;

/// Low-rank factors U [m×k], V [n×k] (active rank k, unpadded).
#[derive(Clone, Debug)]
pub struct Factors {
    pub u: Vec<f32>,
    pub v: Vec<f32>,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Factors {
    /// Write these unpadded rank-k factors into a block's padded factor
    /// buffers (kmax-column layout) and record the active rank in the
    /// mask store. The streaming pipeline calls this as each group's
    /// solves land.
    pub fn write_into(
        &self,
        cfg: &crate::model::Config,
        lin: &str,
        bf: &mut crate::model::lowrank::BlockFactors,
    ) {
        let kmax = cfg.kmax(lin);
        {
            let ub = bf.factors.view_mut(&format!("{lin}.u"));
            ub.fill(0.0);
            for i in 0..self.m {
                ub[i * kmax..i * kmax + self.k]
                    .copy_from_slice(&self.u[i * self.k..(i + 1) * self.k]);
            }
        }
        {
            let vb = bf.factors.view_mut(&format!("{lin}.v"));
            vb.fill(0.0);
            for i in 0..self.n {
                vb[i * kmax..i * kmax + self.k]
                    .copy_from_slice(&self.v[i * self.k..(i + 1) * self.k]);
            }
        }
        bf.set_rank(lin, self.k);
    }

    /// Materialize W' = U Vᵀ (row-major [m, n]).
    pub fn dense(&self) -> Vec<f32> {
        let (m, n, k) = (self.m, self.n, self.k);
        let mut w = vec![0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let u = self.u[i * k + p];
                if u == 0.0 {
                    continue;
                }
                for j in 0..n {
                    w[i * n + j] += u * self.v[j * k + p];
                }
            }
        }
        w
    }
}

/// Default Tikhonov start for rank-deficient covariances.
pub const DEFAULT_EPS0: f64 = 1e-6;

/// Theorem 3.2 closed form ([`Pool::auto`] resolution). `w` is the dense
/// weight [m, n] row-major; `c` = A Bᵀ and `s` = B Bᵀ are [n, n].
pub fn compress_layer(w: &[f32], m: usize, n: usize, c: &Matrix, s: &Matrix, k: usize) -> Factors {
    compress_layer_with(w, m, n, c, s, k, &Pool::auto())
}

/// [`compress_layer`] on an explicit worker pool: the W·C product and the
/// truncated SVD (Gram product + tridiagonal eigensolve) run row-banded
/// on `pool`, so the per-group concurrent solves in `compress::pipeline`
/// never serialize on the eigensolver.
pub fn compress_layer_with(
    w: &[f32],
    m: usize,
    n: usize,
    c: &Matrix,
    s: &Matrix,
    k: usize,
    pool: &Pool,
) -> Factors {
    assert_eq!(w.len(), m * n);
    assert_eq!((c.rows, c.cols), (n, n));
    assert_eq!((s.rows, s.cols), (n, n));
    let k = k.min(m).min(n).max(1);

    let (r, _eps) = cholesky_jittered(s, DEFAULT_EPS0);
    let wm = Matrix::from_f32(m, n, w);
    // step 4: M = (W C) R^{-T}
    let wc = wm.matmul_with(c, pool);
    let mmat = right_mul_inv_rt(&wc, &r);
    // step 5
    let svd = svd_k_with(&mmat, k, pool);
    // step 6: U = U_k Σ_k ; V = R^{-T} V_k
    let mut u = vec![0f32; m * k];
    for i in 0..m {
        for p in 0..k {
            u[i * k + p] = (svd.u.get(i, p) * svd.s[p]) as f32;
        }
    }
    let v64 = solve_upper_t(&r, &svd.v); // R^T V = V_k  =>  V = R^{-T} V_k
    let v = v64.to_f32();
    Factors { u, v, m, n, k }
}

/// Objective ① baseline: plain truncated SVD of W (Eckart–Young).
pub fn compress_layer_plain(w: &[f32], m: usize, n: usize, k: usize) -> Factors {
    compress_layer_plain_with(w, m, n, k, &Pool::auto())
}

/// [`compress_layer_plain`] on an explicit worker pool.
pub fn compress_layer_plain_with(w: &[f32], m: usize, n: usize, k: usize, pool: &Pool) -> Factors {
    let k = k.min(m).min(n).max(1);
    let wm = Matrix::from_f32(m, n, w);
    let svd = svd_k_with(&wm, k, pool);
    let mut u = vec![0f32; m * k];
    for i in 0..m {
        for p in 0..k {
            u[i * k + p] = (svd.u.get(i, p) * svd.s[p]) as f32;
        }
    }
    Factors {
        u,
        v: svd.v.to_f32(),
        m,
        n,
        k,
    }
}

/// ASVD-style baseline: diagonal activation scaling,
/// W' = SVD_k(W diag(s)) diag(s)⁻¹ with s_j = (E[x_j²])^{α/2}.
pub fn compress_layer_asvd(
    w: &[f32],
    m: usize,
    n: usize,
    channel_scales: &[f64],
    alpha: f64,
    k: usize,
) -> Factors {
    compress_layer_asvd_with(w, m, n, channel_scales, alpha, k, &Pool::auto())
}

/// [`compress_layer_asvd`] on an explicit worker pool.
pub fn compress_layer_asvd_with(
    w: &[f32],
    m: usize,
    n: usize,
    channel_scales: &[f64],
    alpha: f64,
    k: usize,
    pool: &Pool,
) -> Factors {
    assert_eq!(channel_scales.len(), n);
    let k = k.min(m).min(n).max(1);
    let s: Vec<f64> = channel_scales
        .iter()
        .map(|&x| x.powf(alpha).max(1e-8))
        .collect();
    // W diag(s)
    let mut ws = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            ws.set(i, j, w[i * n + j] as f64 * s[j]);
        }
    }
    let svd = svd_k_with(&ws, k, pool);
    let mut u = vec![0f32; m * k];
    for i in 0..m {
        for p in 0..k {
            u[i * k + p] = (svd.u.get(i, p) * svd.s[p]) as f32;
        }
    }
    // V = diag(s)^{-1} V_k
    let mut v = vec![0f32; n * k];
    for j in 0..n {
        for p in 0..k {
            v[j * k + p] = (svd.v.get(j, p) / s[j]) as f32;
        }
    }
    Factors { u, v, m, n, k }
}

/// ‖W A − W' B‖²_F evaluated through covariances only:
/// tr(W S_a Wᵀ) − 2 tr(W' C_crossᵀ Wᵀ)… expanded with
/// C = A Bᵀ, S_a = A Aᵀ, S_b = B Bᵀ:
///   tr(W S_a Wᵀ) − 2 tr(W C W'ᵀ) + tr(W' S_b W'ᵀ).
pub fn objective_value(
    w: &[f32],
    wp: &[f32],
    m: usize,
    n: usize,
    s_a: &Matrix,
    c: &Matrix,
    s_b: &Matrix,
) -> f64 {
    let wm = Matrix::from_f32(m, n, w);
    let wpm = Matrix::from_f32(m, n, wp);
    let t1 = trace_quad(&wm, s_a, &wm);
    let t2 = trace_quad(&wm, c, &wpm);
    let t3 = trace_quad(&wpm, s_b, &wpm);
    t1 - 2.0 * t2 + t3
}

/// tr(A S Bᵀ) for A,B [m×n], S [n×n].
fn trace_quad(a: &Matrix, s: &Matrix, b: &Matrix) -> f64 {
    let as_ = a.matmul(s);
    let mut tr = 0.0;
    for i in 0..a.rows {
        let ar = as_.row(i);
        let br = b.row(i);
        for j in 0..a.cols {
            tr += ar[j] * br[j];
        }
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::cov::CovTriple;
    use crate::linalg::svd_k;
    use crate::testkit::approx::rel_err;
    use crate::testkit::prop;
    use crate::util::rng::Rng;

    /// direct ‖W A − W' B‖_F² on explicit activations
    fn direct_obj(w: &[f32], wp: &[f32], m: usize, n: usize, a: &[f32], b: &[f32]) -> f64 {
        let rows = a.len() / n;
        let mut total = 0.0;
        for r in 0..rows {
            let ar = &a[r * n..(r + 1) * n];
            let br = &b[r * n..(r + 1) * n];
            for i in 0..m {
                let wa: f64 = (0..n).map(|j| (w[i * n + j] * ar[j]) as f64).sum();
                let wb: f64 = (0..n).map(|j| (wp[i * n + j] * br[j]) as f64).sum();
                total += (wa - wb) * (wa - wb);
            }
        }
        total
    }

    #[test]
    fn objective_value_matches_direct() {
        let mut rng = Rng::new(1);
        let (m, n, rows) = (4, 6, 40);
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let wp: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let a: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
        let mut cov = CovTriple::new(n);
        cov.add_chunk(&a, &b);
        let got = objective_value(&w, &wp, m, n, &cov.s_orig, &cov.c_cross, &cov.s_shift);
        let want = direct_obj(&w, &wp, m, n, &a, &b);
        assert!((got - want).abs() < 1e-6 * want.max(1.0), "{got} vs {want}");
    }

    #[test]
    fn full_rank_recovers_exactly_when_b_eq_a() {
        // k = min(m,n) and B = A (invertible S): W' must equal W
        let mut rng = Rng::new(2);
        let (m, n, rows) = (5, 5, 64);
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let a: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
        let mut cov = CovTriple::new(n);
        cov.add_chunk(&a, &a);
        let f = compress_layer(&w, m, n, &cov.c_cross, &cov.s_shift, 5);
        assert!(rel_err(&f.dense(), &w) < 1e-4);
    }

    #[test]
    fn theorem_solution_beats_perturbations_and_random() {
        prop::check("thm32-optimality", 12, |case| {
            let n = 3 + case.rng.below(5);
            let m = 3 + case.rng.below(5);
            let rows = 8 * n;
            let k = 1 + case.rng.below(m.min(n) - 1);
            let w: Vec<f32> = (0..m * n).map(|_| case.rng.normal()).collect();
            let a: Vec<f32> = (0..rows * n).map(|_| case.rng.normal()).collect();
            // X' = X + noise
            let b: Vec<f32> = a.iter().map(|v| v + 0.2 * case.rng.normal()).collect();
            let mut cov = CovTriple::new(n);
            cov.add_chunk(&a, &b);
            let f = compress_layer(&w, m, n, &cov.c_cross, &cov.s_shift, k);
            let opt = direct_obj(&w, &f.dense(), m, n, &a, &b);
            // random rank-k competitors are never better
            for _ in 0..3 {
                let ru: Vec<f32> = (0..m * k).map(|_| case.rng.normal()).collect();
                let rv: Vec<f32> = (0..n * k).map(|_| case.rng.normal()).collect();
                let cand = Factors {
                    u: ru,
                    v: rv,
                    m,
                    n,
                    k,
                };
                assert!(direct_obj(&w, &cand.dense(), m, n, &a, &b) >= opt - 1e-6);
            }
            // small perturbations of the solution are never better
            for scale in [1e-3, 1e-2] {
                let pu: Vec<f32> = f
                    .u
                    .iter()
                    .map(|v| v + scale * case.rng.normal())
                    .collect();
                let cand = Factors {
                    u: pu,
                    v: f.v.clone(),
                    m,
                    n,
                    k,
                };
                assert!(
                    direct_obj(&w, &cand.dense(), m, n, &a, &b) >= opt - 1e-5 * opt.abs().max(1.0)
                );
            }
        });
    }

    #[test]
    fn corollary_reduces_to_whitening() {
        // B = A: Theorem 3.2 solution == SVD_k(W L) L^{-1} (SVD-LLM form)
        let mut rng = Rng::new(3);
        let (m, n, rows, k) = (6, 5, 80, 2);
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let a: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
        let mut cov = CovTriple::new(n);
        cov.add_chunk(&a, &a);
        let f_thm = compress_layer(&w, m, n, &cov.c_cross, &cov.s_shift, k);
        // explicit whitening construction
        let (r, _) = cholesky_jittered(&cov.s_shift, DEFAULT_EPS0);
        let wl = Matrix::from_f32(m, n, &w).matmul(&r);
        let svd = svd_k(&wl, k);
        let mut wrec = Matrix::zeros(m, k);
        for i in 0..m {
            for p in 0..k {
                wrec.set(i, p, svd.u.get(i, p) * svd.s[p]);
            }
        }
        let vwhite = solve_upper_t(&r, &svd.v);
        let dense_white = wrec.matmul_bt(&vwhite).to_f32();
        assert!(rel_err(&f_thm.dense(), &dense_white) < 1e-4);
    }

    #[test]
    fn plain_svd_matches_eckart_young_error() {
        let mut rng = Rng::new(4);
        let (m, n, k) = (8, 6, 3);
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let f = compress_layer_plain(&w, m, n, k);
        let err: f64 = w
            .iter()
            .zip(&f.dense())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        let tail = crate::linalg::svd::tail_energy(&Matrix::from_f32(m, n, &w), k);
        assert!((err - tail).abs() < 1e-6 * tail.max(1e-9), "{err} vs {tail}");
    }

    #[test]
    fn asvd_full_rank_recovers_weight() {
        let mut rng = Rng::new(5);
        let (m, n) = (5, 4);
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let scales: Vec<f64> = (0..n).map(|_| 0.5 + rng.f64()).collect();
        let f = compress_layer_asvd(&w, m, n, &scales, 0.5, n);
        assert!(rel_err(&f.dense(), &w) < 1e-4);
    }

    #[test]
    fn asvd_beats_plain_on_anisotropic_inputs() {
        // when one input channel dominates, activation-aware truncation
        // should reduce the *data* error vs plain SVD
        let mut rng = Rng::new(6);
        let (m, n, rows, k) = (8, 8, 200, 2);
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        // activations: channel 0 has 10x the energy
        let mut a = vec![0f32; rows * n];
        for r in 0..rows {
            for j in 0..n {
                a[r * n + j] = rng.normal() * if j == 0 { 10.0 } else { 0.3 };
            }
        }
        let mut cov = CovTriple::new(n);
        cov.add_chunk_same(&a);
        cov.mirror_same();
        let plain = compress_layer_plain(&w, m, n, k);
        let asvd = compress_layer_asvd(&w, m, n, &cov.channel_scales(), 0.5, k);
        let e_plain = direct_obj(&w, &plain.dense(), m, n, &a, &a);
        let e_asvd = direct_obj(&w, &asvd.dense(), m, n, &a, &a);
        assert!(
            e_asvd < e_plain,
            "asvd {e_asvd} should beat plain {e_plain} on anisotropic data"
        );
    }

    #[test]
    fn handles_rank_deficient_covariance() {
        // activations confined to a 2D subspace of R^5
        let mut rng = Rng::new(7);
        let (m, n, rows, k) = (4, 5, 60, 2);
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut a = vec![0f32; rows * n];
        for r in 0..rows {
            let c1 = rng.normal();
            let c2 = rng.normal();
            for j in 0..n {
                a[r * n + j] = c1 * (j as f32 + 1.0) + c2 * ((j * j) as f32 - 2.0);
            }
        }
        let mut cov = CovTriple::new(n);
        cov.add_chunk_same(&a);
        cov.mirror_same();
        let f = compress_layer(&w, m, n, &cov.c_cross, &cov.s_shift, k);
        assert!(f.dense().iter().all(|v| v.is_finite()));
        // rank-2 data, rank-2 approx: data error should be tiny relative
        // to signal
        let err = direct_obj(&w, &f.dense(), m, n, &a, &a);
        let zero = vec![0f32; m * n];
        let sig = direct_obj(&w, &zero, m, n, &a, &a);
        assert!(err < 1e-3 * sig, "err {err} vs signal {sig}");
    }
}
