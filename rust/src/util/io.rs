//! Binary tensor archive: the on-disk format for model weights, optimizer
//! state and cached activations ("`.aat`" — AA-SVD tensors).
//!
//! Layout (little-endian):
//!   magic  b"AAT1"
//!   u32    n_tensors
//!   per tensor:
//!     u32        name_len, name bytes (utf-8)
//!     u32        n_dims,  u64 dims[n_dims]
//!     u64        data_len (f32 count), f32 data[data_len]

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let n = dims.iter().product();
        Tensor {
            dims,
            data: vec![0.0; n],
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TensorArchive {
    pub tensors: BTreeMap<String, Tensor>,
}

impl TensorArchive {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"AAT1");
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
            for &d in &t.dims {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        let tmp = path.as_ref().with_extension("tmp");
        std::fs::File::create(&tmp)
            .and_then(|mut f| f.write_all(&buf))
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path.as_ref())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TensorArchive> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?
            .read_to_end(&mut buf)?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated tensor archive");
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != b"AAT1" {
            bail!("bad magic: not a tensor archive");
        }
        let n_tensors = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut arch = TensorArchive::new();
        for _ in 0..n_tensors {
            let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
            let n_dims = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let mut dims = Vec::with_capacity(n_dims);
            for _ in 0..n_dims {
                dims.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize);
            }
            let len = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
            let bytes = take(&mut pos, len * 4)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            if dims.iter().product::<usize>() != data.len() {
                bail!("tensor '{name}' dims/data mismatch");
            }
            arch.tensors.insert(name, Tensor { dims, data });
        }
        Ok(arch)
    }
}

/// Write a string to a file, creating parent directories.
pub fn write_text(path: impl AsRef<Path>, text: &str) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path.as_ref(), text)
        .with_context(|| format!("writing {}", path.as_ref().display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("aasvd-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn archive_roundtrip() {
        let mut a = TensorArchive::new();
        a.insert("w", Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        a.insert("b", Tensor::new(vec![4], vec![0.5; 4]));
        let p = tmpfile("roundtrip.aat");
        a.save(&p).unwrap();
        let b = TensorArchive::load(&p).unwrap();
        assert_eq!(a.tensors, b.tensors);
    }

    #[test]
    fn empty_archive_roundtrip() {
        let a = TensorArchive::new();
        let p = tmpfile("empty.aat");
        a.save(&p).unwrap();
        assert_eq!(TensorArchive::load(&p).unwrap().tensors.len(), 0);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmpfile("garbage.aat");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(TensorArchive::load(&p).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut a = TensorArchive::new();
        a.insert("w", Tensor::new(vec![8], vec![1.0; 8]));
        let p = tmpfile("trunc.aat");
        a.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(TensorArchive::load(&p).is_err());
    }

    #[test]
    #[should_panic]
    fn tensor_dims_must_match_data() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }
}
