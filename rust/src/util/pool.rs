//! Scoped worker pool for the CPU-parallel compression math.
//!
//! The pool runs *borrowing* jobs through `std::thread::scope`: callers
//! hand over a `Vec` of closures that may capture references to stack data
//! (matrix bands, activation batches), and [`Pool::run`] returns their
//! results **in submission order** no matter which worker finished first. That ordering rule is
//! what makes every parallel reduction in the compression path
//! deterministic: partial results are always merged in a fixed order,
//! never completion order.
//!
//! Thread-count resolution for [`Pool::auto`] (first match wins):
//!   1. an installed pool context ([`Pool::install`], so nested linalg
//!      calls inherit the caller's budget instead of oversubscribing),
//!   2. the `AA_SVD_THREADS` env var (operator override),
//!   3. the process-global knob ([`set_global_threads`], fed by the
//!      `--threads` CLI flag),
//!   4. `std::thread::available_parallelism()`.
//! [`Pool::exact`] pins the count and ignores all four — the determinism
//! tests use it to compare 1-thread vs N-thread runs bit for bit.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static INSTALLED: Cell<usize> = const { Cell::new(0) };
}

/// Set the process-global default worker count (0 = hardware parallelism).
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.store(n, Ordering::SeqCst);
}

/// Resolve the effective worker count for [`Pool::auto`].
pub fn auto_threads() -> usize {
    let installed = INSTALLED.with(|c| c.get());
    if installed > 0 {
        return installed;
    }
    if let Ok(v) = std::env::var("AA_SVD_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    let global = GLOBAL_THREADS.load(Ordering::SeqCst);
    if global > 0 {
        return global;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// A fixed-width scoped worker pool. Holding one is free: threads are
/// spawned per [`Pool::run`] call and joined before it returns.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Context/env/global/hardware-resolved width (the normal entry point).
    pub fn auto() -> Pool {
        Pool {
            threads: auto_threads(),
        }
    }

    /// `requested` workers if nonzero, else [`Pool::auto`] resolution.
    pub fn new(requested: usize) -> Pool {
        if requested > 0 {
            Pool::exact(requested)
        } else {
            Pool::auto()
        }
    }

    /// Exactly `n` workers, ignoring every knob (determinism tests).
    pub fn exact(n: usize) -> Pool {
        Pool { threads: n.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` with this pool's width as the thread-local default, so
    /// `Pool::auto()` calls deeper in the stack (e.g. inside linalg
    /// kernels) inherit the caller's budget. The previous context is
    /// restored on exit — including when `f` unwinds (a caught panic,
    /// e.g. under the property-test harness, must not leak the width).
    pub fn install<R>(&self, f: impl FnOnce() -> R) -> R {
        struct Restore(usize);
        impl Drop for Restore {
            fn drop(&mut self) {
                INSTALLED.with(|c| c.set(self.0));
            }
        }
        let _restore = Restore(INSTALLED.with(|c| c.replace(self.threads)));
        f()
    }

    /// Run all jobs, at most `threads` at a time; results come back in
    /// submission order regardless of completion order. Jobs may borrow
    /// from the caller's stack (scoped threads). With one worker — or one
    /// job — everything runs inline on the calling thread.
    ///
    /// # Panics
    ///
    /// A panicking job panics this call: `std::thread::scope` joins every
    /// worker and then re-raises the first worker panic with its original
    /// payload. There is no deadlock and no corruption — no lock is held
    /// while a job runs, so the queue and the result buffer stay healthy,
    /// the surviving workers keep draining the queue (with a single
    /// panicking job every other job still executes), and the pool itself
    /// is stateless so later `run` calls are unaffected. On the inline
    /// single-worker path the panic propagates immediately and later jobs
    /// do not run.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            return jobs.into_iter().map(|f| f()).collect();
        }
        // LIFO handout is fine: results are re-sorted by submission index.
        let queue = Mutex::new(jobs.into_iter().enumerate().collect::<Vec<_>>());
        let done = Mutex::new(Vec::<(usize, T)>::with_capacity(n));
        // the guard drops inside this closure — no lock is held while a
        // job runs
        let next_job = || queue.lock().unwrap().pop();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    while let Some((i, f)) = next_job() {
                        let r = f();
                        done.lock().unwrap().push((i, r));
                    }
                });
            }
        });
        let mut done = done.into_inner().unwrap();
        done.sort_unstable_by_key(|p| p.0);
        done.into_iter().map(|(_, r)| r).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_submission_order() {
        let pool = Pool::exact(4);
        let jobs: Vec<_> = (0..32usize)
            .map(|i| {
                move || {
                    // later jobs finish first; order must still hold
                    std::thread::sleep(std::time::Duration::from_micros(
                        (32 - i as u64) * 50,
                    ));
                    i * i
                }
            })
            .collect();
        let out = pool.run(jobs);
        assert_eq!(out, (0..32usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_runs_inline() {
        let pool = Pool::exact(1);
        let out = pool.run((0..5usize).map(|i| move || i + 1).collect());
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
        assert!(pool.run::<usize, fn() -> usize>(Vec::new()).is_empty());
    }

    #[test]
    fn jobs_may_borrow_stack_data() {
        let data: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let pool = Pool::exact(3);
        let sums = pool.run(
            data.chunks(25)
                .map(|c| move || c.iter().sum::<f64>())
                .collect(),
        );
        assert_eq!(sums.len(), 4);
        assert_eq!(sums.iter().sum::<f64>(), data.iter().sum::<f64>());
    }

    #[test]
    fn nested_runs_do_not_deadlock() {
        let outer = Pool::exact(2);
        let out = outer.run(
            (0..4usize)
                .map(|i| {
                    move || {
                        let inner = Pool::exact(2);
                        inner
                            .run((0..4usize).map(|j| move || i * 10 + j).collect())
                            .iter()
                            .sum::<usize>()
                    }
                })
                .collect(),
        );
        assert_eq!(out, vec![6, 46, 86, 126]);
    }

    #[test]
    fn install_scopes_the_auto_width() {
        // exact() ignores context; auto() must see the installed width
        let pool = Pool::exact(3);
        let seen = pool.install(|| Pool::auto().threads());
        assert_eq!(seen, 3);
        // nested installs restore the outer context
        let outer = Pool::exact(2);
        let (inner_seen, outer_seen) = outer.install(|| {
            let inner = Pool::exact(5);
            let i = inner.install(|| Pool::auto().threads());
            (i, Pool::auto().threads())
        });
        assert_eq!(inner_seen, 5);
        assert_eq!(outer_seen, 2);
    }

    #[test]
    fn panicking_job_propagates_without_deadlock() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let ran = AtomicUsize::new(0);
        let pool = Pool::exact(3);
        let jobs: Vec<_> = (0..16usize)
            .map(|i| {
                let ran = &ran;
                move || {
                    if i == 5 {
                        panic!("job 5 exploded");
                    }
                    ran.fetch_add(1, Ordering::SeqCst);
                    i
                }
            })
            .collect();
        let panic = catch_unwind(AssertUnwindSafe(|| pool.run(jobs)))
            .expect_err("a job panic must reach the caller");
        // the scope re-raises the worker's panic with its original payload
        assert_eq!(panic.downcast_ref::<&str>(), Some(&"job 5 exploded"));
        // every surviving job still ran: the panicking worker died without
        // holding a lock, so the other workers drained the whole queue
        assert_eq!(ran.load(Ordering::SeqCst), 15);
        // the pool is stateless — a subsequent run returns submission-order
        // results as if nothing happened
        let out = pool.run((0..8usize).map(|i| move || i * 3).collect::<Vec<_>>());
        assert_eq!(out, (0..8usize).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn more_jobs_than_workers_all_complete() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        let pool = Pool::exact(2);
        pool.run(
            (0..100)
                .map(|_| {
                    let count = &count;
                    move || {
                        count.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect(),
        );
        assert_eq!(count.load(Ordering::SeqCst), 100);
    }
}
