//! Evaluation harness: perplexity, zero-shot accuracy, table rendering.

pub mod perplexity;
pub mod report;
pub mod zeroshot;

pub use perplexity::{compressed_ppl, dense_ppl, display_ppl, lowrank_ppl, quant_ppl};
pub use report::Table;
pub use zeroshot::{all_tasks_accuracy, task_accuracy, ModelRef};
