//! Tiny command-line parser (the offline build has no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and free
//! positional arguments. Every binary in this repo parses through here so
//! `--help` output stays consistent.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    /// (name, default, help) — recorded by the typed getters for --help.
    described: std::cell::RefCell<Vec<(String, String, String)>>,
    program: String,
    about: String,
}

impl Args {
    pub fn parse_env(about: &str) -> Args {
        let argv: Vec<String> = std::env::args().collect();
        Self::parse(&argv, about)
    }

    pub fn parse(argv: &[String], about: &str) -> Args {
        let mut a = Args {
            program: argv.first().cloned().unwrap_or_default(),
            about: about.to_string(),
            ..Default::default()
        };
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.bools.push(rest.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    fn describe(&self, name: &str, default: &str, help: &str) {
        self.described.borrow_mut().push((
            name.to_string(),
            default.to_string(),
            help.to_string(),
        ));
    }

    pub fn str(&self, name: &str, default: &str, help: &str) -> String {
        self.describe(name, default, help);
        self.flags
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&self, name: &str, default: usize, help: &str) -> usize {
        self.describe(name, &default.to_string(), help);
        self.flags
            .get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64, help: &str) -> u64 {
        self.describe(name, &default.to_string(), help);
        self.flags
            .get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64, help: &str) -> f64 {
        self.describe(name, &default.to_string(), help);
        self.flags
            .get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn flag(&self, name: &str, help: &str) -> bool {
        self.describe(name, "false", help);
        self.bools.iter().any(|b| b == name)
            || self
                .flags
                .get(name)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    /// Comma-separated list flag.
    pub fn list(&self, name: &str, default: &str, help: &str) -> Vec<String> {
        let raw = self.str(name, default, help);
        if raw.is_empty() {
            Vec::new()
        } else {
            raw.split(',').map(|s| s.trim().to_string()).collect()
        }
    }

    /// Print --help and exit if requested. Call after all getters ran once.
    pub fn finish_or_help(&self) {
        if self.bools.iter().any(|b| b == "help") {
            eprintln!("{}\n\n{}\n\nflags:", self.program, self.about);
            for (name, default, help) in self.described.borrow().iter() {
                eprintln!("  --{name:<20} {help} (default: {default})");
            }
            std::process::exit(0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.split_whitespace().map(String::from))
            .collect()
    }

    #[test]
    fn parses_kv_pairs() {
        let a = Args::parse(&argv("--model base --ratio=0.6 run"), "");
        assert_eq!(a.str("model", "tiny", ""), "base");
        assert_eq!(a.f64("ratio", 1.0, ""), 0.6);
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn boolean_flags() {
        let a = Args::parse(&argv("--verbose --steps 10"), "");
        assert!(a.flag("verbose", ""));
        assert!(!a.flag("quiet", ""));
        assert_eq!(a.usize("steps", 1, ""), 10);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(""), "");
        assert_eq!(a.str("model", "tiny", ""), "tiny");
        assert_eq!(a.usize("n", 7, ""), 7);
        assert_eq!(a.f64("lr", 0.1, ""), 0.1);
        assert_eq!(a.u64("seed", 42, ""), 42);
    }

    #[test]
    fn u64_flag_parses_large_seeds() {
        let a = Args::parse(&argv("--seed 18446744073709551615"), "");
        assert_eq!(a.u64("seed", 0, ""), u64::MAX);
    }

    #[test]
    fn list_flag_splits() {
        let a = Args::parse(&argv("--ratios 0.8,0.6,0.4"), "");
        assert_eq!(a.list("ratios", "", ""), vec!["0.8", "0.6", "0.4"]);
    }

    #[test]
    fn trailing_bool_flag() {
        let a = Args::parse(&argv("--steps 5 --fast"), "");
        assert_eq!(a.usize("steps", 0, ""), 5);
        assert!(a.flag("fast", ""));
    }
}
