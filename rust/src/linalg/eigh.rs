//! Symmetric eigendecomposition.
//!
//! Used for (a) the EVD variant of the whitening factorization L = Q Λ^{1/2}
//! (the SVD-LLM-V2 construction in Appendix A.2) and (b) the Gram-matrix
//! route to the truncated SVD in `svd.rs`.
//!
//! The production path ([`eigh`] / [`eigh_with`] / [`eigh_values`]) is the
//! classic dense symmetric pipeline from `linalg::tridiag`: Householder
//! tridiagonalization, implicit-shift QL on the tridiagonal, and a
//! row-banded rotation replay for the eigenvectors — O(n³) once, with the
//! parallel parts bitwise thread-count invariant.
//!
//! The cyclic Jacobi solver survives as [`eigh_jacobi`]: it is slow (up to
//! 60 full O(n³) sweeps of column-strided rotations) but its convergence
//! theory is independent of the QL shift strategy, which makes it the
//! ideal *oracle* for property tests — the two implementations share no
//! code beyond `Matrix`, so agreement on degenerate spectra (clustered,
//! rank-deficient, near-zero) is strong evidence both are right. It is
//! also the runtime fallback on the (pathological) inputs where QL fails
//! to deflate.

use super::matrix::Matrix;
use super::tridiag::{apply_rotations_with, householder_tridiag_with, ql_implicit_shift};
use crate::util::pool::Pool;

/// Eigendecomposition of a symmetric matrix: S = Q diag(λ) Q^T.
/// Returns (eigenvalues descending, Q with matching column order).
/// Pool resolution follows [`Pool::auto`] (installed context → env →
/// global knob → hardware).
pub fn eigh(s: &Matrix) -> (Vec<f64>, Matrix) {
    eigh_with(s, &Pool::auto())
}

/// [`eigh`] on an explicit worker pool. Results are bitwise identical for
/// any worker count (see `linalg::tridiag` for the contract).
pub fn eigh_with(s: &Matrix, pool: &Pool) -> (Vec<f64>, Matrix) {
    assert_eq!(s.rows, s.cols, "eigh needs a square matrix");
    let n = s.rows;
    let mut a = s.clone();
    a.symmetrize();

    let mut tri = householder_tridiag_with(&a, true, pool);
    let mut rots = Vec::new();
    if ql_implicit_shift(&mut tri.d, &mut tri.e, Some(&mut rots)).is_err() {
        // pathological spectrum: defer to the slow-but-stubborn oracle
        return eigh_jacobi(s);
    }
    let mut q = tri.q.expect("q requested from tridiagonalization");
    apply_rotations_with(&mut q, &rots, pool);
    sort_eigenpairs_desc(tri.d, q, n)
}

/// Eigenvalues only, descending — skips the Q back-transformation and the
/// O(n³) rotation replay entirely, leaving the cheap O(n²) QL core on top
/// of the reduction. Bitwise identical to the spectrum [`eigh`] returns
/// (both run the same reduction and the same serial QL recurrence).
pub fn eigh_values(s: &Matrix) -> Vec<f64> {
    eigh_values_with(s, &Pool::auto())
}

/// [`eigh_values`] on an explicit worker pool.
pub fn eigh_values_with(s: &Matrix, pool: &Pool) -> Vec<f64> {
    assert_eq!(s.rows, s.cols, "eigh needs a square matrix");
    let mut a = s.clone();
    a.symmetrize();
    let mut tri = householder_tridiag_with(&a, false, pool);
    if ql_implicit_shift(&mut tri.d, &mut tri.e, None).is_err() {
        return eigh_jacobi(s).0;
    }
    let mut vals = tri.d;
    vals.sort_by(|x, y| y.total_cmp(x));
    vals
}

/// Sort eigenpairs descending (NaN-safe via `total_cmp` — a pathological
/// Gram matrix must degrade to NaN output, never panic mid-compression)
/// and permute Q's columns to match.
fn sort_eigenpairs_desc(d: Vec<f64>, q: Matrix, n: usize) -> (Vec<f64>, Matrix) {
    let mut pairs: Vec<(f64, usize)> = d.into_iter().enumerate().map(|(i, v)| (v, i)).collect();
    pairs.sort_by(|x, y| y.0.total_cmp(&x.0));
    let vals: Vec<f64> = pairs.iter().map(|&(v, _)| v).collect();
    let mut qs = Matrix::zeros(n, n);
    for (newj, &(_, oldj)) in pairs.iter().enumerate() {
        for i in 0..n {
            qs.set(i, newj, q.get(i, oldj));
        }
    }
    (vals, qs)
}

/// Cyclic Jacobi eigendecomposition — retained as the property-test
/// oracle and the fallback for inputs where QL fails to deflate. Do not
/// call on the hot path: it is the O(n³)-per-sweep bottleneck the
/// tridiagonal pipeline replaced.
pub fn eigh_jacobi(s: &Matrix) -> (Vec<f64>, Matrix) {
    assert_eq!(s.rows, s.cols, "eigh needs a square matrix");
    let n = s.rows;
    let mut a = s.clone();
    a.symmetrize();
    let mut q = Matrix::identity(n);

    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius mass
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a.get(i, j) * a.get(i, j);
            }
        }
        let diag_scale: f64 = (0..n)
            .map(|i| a.get(i, i) * a.get(i, i))
            // aasvd-lint: allow(float-reduce): sequential diagonal mass in fixed index order; Jacobi convergence test, single-threaded
            .sum::<f64>()
            .max(1e-300);
        if off <= 1e-26 * diag_scale || !off.is_finite() {
            break;
        }
        for p in 0..n {
            for r in (p + 1)..n {
                let apr = a.get(p, r);
                if apr.abs() < 1e-300 {
                    continue;
                }
                let app = a.get(p, p);
                let arr = a.get(r, r);
                // Jacobi rotation: tan via the stable formula
                let tau = (arr - app) / (2.0 * apr);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s_ = t * c;

                // A <- J^T A J (only rows/cols p, r change)
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akr = a.get(k, r);
                    a.set(k, p, c * akp - s_ * akr);
                    a.set(k, r, s_ * akp + c * akr);
                }
                for k in 0..n {
                    let apk = a.get(p, k);
                    let ark = a.get(r, k);
                    a.set(p, k, c * apk - s_ * ark);
                    a.set(r, k, s_ * apk + c * ark);
                }
                // accumulate Q <- Q J
                for k in 0..n {
                    let qkp = q.get(k, p);
                    let qkr = q.get(k, r);
                    q.set(k, p, c * qkp - s_ * qkr);
                    q.set(k, r, s_ * qkp + c * qkr);
                }
            }
        }
    }

    let d: Vec<f64> = (0..n).map(|i| a.get(i, i)).collect();
    sort_eigenpairs_desc(d, q, n)
}

/// Whitening factor L = Q Λ^{1/2} with eigenvalues clamped at `floor·λmax`
/// (rank-deficient-safe EVD alternative to Cholesky; Appendix A.2).
pub fn evd_whitening_factor(s: &Matrix, floor: f64) -> Matrix {
    evd_whitening_factor_with(s, floor, &Pool::auto())
}

/// [`evd_whitening_factor`] on an explicit worker pool.
pub fn evd_whitening_factor_with(s: &Matrix, floor: f64, pool: &Pool) -> Matrix {
    let n = s.rows;
    let (vals, q) = eigh_with(s, pool);
    let lmax = vals.first().copied().unwrap_or(1.0).max(1e-300);
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        let lam = vals[j].max(floor * lmax);
        let sq = lam.sqrt();
        for i in 0..n {
            l.set(i, j, q.get(i, j) * sq);
        }
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;
    use crate::testkit::prop;
    use crate::util::rng::Rng;

    fn reconstruct(vals: &[f64], q: &Matrix) -> Matrix {
        let n = vals.len();
        let mut lam_qt = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                lam_qt.set(i, j, vals[i] * q.get(j, i));
            }
        }
        q.matmul(&lam_qt)
    }

    /// max |λ_fast − λ_oracle| relative to the spectrum scale, via the
    /// shared criterion in `testkit::approx` (the bench-smoke accuracy
    /// gate uses the same function, so test and CI enforce one contract).
    fn spectrum_gap(s: &Matrix) -> f64 {
        let fast = eigh_values(s);
        let (oracle, _) = eigh_jacobi(s);
        crate::testkit::approx::spectrum_gap(&fast, &oracle)
    }

    #[test]
    fn diag_matrix_eigs() {
        let s = Matrix::from_vec(3, 3, vec![3., 0., 0., 0., 1., 0., 0., 0., 2.]);
        let (vals, _) = eigh(&s);
        assert_close(&vals, &[3.0, 2.0, 1.0], 1e-12);
    }

    #[test]
    fn hand_2x2() {
        // [[2,1],[1,2]] -> eigs 3, 1
        let s = Matrix::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let (vals, q) = eigh(&s);
        assert_close(&vals, &[3.0, 1.0], 1e-12);
        let rec = reconstruct(&vals, &q);
        assert_close(&rec.data, &s.data, 1e-12);
    }

    #[test]
    fn random_spd_reconstructs_and_orthogonal() {
        let mut rng = Rng::new(7);
        for n in [2, 5, 17, 40] {
            let s = Matrix::random_spd(n, &mut rng);
            let (vals, q) = eigh(&s);
            // descending
            for w in vals.windows(2) {
                assert!(w[0] >= w[1] - 1e-9);
            }
            // orthogonal
            let qtq = q.matmul_at(&q);
            assert_close(&qtq.data, &Matrix::identity(n).data, 1e-9);
            // reconstruction
            let rec = reconstruct(&vals, &q);
            let rel = rec.sub(&s).frob_norm() / s.frob_norm();
            assert!(rel < 1e-10, "n={n} rel={rel}");
        }
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(8);
        let n = 12;
        let s = Matrix::random_spd(n, &mut rng);
        let tr: f64 = (0..n).map(|i| s.get(i, i)).sum();
        let (vals, _) = eigh(&s);
        assert!((vals.iter().sum::<f64>() - tr).abs() < 1e-8 * tr.abs());
    }

    #[test]
    fn evd_whitening_factor_reconstructs_pd() {
        let mut rng = Rng::new(9);
        let s = Matrix::random_spd(10, &mut rng);
        let l = evd_whitening_factor(&s, 0.0);
        let rec = l.matmul_bt(&l);
        let rel = rec.sub(&s).frob_norm() / s.frob_norm();
        assert!(rel < 1e-10, "rel={rel}");
    }

    #[test]
    fn evd_whitening_floor_regularizes_singular() {
        let x = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let s = x.matmul_bt(&x); // rank 1
        let l = evd_whitening_factor(&s, 1e-6);
        // L must be invertible: all columns have nonzero norm
        for j in 0..3 {
            let norm: f64 = (0..3).map(|i| l.get(i, j) * l.get(i, j)).sum();
            assert!(norm > 0.0);
        }
    }

    // ---- tridiagonal path vs the Jacobi oracle ----

    #[test]
    fn matches_jacobi_on_random_spd() {
        prop::check("eigh-vs-jacobi-spd", 16, |case| {
            let n = 2 + case.rng.below(30);
            let s = Matrix::random_spd(n, &mut case.rng);
            let gap = spectrum_gap(&s);
            assert!(gap < 1e-10, "n={n}: spectrum gap {gap:.3e}");
            // and eigenvectors actually diagonalize: S q_j == λ_j q_j
            let (vals, q) = eigh(&s);
            let sq = s.matmul(&q);
            for j in 0..n {
                for i in 0..n {
                    let diff = (sq.get(i, j) - vals[j] * q.get(i, j)).abs();
                    assert!(diff < 1e-8 * vals[0].max(1.0), "residual {diff}");
                }
            }
        });
    }

    #[test]
    fn matches_jacobi_on_clustered_spectra() {
        // repeated eigenvalues: S = Q diag(λ) Qᵀ with λ ∈ {3, 3, 3, 1, 1, …}
        prop::check("eigh-vs-jacobi-clustered", 10, |case| {
            let n = 4 + case.rng.below(16);
            let basis = Matrix::random(n, n, &mut case.rng, 1.0);
            let (q, _) = crate::linalg::qr::qr_thin(&basis);
            let lam: Vec<f64> = (0..n).map(|i| if i < n / 2 { 3.0 } else { 1.0 }).collect();
            let mut ql = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    ql.set(i, j, q.get(i, j) * lam[j]);
                }
            }
            let mut s = ql.matmul_bt(&q);
            s.symmetrize();
            let gap = spectrum_gap(&s);
            assert!(gap < 1e-9, "n={n}: clustered spectrum gap {gap:.3e}");
        });
    }

    #[test]
    fn matches_jacobi_on_rank_deficient_and_near_zero() {
        prop::check("eigh-vs-jacobi-degenerate", 10, |case| {
            let n = 3 + case.rng.below(20);
            // rank-1 Gram
            let x = Matrix::random(n, 1, &mut case.rng, 1.0);
            let s1 = x.matmul_bt(&x);
            assert!(spectrum_gap(&s1) < 1e-9, "rank-1 gap");
            // rank-deficient Gram (rank ~ n/3) with a near-zero floor
            let r = 1 + n / 3;
            let y = Matrix::random(n, r, &mut case.rng, 1.0);
            let mut s2 = y.matmul_bt(&y);
            for i in 0..n {
                let v = s2.get(i, i) + 1e-14;
                s2.set(i, i, v);
            }
            assert!(spectrum_gap(&s2) < 1e-9, "rank-deficient gap");
        });
    }

    #[test]
    fn eigh_values_bitwise_matches_full_path_spectrum() {
        // the values-only path runs the same reduction and QL recurrence,
        // so the spectra agree bitwise, not just approximately
        let mut rng = Rng::new(77);
        for n in [3usize, 9, 33] {
            let s = Matrix::random_spd(n, &mut rng);
            let (full, _) = eigh(&s);
            assert_eq!(eigh_values(&s), full, "n={n}");
        }
    }

    #[test]
    fn nan_input_degrades_without_panicking() {
        // regression: the old partial_cmp(..).unwrap() sort panicked on
        // NaN from a pathological Gram matrix; total_cmp must not
        let mut s = Matrix::random_spd(6, &mut Rng::new(12));
        s.set(2, 3, f64::NAN);
        s.set(3, 2, f64::NAN);
        let (vals, q) = eigh(&s);
        assert_eq!(vals.len(), 6);
        assert_eq!(q.rows, 6);
        let (jvals, _) = eigh_jacobi(&s);
        assert_eq!(jvals.len(), 6);
        let v = eigh_values(&s);
        assert_eq!(v.len(), 6);
    }
}
