//! The four layer-wise compression objectives (paper Figure 2, left).
//!
//! Each objective reduces to an instance of Theorem 3.2's problem
//! min ‖W A − W' B‖²_F by choosing (A, B); the solver only ever sees the
//! covariances C = A Bᵀ and S = B Bᵀ assembled here from a `CovTriple`.

use super::cov::CovTriple;
use crate::linalg::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Objective {
    /// ① ‖W − W'‖²: plain truncated SVD of the weights (Eckart–Young).
    InputAgnostic,
    /// ② ‖W X − W' X‖²: whitening on original activations
    ///    (DRONE / ASVD / SVD-LLM family; A = B = X).
    InputAware,
    /// ③ ‖W X' − W' X'‖²: whitening on shifted activations
    ///    (Dobi-SVD family; A = B = X').
    ShiftAware,
    /// ④ ‖W X − W' X'‖²: anchored to original outputs, conditioned on
    ///    shifted inputs (this paper; A = X, B = X').
    Anchored,
}

pub const ALL_OBJECTIVES: [Objective; 4] = [
    Objective::InputAgnostic,
    Objective::InputAware,
    Objective::ShiftAware,
    Objective::Anchored,
];

impl Objective {
    pub fn name(&self) -> &'static str {
        match self {
            Objective::InputAgnostic => "input_agnostic",
            Objective::InputAware => "input_aware",
            Objective::ShiftAware => "shift_aware",
            Objective::Anchored => "anchored",
        }
    }

    pub fn from_name(s: &str) -> Option<Objective> {
        ALL_OBJECTIVES.iter().copied().find(|o| o.name() == s)
    }

    /// Does this objective need the shifted activations X'?
    /// (If not, the pipeline can skip the extra collection pass.)
    pub fn needs_shift(&self) -> bool {
        matches!(self, Objective::ShiftAware | Objective::Anchored)
    }

    /// Assemble (C = A Bᵀ, S = B Bᵀ) for Theorem 3.2, or None for the
    /// data-free objective ①.
    pub fn assemble(&self, cov: &CovTriple) -> Option<(Matrix, Matrix)> {
        match self {
            Objective::InputAgnostic => None,
            Objective::InputAware => Some((cov.s_orig.clone(), cov.s_orig.clone())),
            Objective::ShiftAware => Some((cov.s_shift.clone(), cov.s_shift.clone())),
            Objective::Anchored => Some((cov.c_cross.clone(), cov.s_shift.clone())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::approx::assert_close;
    use crate::util::rng::Rng;

    fn triple(d: usize, seed: u64) -> CovTriple {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..64 * d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = x.iter().map(|v| v + 0.1 * rng.normal()).collect();
        let mut cov = CovTriple::new(d);
        cov.add_chunk(&x, &y);
        cov
    }

    #[test]
    fn names_roundtrip() {
        for o in ALL_OBJECTIVES {
            assert_eq!(Objective::from_name(o.name()), Some(o));
        }
        assert_eq!(Objective::from_name("bogus"), None);
    }

    #[test]
    fn input_agnostic_is_data_free() {
        assert!(Objective::InputAgnostic.assemble(&triple(4, 1)).is_none());
        assert!(!Objective::InputAgnostic.needs_shift());
    }

    #[test]
    fn aware_variants_pick_right_matrices() {
        let cov = triple(5, 2);
        let (c, s) = Objective::InputAware.assemble(&cov).unwrap();
        assert_close(&c.data, &cov.s_orig.data, 1e-12);
        assert_close(&s.data, &cov.s_orig.data, 1e-12);
        let (c, s) = Objective::ShiftAware.assemble(&cov).unwrap();
        assert_close(&c.data, &cov.s_shift.data, 1e-12);
        assert_close(&s.data, &cov.s_shift.data, 1e-12);
        let (c, s) = Objective::Anchored.assemble(&cov).unwrap();
        assert_close(&c.data, &cov.c_cross.data, 1e-12);
        assert_close(&s.data, &cov.s_shift.data, 1e-12);
    }

    #[test]
    fn anchored_equals_input_aware_when_no_shift() {
        // X' == X  =>  objective ④ assembles the same (C, S) as ②
        let d = 6;
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..32 * d).map(|_| rng.normal()).collect();
        let mut cov = CovTriple::new(d);
        cov.add_chunk(&x, &x);
        let (c4, s4) = Objective::Anchored.assemble(&cov).unwrap();
        let (c2, s2) = Objective::InputAware.assemble(&cov).unwrap();
        assert_close(&c4.data, &c2.data, 1e-9);
        assert_close(&s4.data, &s2.data, 1e-9);
    }

    #[test]
    fn shift_requirements() {
        assert!(Objective::Anchored.needs_shift());
        assert!(Objective::ShiftAware.needs_shift());
        assert!(!Objective::InputAware.needs_shift());
    }
}
