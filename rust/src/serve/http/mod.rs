//! Pure-`std::net` HTTP/1.1 front door for the serving engine.
//!
//! Three layers, each testable on its own:
//!
//! - [`parse`] — request-head parsing with strict limits (head bytes,
//!   header count, body bytes) and typed 4xx mappings
//! - [`sse`] — response writing: status lines, JSON error bodies, and
//!   chunked server-sent-event streams with a deferred head
//! - [`server`] — the accept loop, per-connection threads (capped, shed
//!   inline with 429), lazy JSON request decoding via
//!   [`crate::util::json::JsonScan`], and the bridge from engine
//!   [`crate::serve::request::Event`]s onto the socket
//!
//! Requests are decoded lazily — the body is scanned for the handful of
//! fields the endpoint understands without building a `Json` tree, so a
//! megabyte of ignored fields costs a skip, not an allocation.
//!
//! The open-loop load harness in `bin/load.rs` drives this front door;
//! CI's `http-smoke` lane gates zero 5xx and a p99 TTFT ceiling over a
//! sustained profile (see README "HTTP API").

pub mod parse;
pub mod server;
pub mod sse;

pub use parse::{Limits, ParseError, RequestHead};
pub use server::{HttpOptions, HttpServer};
