"""Layer-2: JAX model definitions for the AA-SVD reproduction.

Everything here is build-time only: `aot.py` lowers the jitted entry points
to HLO text that the Rust coordinator loads through PJRT. Python never runs
on the request path.

Model family: small LLaMA-style decoders (RMSNorm, RoPE, causal MHA, SwiGLU)
with a byte-level vocabulary. Parameters travel as a single flat f32 vector
whose layout (`param_specs`) is exported in the artifact manifest so the
Rust side can pack/unpack by name.

Low-rank ("compressed") blocks replace every linear W[m,n] by
(U * mask) @ V^T with U[m,kmax], V[n,kmax], kmax = min(m,n). The rank mask
zero-pads unused components so one HLO artifact serves every rank
allocation; masking U also zeroes gradients of padded components during
block-level refinement (Algorithm 2, step 9).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Config(NamedTuple):
    """Transformer hyper-parameters (mirrors rust/src/model/config.rs)."""

    name: str
    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 352
    rope_theta: float = 10000.0
    # shapes baked into the AOT artifacts
    batch: int = 8        # calibration/eval batch
    seq: int = 64         # sequence length
    refine_batch: int = 32
    train_batch: int = 16

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# The model-family configs double as stand-ins for the paper's model zoo
# (LLaMA-7B ... Qwen-2.5-7B); see DESIGN.md §3.
CONFIGS = {
    "tiny": Config("tiny", d_model=64, n_heads=2, n_layers=2, d_ff=176,
                   batch=4, seq=16, refine_batch=8, train_batch=8),
    "small": Config("small", d_model=128, n_heads=4, n_layers=4, d_ff=352),
    "base": Config("base", d_model=256, n_heads=4, n_layers=6, d_ff=704),
    # Table-2 family (roles: llama2-13b, llama3-1b, llama3-8b, qwen2.5-7b)
    "wide": Config("wide", d_model=320, n_heads=5, n_layers=7, d_ff=880),
    "compact": Config("compact", d_model=96, n_heads=3, n_layers=5, d_ff=264),
    "deep": Config("deep", d_model=192, n_heads=4, n_layers=8, d_ff=528),
    "alt": Config("alt", d_model=256, n_heads=8, n_layers=6, d_ff=640),
}

# The seven linear layers inside every block, with (out, in) dims as a
# function of (d_model, d_ff). Order is the canonical flattening order.
BLOCK_LINEARS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def linear_dims(cfg: Config, name: str) -> tuple[int, int]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "w_gate": (f, d), "w_up": (f, d), "w_down": (d, f),
    }[name]


# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------

def block_param_specs(cfg: Config, i: int) -> list:
    d = cfg.d_model
    specs = [(f"blocks.{i}.attn_norm", (d,))]
    for name in ("wq", "wk", "wv", "wo"):
        specs.append((f"blocks.{i}.{name}", linear_dims(cfg, name)))
    specs.append((f"blocks.{i}.mlp_norm", (d,)))
    for name in ("w_gate", "w_up", "w_down"):
        specs.append((f"blocks.{i}.{name}", linear_dims(cfg, name)))
    return specs


def param_specs(cfg: Config) -> list:
    specs = [("embed", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        specs.extend(block_param_specs(cfg, i))
    specs.append(("final_norm", (cfg.d_model,)))
    specs.append(("lm_head", (cfg.vocab, cfg.d_model)))
    return specs


def kmax(cfg: Config, name: str) -> int:
    m, n = linear_dims(cfg, name)
    return min(m, n)


def factor_specs_one_block(cfg: Config) -> list:
    """Trainable tensors of one compressed block, canonical order."""
    d = cfg.d_model
    specs = [("attn_norm", (d,)), ("mlp_norm", (d,))]
    for name in BLOCK_LINEARS:
        m, n = linear_dims(cfg, name)
        k = kmax(cfg, name)
        specs.append((f"{name}.u", (m, k)))
        specs.append((f"{name}.v", (n, k)))
    return specs


def mask_specs_one_block(cfg: Config) -> list:
    return [(f"{name}.mask", (kmax(cfg, name),)) for name in BLOCK_LINEARS]


def _sizes(specs):
    return [int(np.prod(s)) for _, s in specs]


def unflatten(flat, specs):
    """Split a flat vector into a dict of named, shaped arrays."""
    out, off = {}, 0
    for (name, shape), size in zip(specs, _sizes(specs)):
        out[name] = jax.lax.dynamic_slice_in_dim(flat, off, size).reshape(shape)
        off += size
    return out


def flatten(tree, specs):
    return jnp.concatenate([tree[name].reshape(-1) for name, _ in specs])


def total_size(specs) -> int:
    return sum(_sizes(specs))


# ---------------------------------------------------------------------------
# Core ops
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps: float = 1e-5):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope_tables(cfg: Config, t: int):
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, hd, 2) / hd))
    ang = np.arange(t)[:, None] * inv[None, :]
    return jnp.asarray(np.cos(ang), jnp.float32), jnp.asarray(np.sin(ang), jnp.float32)


def apply_rope(x, cos, sin):
    # x: [B, H, T, hd]; tables [T, hd/2]; pairs are (even, odd) interleaved.
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def attention(cfg: Config, q, k, v):
    # q,k,v: [B, T, d] -> causal MHA -> [B, T, d]
    b, t, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim
    cos, sin = rope_tables(cfg, t)

    def split(x):
        return x.reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return out.transpose(0, 2, 1, 3).reshape(b, t, d)


def _lin(x, w):
    """y = W x with row-major W[m,n]; x[..., n] -> [..., m]."""
    return x @ w.T


def block_inner(cfg: Config, p: dict, x, prefix: str = ""):
    """Dense block forward returning intermediate activations.

    Returns (y, a_in, o_in, m_in, d_in): the inputs seen by q/k/v, wo,
    gate/up, and w_down — exactly the X_j matrices Algorithm 2 collects.
    """
    g = lambda n: p[prefix + n]
    a_in = rmsnorm(x, g("attn_norm"))
    q, k, v = _lin(a_in, g("wq")), _lin(a_in, g("wk")), _lin(a_in, g("wv"))
    o_in = attention(cfg, q, k, v)
    h = x + _lin(o_in, g("wo"))
    m_in = rmsnorm(h, g("mlp_norm"))
    gate = jax.nn.silu(_lin(m_in, g("w_gate")))
    d_in = gate * _lin(m_in, g("w_up"))
    y = h + _lin(d_in, g("w_down"))
    return y, a_in, o_in, m_in, d_in


def block_fwd(cfg: Config, p: dict, x, prefix: str = ""):
    return block_inner(cfg, p, x, prefix)[0]


# ---------------------------------------------------------------------------
# Low-rank (compressed) block
# ---------------------------------------------------------------------------

def _lr_lin(x, u, v, mask):
    """y = (U*mask) (V^T x): rank-masked factorized linear."""
    z = x @ v                      # [..., k]
    return (z * mask) @ u.T        # [..., m]


def block_lr_inner(cfg: Config, f: dict, masks: dict, x):
    lr = lambda n, h: _lr_lin(h, f[f"{n}.u"], f[f"{n}.v"], masks[f"{n}.mask"])
    a_in = rmsnorm(x, f["attn_norm"])
    q, k, v = lr("wq", a_in), lr("wk", a_in), lr("wv", a_in)
    o_in = attention(cfg, q, k, v)
    h = x + lr("wo", o_in)
    m_in = rmsnorm(h, f["mlp_norm"])
    gate = jax.nn.silu(lr("w_gate", m_in))
    d_in = gate * lr("w_up", m_in)
    y = h + lr("w_down", d_in)
    return y, a_in, o_in, m_in, d_in


def block_lr_fwd(cfg: Config, f: dict, masks: dict, x):
    return block_lr_inner(cfg, f, masks, x)[0]


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def model_hidden(cfg: Config, p: dict, tokens):
    x = p["embed"][tokens]
    for i in range(cfg.n_layers):
        x = block_fwd(cfg, p, x, prefix=f"blocks.{i}.")
    return rmsnorm(x, p["final_norm"])


def model_fwd(cfg: Config, p: dict, tokens):
    return _lin(model_hidden(cfg, p, tokens), p["lm_head"])


def model_lr_fwd(cfg: Config, p: dict, fs: list, masks: list, tokens):
    """Compressed model: dense embed/final_norm/head + low-rank blocks."""
    x = p["embed"][tokens]
    for f, m in zip(fs, masks):
        x = block_lr_fwd(cfg, f, m, x)
    return _lin(rmsnorm(x, p["final_norm"]), p["lm_head"])


def nll(logits, targets):
    """Per-token negative log-likelihood [B, T]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - tgt


# ---------------------------------------------------------------------------
# Fused AdamW steps (pretraining + block refinement)
# ---------------------------------------------------------------------------

def adamw_update(g, w, m, v, step, lr, wd=0.01, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    t = step.astype(jnp.float32) + 1.0
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    w = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * w)
    return w, m, v


def train_step(cfg: Config, params, m, v, step, lr, tokens, targets):
    specs = param_specs(cfg)

    def loss_fn(flat):
        logits = model_fwd(cfg, unflatten(flat, specs), tokens)
        return jnp.mean(nll(logits, targets))

    loss, g = jax.value_and_grad(loss_fn)(params)
    params, m, v = adamw_update(g, params, m, v, step, lr, wd=0.01)
    return params, m, v, loss


def refine_step(cfg: Config, train, m, v, step, lr, masks_flat, x_shift, y_target):
    """One AdamW step of block-level local refinement (Alg. 2, step 9).

    Minimizes || L_i(X) - L'_i(X') ||^2 over the block's low-rank factors
    and norm gains; `y_target = L_i(X)` is precomputed by the coordinator
    from the *dense* block on *original* inputs, anchoring the objective.
    """
    fspecs = factor_specs_one_block(cfg)
    mspecs = mask_specs_one_block(cfg)
    masks = unflatten(masks_flat, mspecs)

    def loss_fn(flat):
        f = unflatten(flat, fspecs)
        y = block_lr_fwd(cfg, f, masks, x_shift)
        return jnp.mean(jnp.square(y - y_target))

    loss, g = jax.value_and_grad(loss_fn)(train)
    train, m, v = adamw_update(g, train, m, v, step, lr, wd=0.0)
    return train, m, v, loss


# ---------------------------------------------------------------------------
# Jitted entry points (flat-vector signatures, ready for AOT lowering)
# ---------------------------------------------------------------------------

def entry_points(cfg: Config):
    """name -> (fn, example_args). All tensor args are flat f32 / i32."""
    specs = param_specs(cfg)
    fspecs = factor_specs_one_block(cfg)
    bspecs = block_param_specs(cfg, 0)
    mspecs = mask_specs_one_block(cfg)
    msize = total_size(mspecs)
    psize, fsize, bsize = total_size(specs), total_size(fspecs), total_size(bspecs)
    B, T, BR = cfg.batch, cfg.seq, cfg.refine_batch
    d = cfg.d_model
    f32, i32 = jnp.float32, jnp.int32

    def S(*shape, dtype=f32):
        return jax.ShapeDtypeStruct(shape, dtype)

    def strip(block_params):
        # block params arrive with bare names (no "blocks.i." prefix)
        return {name.split(".", 2)[-1]: val for name, val in block_params.items()}

    def split_all(factors_all, masks_all):
        fs, ms = [], []
        for i in range(cfg.n_layers):
            fflat = jax.lax.dynamic_slice_in_dim(factors_all, i * fsize, fsize)
            mflat = jax.lax.dynamic_slice_in_dim(masks_all, i * msize, msize)
            fs.append(unflatten(fflat, fspecs))
            ms.append(unflatten(mflat, mspecs))
        return fs, ms

    def ep_model_fwd(params, tokens):
        return (model_fwd(cfg, unflatten(params, specs), tokens),)

    def ep_model_nll(params, tokens, targets):
        logits = model_fwd(cfg, unflatten(params, specs), tokens)
        return (nll(logits, targets),)

    def ep_model_lr_nll(params, factors_all, masks_all, tokens, targets):
        p = unflatten(params, specs)
        fs, ms = split_all(factors_all, masks_all)
        logits = model_lr_fwd(cfg, p, fs, ms, tokens)
        return (nll(logits, targets),)

    def ep_model_lr_fwd(params, factors_all, masks_all, tokens):
        p = unflatten(params, specs)
        fs, ms = split_all(factors_all, masks_all)
        return (model_lr_fwd(cfg, p, fs, ms, tokens),)

    def ep_block_fwd(bp, x):
        return (block_fwd(cfg, strip(unflatten(bp, bspecs)), x),)

    def ep_block_collect(bp, x):
        return block_inner(cfg, strip(unflatten(bp, bspecs)), x)

    def ep_block_lr_fwd(fp, masks_flat, x):
        f = unflatten(fp, fspecs)
        mk = unflatten(masks_flat, mspecs)
        return (block_lr_fwd(cfg, f, mk, x),)

    def ep_block_lr_collect(fp, masks_flat, x):
        f = unflatten(fp, fspecs)
        mk = unflatten(masks_flat, mspecs)
        return block_lr_inner(cfg, f, mk, x)

    def ep_refine_step(train, m, v, step, lr, masks_flat, x_shift, y_target):
        return refine_step(cfg, train, m, v, step, lr, masks_flat,
                           x_shift, y_target)

    def ep_train_step(params, m, v, step, lr, tokens, targets):
        return train_step(cfg, params, m, v, step, lr, tokens, targets)

    return {
        "model_fwd": (ep_model_fwd, [S(psize), S(B, T, dtype=i32)]),
        "model_nll": (ep_model_nll,
                      [S(psize), S(B, T, dtype=i32), S(B, T, dtype=i32)]),
        "model_lr_nll": (ep_model_lr_nll,
                         [S(psize), S(cfg.n_layers * fsize),
                          S(cfg.n_layers * msize),
                          S(B, T, dtype=i32), S(B, T, dtype=i32)]),
        "model_lr_fwd": (ep_model_lr_fwd,
                         [S(psize), S(cfg.n_layers * fsize),
                          S(cfg.n_layers * msize), S(B, T, dtype=i32)]),
        "block_fwd": (ep_block_fwd, [S(bsize), S(B, T, d)]),
        "block_collect": (ep_block_collect, [S(bsize), S(B, T, d)]),
        "block_lr_fwd": (ep_block_lr_fwd, [S(fsize), S(msize), S(B, T, d)]),
        "block_lr_collect": (ep_block_lr_collect,
                             [S(fsize), S(msize), S(B, T, d)]),
        "refine_step": (ep_refine_step,
                        [S(fsize), S(fsize), S(fsize), S(dtype=i32), S(),
                         S(msize), S(BR, T, d), S(BR, T, d)]),
        "train_step": (ep_train_step,
                       [S(psize), S(psize), S(psize), S(dtype=i32), S(),
                        S(cfg.train_batch, T, dtype=i32),
                        S(cfg.train_batch, T, dtype=i32)]),
    }
