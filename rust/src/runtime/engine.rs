//! PJRT execution engine: compiles HLO-text artifacts once, then serves
//! typed host-side calls from the coordinator's hot paths.
//!
//! Executables are cached per (config, artifact). Inputs travel as
//! `Value` views over host slices; outputs come back as `HostTensor`s.
//! Device-buffer reuse for loop-invariant inputs (model params) is exposed
//! through `DeviceCache` — see EXPERIMENTS.md §Perf for the measured win.

use super::manifest::{ArtifactSpec, ConfigEntry, DType, Manifest};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

/// A borrowed, typed input tensor.
#[derive(Clone, Copy, Debug)]
pub enum Value<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
    ScalarI32(i32),
}

/// An owned, typed output tensor.
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub f32: Vec<f32>, // i32 outputs are converted (none exist today)
}

pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    // cache key: "<config>/<artifact>"
    executables: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    pub stats: RefCell<EngineStats>,
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
    pub h2d_bytes: usize,
}

impl Engine {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            manifest,
            client,
            executables: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    pub fn entry(&self, config: &str) -> Result<&ConfigEntry> {
        self.manifest.entry(config)
    }

    /// Compile (or fetch from cache) an artifact's executable.
    fn executable(
        &self,
        config: &str,
        artifact: &str,
    ) -> Result<std::cell::Ref<'_, xla::PjRtLoadedExecutable>> {
        let key = format!("{config}/{artifact}");
        if !self.executables.borrow().contains_key(&key) {
            let spec = self.entry(config)?.artifact(artifact)?;
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?;
            let mut stats = self.stats.borrow_mut();
            stats.compiles += 1;
            stats.compile_secs += t0.elapsed().as_secs_f64();
            self.executables.borrow_mut().insert(key.clone(), exe);
        }
        Ok(std::cell::Ref::map(self.executables.borrow(), |m| {
            m.get(&key).unwrap()
        }))
    }

    /// Pre-compile a set of artifacts (warms the cache; used at startup so
    /// serving latencies exclude compilation).
    pub fn warmup(&self, config: &str, artifacts: &[&str]) -> Result<()> {
        for a in artifacts {
            self.executable(config, a)?;
        }
        Ok(())
    }

    fn literal(&self, spec: &super::manifest::TensorSpec, v: &Value) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match (spec.dtype, v) {
            (DType::F32, Value::F32(data)) => {
                if data.len() != spec.numel() {
                    bail!(
                        "f32 input length {} != spec {:?}",
                        data.len(),
                        spec.shape
                    );
                }
                xla::Literal::vec1(data).reshape(&dims)?
            }
            (DType::I32, Value::I32(data)) => {
                if data.len() != spec.numel() {
                    bail!(
                        "i32 input length {} != spec {:?}",
                        data.len(),
                        spec.shape
                    );
                }
                xla::Literal::vec1(data).reshape(&dims)?
            }
            (DType::F32, Value::ScalarF32(x)) => {
                if !spec.shape.is_empty() {
                    bail!("scalar given for non-scalar spec {:?}", spec.shape);
                }
                xla::Literal::scalar(*x)
            }
            (DType::I32, Value::ScalarI32(x)) => {
                if !spec.shape.is_empty() {
                    bail!("scalar given for non-scalar spec {:?}", spec.shape);
                }
                xla::Literal::scalar(*x)
            }
            (dt, _) => bail!("input dtype mismatch (artifact wants {dt:?})"),
        };
        self.stats.borrow_mut().h2d_bytes += lit.size_bytes();
        Ok(lit)
    }

    /// Execute `artifact` with host inputs; returns one HostTensor per
    /// declared output.
    pub fn run(
        &self,
        config: &str,
        artifact: &str,
        inputs: &[Value],
    ) -> Result<Vec<HostTensor>> {
        let spec: ArtifactSpec = self.entry(config)?.artifact(artifact)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{config}/{artifact}: got {} inputs, artifact wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let lits: Vec<xla::Literal> = spec
            .inputs
            .iter()
            .zip(inputs)
            .enumerate()
            .map(|(i, (s, v))| {
                self.literal(s, v)
                    .with_context(|| format!("{config}/{artifact} input {i}"))
            })
            .collect::<Result<_>>()?;

        let exe = self.executable(config, artifact)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {config}/{artifact}"))?[0][0]
            .to_literal_sync()?;
        {
            let mut stats = self.stats.borrow_mut();
            stats.executions += 1;
            stats.execute_secs += t0.elapsed().as_secs_f64();
        }
        // artifacts are lowered with return_tuple=True
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{config}/{artifact}: {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| {
                let f32 = match ospec.dtype {
                    DType::F32 => lit.to_vec::<f32>()?,
                    DType::I32 => lit
                        .to_vec::<i32>()?
                        .into_iter()
                        .map(|x| x as f32)
                        .collect(),
                };
                if f32.len() != ospec.numel() {
                    bail!(
                        "output length {} != manifest {:?}",
                        f32.len(),
                        ospec.shape
                    );
                }
                Ok(HostTensor {
                    shape: ospec.shape.clone(),
                    f32,
                })
            })
            .collect()
    }

    /// `run`, taking the artifact's first output by value — forward
    /// plumbing for hot paths (serving backends) that stream one tensor
    /// out per step and should not clone it.
    pub fn run_first(
        &self,
        config: &str,
        artifact: &str,
        inputs: &[Value],
    ) -> Result<HostTensor> {
        let mut out = self.run(config, artifact, inputs)?;
        if out.is_empty() {
            bail!("{config}/{artifact}: artifact declares no outputs");
        }
        Ok(out.swap_remove(0))
    }

    pub fn stats_snapshot(&self) -> EngineStats {
        self.stats.borrow().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        Engine::new("artifacts").ok()
    }

    #[test]
    fn cov_accum_artifact_runs() {
        let Some(eng) = engine() else { return };
        let e = eng.entry("tiny").unwrap();
        let d = e.config.d_model;
        let chunk = e.cov_chunk;
        let c = vec![0f32; d * d];
        let x = vec![1f32; chunk * d];
        let out = eng
            .run("tiny", "cov_accum_d", &[Value::F32(&c), Value::F32(&x)])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![d, d]);
        assert!((out[0].f32[0] - chunk as f32).abs() < 1e-3);
    }

    #[test]
    fn executable_cache_hits() {
        let Some(eng) = engine() else { return };
        let e = eng.entry("tiny").unwrap();
        let d = e.config.d_model;
        let c = vec![0f32; d * d];
        let x = vec![0.5f32; e.cov_chunk * d];
        for _ in 0..3 {
            eng.run("tiny", "cov_accum_d", &[Value::F32(&c), Value::F32(&x)])
                .unwrap();
        }
        let stats = eng.stats_snapshot();
        assert_eq!(stats.compiles, 1, "must compile once");
        assert_eq!(stats.executions, 3);
    }

    #[test]
    fn input_arity_and_shape_errors() {
        let Some(eng) = engine() else { return };
        let bad = eng.run("tiny", "cov_accum_d", &[Value::F32(&[0.0])]);
        assert!(bad.is_err());
        let short = vec![0f32; 3];
        let e = eng.entry("tiny").unwrap();
        let x = vec![0f32; e.cov_chunk * e.config.d_model];
        let bad2 = eng.run("tiny", "cov_accum_d", &[Value::F32(&short), Value::F32(&x)]);
        assert!(bad2.is_err());
    }
}
