//! Small numeric-summary helpers shared by eval, benches and serving metrics.

// aasvd-lint: allow-file(float-reduce): sequential slice reductions with a fixed iteration order — summary statistics for reports, never on the compressed-artifact path

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (n-1 denominator; 0 if n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation on the sorted sample, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let rank = (q / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Cosine distance 1 - <a,b>/(|a||b|); 0 for zero vectors.
pub fn cosine_distance(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    1.0 - dot / (na.sqrt() * nb.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
        assert_eq!(mse(&[], &[]), 0.0);
    }

    #[test]
    fn cosine_identities() {
        let a = [1.0f32, 0.0, 0.0];
        let b = [0.0f32, 1.0, 0.0];
        assert!((cosine_distance(&a, &a)).abs() < 1e-9);
        assert!((cosine_distance(&a, &b) - 1.0).abs() < 1e-9);
        let c = [-1.0f32, 0.0, 0.0];
        assert!((cosine_distance(&a, &c) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
