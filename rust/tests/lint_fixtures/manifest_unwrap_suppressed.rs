// aasvd-lint: path=src/runtime/manifest.rs

pub fn shard_hash(entries: &[(String, Option<u64>)]) -> u64 {
    // aasvd-lint: allow(serve-unwrap): fixture justification — caller guarantees a written entry exists
    entries.first().unwrap().1.unwrap_or(0)
}
