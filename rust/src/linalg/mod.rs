//! Pure-Rust dense linear algebra for the compression closed form.
//!
//! XLA-CPU lowers `jnp.linalg.*` to LAPACK custom-calls that the pinned
//! xla_extension 0.5.1 cannot execute, so Cholesky / EVD / SVD live here.
//! Sizes are bounded by the model's hidden dims (≤ ~1k), comfortably within
//! pure-Rust range; see benches/linalg.rs for measured throughput.

pub mod chol;
pub mod eigh;
pub mod matrix;
pub mod qr;
pub mod svd;

pub use chol::{cholesky, cholesky_jittered, right_mul_inv_rt, solve_lower, solve_upper_t};
pub use eigh::{eigh, evd_whitening_factor};
pub use matrix::Matrix;
pub use svd::{svd, svd_k, Svd};
